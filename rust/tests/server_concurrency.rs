//! Server queue-discipline concurrency test (ISSUE 2 satellite): under a
//! saturated normal-request queue, a critical request jumps the queue, so
//! its observed queueing latency stays below the normal-class median.
//!
//! Uses a synthetic [`Executor`] (fixed per-request service time) so the
//! discipline is exercised without the `pjrt` feature; assertions are
//! comparative (critical vs normal median), not absolute wall-clock, to
//! stay robust on loaded CI machines. Bounded: ~32 x 2ms of service time.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use miriam::gpu::kernel::Criticality;
use miriam::server::{Executor, InferRequest, Server};

fn sleepy_executor() -> Box<dyn Executor> {
    Box::new(|_model: &str, input: &[f32]| -> anyhow::Result<Vec<f32>> {
        thread::sleep(Duration::from_millis(2));
        Ok(vec![input.first().copied().unwrap_or(0.0) + 1.0])
    })
}

#[test]
fn critical_request_jumps_a_saturated_normal_queue() {
    let server = Server::start_with_executor(|| Ok(sleepy_executor()))
        .expect("server starts");
    let n_normal = 32usize;

    // Saturate: enqueue every normal request up front (submit does not
    // block), keeping the reply channels.
    let mut replies = Vec::new();
    for i in 0..n_normal {
        let (tx, rx) = mpsc::channel();
        server.handle.submit(InferRequest {
            model: "m".into(),
            criticality: Criticality::Normal,
            input: vec![i as f32],
            reply: tx,
        });
        replies.push(rx);
    }

    // With the backlog enqueued, issue the critical request; the worker
    // thread is mid-backlog, so this exercises the priority pop under
    // real contention between the test thread and the worker.
    let crit = server.handle.infer("m", Criticality::Critical, vec![100.0]);
    assert!(crit.ok, "critical request failed: {:?}", crit.error);

    let mut normal_lat: Vec<f64> = replies
        .into_iter()
        .map(|rx| {
            let r = rx.recv().expect("normal reply");
            assert!(r.ok);
            r.latency_us
        })
        .collect();
    normal_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = normal_lat[n_normal / 2];

    // The critical request waited for at most the in-flight request plus
    // its own service time; the median normal request sat behind half the
    // backlog. Orders of magnitude apart — compare, don't time.
    assert!(crit.latency_us < median,
            "critical latency {:.0}us not below normal median {:.0}us",
            crit.latency_us, median);

    let stats = &server.handle.stats;
    assert_eq!(stats.served_critical.load(Ordering::Relaxed), 1);
    assert_eq!(stats.served_normal.load(Ordering::Relaxed), n_normal as u64);
    assert_eq!(stats.errors.load(Ordering::Relaxed), 0);
    assert!(stats.mean_critical_latency_us() > 0.0);
    assert!(stats.mean_normal_latency_us() > stats.mean_critical_latency_us());
    server.stop();
}

#[test]
fn executor_errors_are_reported_not_fatal() {
    let server = Server::start_with_executor(|| {
        Ok(Box::new(|model: &str, input: &[f32]| {
            if model == "broken" {
                Err(anyhow::anyhow!("no such model"))
            } else {
                Ok(vec![input.iter().sum()])
            }
        }) as Box<dyn Executor>)
    })
    .expect("server starts");
    let bad = server.handle.infer("broken", Criticality::Normal, vec![1.0]);
    assert!(!bad.ok);
    assert!(bad.error.as_deref().unwrap_or("").contains("no such model"));
    // The worker survives an executor error and keeps serving.
    let good = server.handle.infer("ok", Criticality::Critical,
                                   vec![1.0, 2.0]);
    assert!(good.ok);
    assert!((good.output[0] - 3.0).abs() < 1e-6);
    let stats = &server.handle.stats;
    assert_eq!(stats.errors.load(Ordering::Relaxed), 1);
    assert_eq!(stats.served_critical.load(Ordering::Relaxed), 1);
    server.stop();
}

#[test]
fn factory_failure_propagates_from_start() {
    let err = Server::start_with_executor(|| Err(anyhow::anyhow!("boom")));
    assert!(err.is_err());
}
