//! Thread-count determinism of the parallel sweep runner (ISSUE 3
//! tentpole contract): a sweep's per-cell results — including canonical
//! engine traces — are byte-identical whether the grid runs on one worker
//! or many, because cells share nothing and land in slots indexed by grid
//! position. Also pins the seed-derivation rule and that the retained
//! `miriam-ref` coordinator path walks the exact trajectory of the
//! zero-clone fast path (so the bench legs measure cost, not behavior).

use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::scheduler_for;
use miriam::coordinator::sweep::{self, SweepSpec};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::scenario;

const DUR_US: f64 = 12_000.0;

fn small_spec(trace: bool) -> SweepSpec {
    SweepSpec {
        platform: "rtx2060".into(),
        duration_us: DUR_US,
        scenarios: scenario::family(DUR_US).into_iter().take(2).collect(),
        schedulers: vec!["sequential".into(), "miriam".into()],
        seeds: 2,
        trace,
        reference_rates: false,
    }
}

#[test]
fn one_thread_and_many_threads_produce_byte_identical_cells() {
    let spec = small_spec(true);
    let a = sweep::run_sweep(&spec, 1).expect("1-thread sweep");
    let b = sweep::run_sweep(&spec, 4).expect("4-thread sweep");
    assert_eq!(a.cells.len(), 8); // 2 scenarios x 2 schedulers x 2 seeds
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.scenario, y.scenario);
        assert_eq!(x.scheduler, y.scheduler);
        assert_eq!(x.replica, y.replica);
        assert_eq!(x.seed, y.seed);
        assert_eq!(x.events, y.events, "{}/{}", x.scenario, x.scheduler);
        assert_eq!(x.launches, y.launches);
        assert_eq!(x.completed_critical, y.completed_critical);
        assert_eq!(x.completed_normal, y.completed_normal);
        assert_eq!(x.deadline_misses_critical, y.deadline_misses_critical);
        // Latency stats must agree to the bit (NaN-safe comparison).
        assert_eq!(x.crit_p50_us.to_bits(), y.crit_p50_us.to_bits());
        assert_eq!(x.crit_p99_us.to_bits(), y.crit_p99_us.to_bits());
        assert_eq!(x.throughput_rps.to_bits(), y.throughput_rps.to_bits());
        // The tentpole contract: byte-identical canonical traces per cell.
        let tx = x.trace_json.as_ref().expect("trace requested");
        let ty = y.trace_json.as_ref().expect("trace requested");
        assert!(!tx.is_empty());
        assert_eq!(tx, ty,
                   "{}/{}/replica {}: canonical traces differ across \
                    thread counts", x.scenario, x.scheduler, x.replica);
    }
}

#[test]
fn replica_zero_reproduces_a_direct_driver_run() {
    // Sweep cells at replica 0 keep the scenario's pinned seed, so they
    // are the same runs the conformance suite pins.
    let sc = scenario::by_name("duo-burst", DUR_US).unwrap();
    let wl = sc.build();
    let mut s = scheduler_for("sequential", &wl).unwrap();
    let direct = driver::run_with(
        GpuSpec::rtx2060(), &wl, s.as_mut(),
        RunOpts { reference_rates: false, trace: true });
    let direct_json = direct.trace.as_ref().unwrap().to_canonical_json();

    let spec = SweepSpec {
        platform: "rtx2060".into(),
        duration_us: DUR_US,
        scenarios: vec![sc],
        schedulers: vec!["sequential".into()],
        seeds: 1,
        trace: true,
        reference_rates: false,
    };
    let report = sweep::run_sweep(&spec, 2).unwrap();
    assert_eq!(report.cells.len(), 1);
    let cell = &report.cells[0];
    assert_eq!(cell.replica, 0);
    assert_eq!(cell.seed, 0x2B1);
    assert_eq!(cell.trace_json.as_deref(), Some(direct_json.as_str()));
    assert_eq!(cell.events, direct.events);
}

#[test]
fn different_replicas_actually_decorrelate() {
    // Replica 1 must be a different run than replica 0 on a stochastic
    // scenario (otherwise "8 seeds" would be 8 copies of one sample).
    let spec = SweepSpec {
        platform: "rtx2060".into(),
        duration_us: DUR_US,
        scenarios: vec![scenario::by_name("duo-burst", DUR_US).unwrap()],
        schedulers: vec!["sequential".into()],
        seeds: 2,
        trace: true,
        reference_rates: false,
    };
    let report = sweep::run_sweep(&spec, 2).unwrap();
    assert_eq!(report.cells.len(), 2);
    assert_ne!(report.cells[0].seed, report.cells[1].seed);
    assert_ne!(report.cells[0].trace_json, report.cells[1].trace_json);
}

#[test]
fn miriam_ref_trace_matches_miriam_trace() {
    // The retained pre-change coordinator plumbing must be decision-
    // identical to the zero-clone fast path on a contended scenario.
    let sc = scenario::by_name("duo-burst", DUR_US).unwrap();
    let run = |sched: &str| {
        let wl = sc.build();
        let mut s = scheduler_for(sched, &wl).unwrap();
        let mut st = driver::run_with(
            GpuSpec::rtx2060(), &wl, s.as_mut(),
            RunOpts { reference_rates: false, trace: true });
        (st.trace.take().unwrap(), st)
    };
    let (t_fast, st_fast) = run("miriam");
    let (t_ref, st_ref) = run("miriam-ref");
    assert_eq!(st_fast.events, st_ref.events);
    assert_eq!(st_fast.timeline.len(), st_ref.timeline.len());
    let divs = t_fast.diff(&t_ref);
    assert!(divs.is_empty(),
            "miriam vs miriam-ref diverge at {} point(s); first: {}",
            divs.len(), divs[0]);
}
