//! Steady-state allocation contract (ISSUE 3 satellite): once caches are
//! warm, the Miriam pump + completion path performs **zero** heap
//! allocations per event, and the engine event loop allocates only the
//! per-*launch* record strings (EXPERIMENTS.md §Perf).
//!
//! A counting `#[global_allocator]` wraps the system allocator with
//! per-thread (const-initialized TLS) counters, so parallel test threads
//! cannot pollute each other's windows. Counting is toggled only around
//! the code under measurement; everything the harness itself does
//! (request construction, bookkeeping, asserts) stays outside the
//! windows. All runs are deterministic, so these bounds are exact
//! regressions gates, not flaky heuristics.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use miriam::coordinator::miriam::Miriam;
use miriam::coordinator::scheduler::{Req, Scheduler};
use miriam::coordinator::stats::StreamingSummary;
use miriam::gpu::engine::{Completion, Engine};
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::runtime::timewheel::TimingWheel;
use miriam::workloads::generation;
use miriam::workloads::models::{self, ModelRef};
use miriam::workloads::rng::Rng;

thread_local! {
    static COUNTING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn bump() {
        // `try_with`: the allocator may run during TLS teardown.
        let _ = COUNTING.try_with(|on| {
            if on.get() {
                let _ = ALLOCS.try_with(|n| n.set(n.get() + 1));
            }
        });
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        Self::bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn counting(on: bool) {
    COUNTING.with(|c| c.set(on));
}

fn allocs() -> u64 {
    ALLOCS.with(|n| n.get())
}

fn make_req(model: &ModelRef, ids: &Arc<Vec<u32>>, next_id: &mut u64,
            crit: Criticality, now: f64) -> Req {
    let req = Req {
        id: *next_id,
        source: if crit == Criticality::Critical { 0 } else { 1 },
        model: model.clone(),
        name_ids: ids.clone(),
        criticality: crit,
        arrival_us: now,
    };
    *next_id += 1;
    req
}

#[test]
fn warm_pump_and_completion_path_allocates_nothing() {
    // Normal-only closed loop (2 clients of cifarnet): after warmup every
    // elastic cache entry, shard name id, slab slot, and container
    // capacity exists, and the scheduler windows must be allocation-free.
    let mut eng = Engine::new(GpuSpec::rtx2060());
    let mut m = Miriam::new(&[]);
    m.init(&mut eng);
    let model: ModelRef = Arc::new(models::cifarnet());
    let ids = Arc::new(model.intern_kernels(|n| eng.intern_name(n)));
    let mut next_id: u64 = 1;
    let mut completions: Vec<Completion> = Vec::new();
    let mut finished: Vec<u64> = Vec::new();
    for _ in 0..2 {
        let req = make_req(&model, &ids, &mut next_id, Criticality::Normal,
                           eng.now_us());
        m.on_request(req, &mut eng);
    }

    const WARMUP: u64 = 2000;
    const TOTAL: u64 = 5000;
    let mut events: u64 = 0;
    let mut measured_calls: u64 = 0;
    let mut measured_allocs: u64 = 0;
    while events < TOTAL {
        if eng.next_event_time().is_none() {
            break;
        }
        eng.step_into(&mut completions);
        events += 1;
        let warm = events > WARMUP;
        for c in &completions {
            finished.clear();
            let a0 = allocs();
            counting(true);
            m.on_completion(c, &mut eng, &mut finished);
            counting(false);
            if warm {
                measured_allocs += allocs() - a0;
                measured_calls += 1;
            }
            for _ in 0..finished.len() {
                // Closed loop: replace the finished request immediately.
                let req = make_req(&model, &ids, &mut next_id,
                                   Criticality::Normal, eng.now_us());
                let a0 = allocs();
                counting(true);
                m.on_request(req, &mut eng);
                counting(false);
                if warm {
                    measured_allocs += allocs() - a0;
                }
            }
        }
    }
    assert_eq!(events, TOTAL, "event loop stalled early");
    assert!(measured_calls > 200,
            "too few warm completions measured: {measured_calls}");
    assert_eq!(measured_allocs, 0,
               "warm Miriam pump+completion path allocated \
                {measured_allocs} time(s) over {measured_calls} calls");
}

#[test]
fn warm_decode_step_resubmit_path_allocates_nothing() {
    // ISSUE 10 generation serving: a decode step is a tiny five-launch
    // graph, and one run re-submits thousands of them (one per emitted
    // token) through the interned fast path. Pre-intern every kv-bucket
    // decode graph of llama-nano, then run two closed-loop clients whose
    // completions immediately resubmit the next decode step at the next
    // bucket — the same shape `server::gen` produces as a request's KV
    // cache grows. Once every bucket's elastic cache entry and shard
    // name id is warm, the on_completion + resubmit windows must be
    // exactly allocation-free: token loops stay O(Δ) regardless of how
    // many tiny launches a generation emits.
    let gen = generation::gen_model_by_name("llama-nano").expect("model");
    let mut eng = Engine::new(GpuSpec::rtx2060());
    let mut m = Miriam::new(&[]);
    m.init(&mut eng);
    let nb = (gen.max_context / gen.kv_bucket) as usize;
    assert!(nb >= 4, "need several kv buckets to cycle, got {nb}");
    let graphs: Vec<(ModelRef, Arc<Vec<u32>>)> = (1..=nb as u32)
        .map(|i| {
            let g: ModelRef = Arc::new(gen.decode_graph(i * gen.kv_bucket));
            let ids = Arc::new(g.intern_kernels(|n| eng.intern_name(n)));
            (g, ids)
        })
        .collect();
    let mut next_id: u64 = 1;
    let mut step: u64 = 0; // global decode-step ordinal, cycles buckets
    let mut completions: Vec<Completion> = Vec::new();
    let mut finished: Vec<u64> = Vec::new();
    for client in 0..2usize {
        let (g, ids) = &graphs[client % nb];
        let req = make_req(g, ids, &mut next_id, Criticality::Normal,
                           eng.now_us());
        m.on_request(req, &mut eng);
    }

    const WARMUP: u64 = 2000;
    const TOTAL: u64 = 6000;
    let mut events: u64 = 0;
    let mut measured_calls: u64 = 0;
    let mut measured_allocs: u64 = 0;
    while events < TOTAL {
        if eng.next_event_time().is_none() {
            break;
        }
        eng.step_into(&mut completions);
        events += 1;
        let warm = events > WARMUP;
        for c in &completions {
            finished.clear();
            let a0 = allocs();
            counting(true);
            m.on_completion(c, &mut eng, &mut finished);
            counting(false);
            if warm {
                measured_allocs += allocs() - a0;
                measured_calls += 1;
            }
            for _ in 0..finished.len() {
                // Re-submit the next decode step at the next kv bucket,
                // exactly as the generation loop does per token.
                step += 1;
                let (g, ids) = &graphs[step as usize % nb];
                let req = make_req(g, ids, &mut next_id,
                                   Criticality::Normal, eng.now_us());
                let a0 = allocs();
                counting(true);
                m.on_request(req, &mut eng);
                counting(false);
                if warm {
                    measured_allocs += allocs() - a0;
                }
            }
        }
    }
    assert_eq!(events, TOTAL, "event loop stalled early");
    assert!(measured_calls > 200,
            "too few warm decode completions measured: {measured_calls}");
    assert!(step > nb as u64 * 4, "bucket cycle barely exercised: {step}");
    assert_eq!(measured_allocs, 0,
               "warm decode-step resubmit path allocated {measured_allocs} \
                time(s) over {measured_calls} calls");
}

#[test]
fn warm_timewheel_and_sketch_path_allocates_nothing() {
    // ISSUE 7 event core: a closed-loop wheel (256 in-flight sources,
    // quantized gaps so slots keep real multi-entry occupancy) feeding a
    // streaming quantile sketch. Slot buffers recycle through the ready
    // buffer and the sketch is a fixed five-marker array, so once
    // capacities have circulated the warm window must be exactly
    // allocation-free — this is the contract that makes the 100k-tenant
    // scale path O(tenants) resident instead of O(arrivals).
    let mut wheel = TimingWheel::new();
    let mut sketch = StreamingSummary::new();
    let mut rng = Rng::new(0xA110C);
    for src in 0..256usize {
        wheel.push(src as f64 * 3.5, src);
    }

    const WARMUP: u64 = 100_000;
    const MEASURE: u64 = 20_000;
    let mut measured_allocs: u64 = 0;
    for op in 0..WARMUP + MEASURE {
        let gap = (1 + rng.next_below(96)) as f64 * 2.5;
        let a0 = allocs();
        counting(true);
        let (t, src) = wheel.pop().expect("closed loop never drains");
        wheel.push(t + gap, src);
        sketch.record(gap);
        counting(false);
        if op >= WARMUP {
            measured_allocs += allocs() - a0;
        }
    }
    assert_eq!(wheel.len(), 256);
    assert_eq!(sketch.count(), WARMUP + MEASURE);
    assert!(sketch.p50().is_finite() && sketch.p99().is_finite());
    assert_eq!(measured_allocs, 0,
               "warm wheel+sketch event path allocated {measured_allocs} \
                time(s) over {MEASURE} ops");
}

#[test]
fn engine_event_loop_allocates_only_per_launch_records() {
    // Same workload, counting the *engine* windows: the only steady-state
    // allocations are the launch-record strings (one resolve + one clone
    // per completed launch) plus amortized metrics-vector growth.
    let mut eng = Engine::new(GpuSpec::rtx2060());
    let mut m = Miriam::new(&[]);
    m.init(&mut eng);
    let model: ModelRef = Arc::new(models::cifarnet());
    let ids = Arc::new(model.intern_kernels(|n| eng.intern_name(n)));
    let mut next_id: u64 = 1;
    let mut completions: Vec<Completion> = Vec::new();
    let mut finished: Vec<u64> = Vec::new();
    for _ in 0..2 {
        let req = make_req(&model, &ids, &mut next_id, Criticality::Normal,
                           eng.now_us());
        m.on_request(req, &mut eng);
    }

    const WARMUP: u64 = 2000;
    const TOTAL: u64 = 5000;
    let mut events: u64 = 0;
    let mut measured_allocs: u64 = 0;
    let mut measured_launches: u64 = 0;
    while events < TOTAL {
        if eng.next_event_time().is_none() {
            break;
        }
        let warm = events > WARMUP;
        let a0 = allocs();
        counting(true);
        eng.step_into(&mut completions);
        counting(false);
        events += 1;
        if warm {
            measured_allocs += allocs() - a0;
            measured_launches += completions.len() as u64;
        }
        for c in &completions {
            finished.clear();
            m.on_completion(c, &mut eng, &mut finished);
            for _ in 0..finished.len() {
                let req = make_req(&model, &ids, &mut next_id,
                                   Criticality::Normal, eng.now_us());
                m.on_request(req, &mut eng);
            }
        }
    }
    assert_eq!(events, TOTAL, "event loop stalled early");
    assert!(measured_launches > 100, "too few launches: {measured_launches}");
    let bound = 4 * measured_launches + 64;
    assert!(measured_allocs <= bound,
            "engine loop allocated {measured_allocs} times for \
             {measured_launches} launches (bound {bound})");
}

#[test]
fn contended_scheduler_path_stays_sub_allocation_per_event() {
    // Critical AlexNet (kept one inflight, closed loop) against two
    // closed-loop CifarNet clients: real contention, so shards carve at
    // varying geometry. Shard-name interning may still fault in a few
    // late-first-seen indexes, so the contract here is a hard sub-linear
    // bound rather than strict zero — pre-ISSUE-3 plumbing (deep clones +
    // snapshots per pump) sat at several allocations per event and fails
    // this by an order of magnitude.
    let crit_model: ModelRef = Arc::new(models::alexnet());
    let norm_model: ModelRef = Arc::new(models::cifarnet());
    let mut eng = Engine::new(GpuSpec::rtx2060());
    let mut m = Miriam::new(&[crit_model.clone()]);
    m.init(&mut eng);
    let crit_ids = Arc::new(crit_model.intern_kernels(|n| eng.intern_name(n)));
    let norm_ids = Arc::new(norm_model.intern_kernels(|n| eng.intern_name(n)));
    let mut next_id: u64 = 1;
    let mut completions: Vec<Completion> = Vec::new();
    let mut finished: Vec<u64> = Vec::new();

    let crit_req = make_req(&crit_model, &crit_ids, &mut next_id,
                            Criticality::Critical, 0.0);
    let mut crit_live = crit_req.id;
    m.on_request(crit_req, &mut eng);
    for _ in 0..2 {
        let req = make_req(&norm_model, &norm_ids, &mut next_id,
                           Criticality::Normal, eng.now_us());
        m.on_request(req, &mut eng);
    }

    const WARMUP: u64 = 4000;
    const TOTAL: u64 = 8000;
    let mut events: u64 = 0;
    let mut measured_events: u64 = 0;
    let mut measured_allocs: u64 = 0;
    while events < TOTAL {
        if eng.next_event_time().is_none() {
            break;
        }
        eng.step_into(&mut completions);
        events += 1;
        let warm = events > WARMUP;
        if warm {
            measured_events += 1;
        }
        for c in &completions {
            finished.clear();
            let a0 = allocs();
            counting(true);
            m.on_completion(c, &mut eng, &mut finished);
            counting(false);
            if warm {
                measured_allocs += allocs() - a0;
            }
            for &done in &finished {
                let (model, ids, crit) = if done == crit_live {
                    (&crit_model, &crit_ids, Criticality::Critical)
                } else {
                    (&norm_model, &norm_ids, Criticality::Normal)
                };
                let req = make_req(model, ids, &mut next_id, crit,
                                   eng.now_us());
                if crit == Criticality::Critical {
                    crit_live = req.id;
                }
                let a0 = allocs();
                counting(true);
                m.on_request(req, &mut eng);
                counting(false);
                if warm {
                    measured_allocs += allocs() - a0;
                }
            }
        }
    }
    assert_eq!(events, TOTAL, "event loop stalled early");
    assert!(measured_events > 1000);
    let bound = measured_events / 4 + 64;
    assert!(measured_allocs <= bound,
            "contended scheduler path allocated {measured_allocs} times \
             over {measured_events} events (bound {bound})");
}
