//! Determinism and differential contracts of the fleet serving loop
//! (ISSUE 5 satellite):
//!
//! * a **1-device fleet** under `round-robin` + policy `none` reproduces
//!   the existing `serve-sim` trajectory **bitwise** — per-tenant
//!   p50/p99/served identical, span and event counts equal (fleet and
//!   single-device runs share `DeviceCore`, so this pins the refactor);
//! * repeated fleet runs are **byte-identical** `BENCH_fleet.json`
//!   documents at any `--threads` value (reports carry no host timing
//!   and grid cells land in deterministic slots);
//! * heterogeneous fleets stay deterministic per (seed, devices, router)
//!   while different seeds produce different documents;
//! * (ISSUE 6) a **zero-event `ChaosSpec`** reproduces the chaos-free
//!   fleet document **bitwise** — arming the chaos layer without events
//!   must be invisible, pinning backward compatibility of the refactor;
//! * (ISSUE 6) the resilience grid (`BENCH_resilience.json`) is
//!   byte-identical across `--threads` values and repeat runs, with the
//!   autoscaler armed;
//! * (ISSUE 8) an **inert `FaultSpec`** reproduces the fault-free fleet
//!   document **bitwise** — arming the fault layer with zero
//!   probabilities must be invisible, pinning that fault-free runs
//!   match pre-fault builds byte for byte;
//! * (ISSUE 8) the faults grid (`BENCH_faults.json`) is byte-identical
//!   across `--threads` values and repeat runs.

use miriam::coordinator::admission::AdmissionPolicy;
use miriam::fleet::{run_fleet, run_fleet_grid, FleetOpts, FleetSpec, ROUTERS};
use miriam::gpu::spec::GpuSpec;
use miriam::server::online::{run_serve, ServeOpts};
use miriam::workloads::scenario;

const DUR_US: f64 = 40_000.0;

fn one_device(preset: &str, scheduler: &str) -> FleetSpec {
    FleetSpec::parse(&[preset.into()], &[scheduler.into()]).unwrap()
}

fn hetero() -> FleetSpec {
    FleetSpec::parse(
        &["rtx2060".into(), "xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .unwrap()
}

fn routers() -> Vec<String> {
    ROUTERS.iter().map(|r| r.to_string()).collect()
}

#[test]
fn one_device_fleet_reproduces_serve_sim_bitwise() {
    // Same scenario, same seed, same scheduler: the fleet loop with a
    // single device and the class-blind router must walk the exact
    // trajectory of run_serve — per-tenant quantiles compared to the bit.
    for (sc_name, sched) in
        [("duo-burst", "miriam"), ("five-storm", "miriam"),
         ("trio-skew", "multistream")]
    {
        let sc = scenario::by_name(sc_name, DUR_US).unwrap();
        let fleet_rep = run_fleet(
            &one_device("rtx2060", sched),
            &sc,
            &FleetOpts { router: "round-robin".into(),
                         ..FleetOpts::default() },
        )
        .expect("fleet run");
        let serve_rep = run_serve(
            &GpuSpec::rtx2060(),
            &sc,
            &ServeOpts { scheduler: sched.into(),
                         policy: AdmissionPolicy::Open,
                         ..ServeOpts::default() },
        )
        .expect("serve run");

        assert_eq!(fleet_rep.offered(), serve_rep.offered(),
                   "{sc_name}/{sched}: offered diverged");
        assert_eq!(fleet_rep.admitted(), serve_rep.admitted());
        assert_eq!(fleet_rep.shed(), 0);
        assert_eq!(fleet_rep.served(), serve_rep.served(),
                   "{sc_name}/{sched}: served diverged");
        assert_eq!(fleet_rep.events, serve_rep.events,
                   "{sc_name}/{sched}: event counts diverged");
        assert_eq!(fleet_rep.span_us.to_bits(), serve_rep.span_us.to_bits(),
                   "{sc_name}/{sched}: span diverged");
        assert_eq!(fleet_rep.crit_p99_us().to_bits(),
                   serve_rep.crit_p99_us().to_bits(),
                   "{sc_name}/{sched}: fleet-level critical p99 diverged");
        assert_eq!(fleet_rep.tenants.len(), serve_rep.tenants.len());
        for (f, s) in fleet_rep.tenants.iter().zip(&serve_rep.tenants) {
            assert_eq!(f.label, s.label);
            assert_eq!(f.offered, s.offered, "{sc_name}/{}", f.label);
            assert_eq!(f.admitted, s.admitted, "{sc_name}/{}", f.label);
            assert_eq!(f.served, s.served, "{sc_name}/{}", f.label);
            assert_eq!(f.deadline_misses, s.deadline_misses,
                       "{sc_name}/{}", f.label);
            assert_eq!(f.p50_us().to_bits(), s.p50_us().to_bits(),
                       "{sc_name}/{}: p50 not bitwise", f.label);
            assert_eq!(f.p99_us().to_bits(), s.p99_us().to_bits(),
                       "{sc_name}/{}: p99 not bitwise", f.label);
            // The whole latency vector, to the bit, in completion order.
            assert_eq!(f.latencies_us.len(), s.latencies_us.len());
            for (a, b) in f.latencies_us.iter().zip(&s.latencies_us) {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "{sc_name}/{}: latency stream diverged", f.label);
            }
        }
        // The single device absorbed everything.
        assert_eq!(fleet_rep.devices.len(), 1);
        assert_eq!(fleet_rep.devices[0].routed, fleet_rep.admitted());
        assert_eq!(fleet_rep.devices[0].max_normal_queue,
                   serve_rep.max_normal_queue);
    }
}

#[test]
fn fleet_grid_is_byte_identical_across_threads_and_repeats() {
    let scenarios: Vec<_> = scenario::family(DUR_US)
        .into_iter()
        .filter(|s| s.name == "duo-burst" || s.name == "trio-skew")
        .collect();
    assert_eq!(scenarios.len(), 2);
    let fleet = hetero();
    let base = FleetOpts::default();
    let j1 = run_fleet_grid(&fleet, &scenarios, &routers(), &base, 1)
        .expect("threads=1")
        .to_json();
    let j4 = run_fleet_grid(&fleet, &scenarios, &routers(), &base, 4)
        .expect("threads=4")
        .to_json();
    assert_eq!(j1, j4, "BENCH_fleet.json differs across --threads");
    let j1b = run_fleet_grid(&fleet, &scenarios, &routers(), &base, 1)
        .expect("repeat")
        .to_json();
    assert_eq!(j1, j1b, "BENCH_fleet.json differs across repeat runs");
}

#[test]
fn heterogeneous_repeat_runs_match_and_seeds_differ() {
    let sc = scenario::by_name("five-storm", DUR_US).unwrap();
    let fleet = hetero();
    for r in ROUTERS {
        let opts = FleetOpts { router: r.into(), ..FleetOpts::default() };
        let a = run_fleet(&fleet, &sc, &opts).expect("run a");
        let b = run_fleet(&fleet, &sc, &opts).expect("run b");
        assert_eq!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string(),
                   "{r}: repeat runs diverged");
        let c = run_fleet(&fleet, &sc,
                          &FleetOpts { seed: Some(99), ..opts.clone() })
            .expect("run c");
        assert_ne!(a.to_json_value().to_canonical_string(),
                   c.to_json_value().to_canonical_string(),
                   "{r}: a different seed produced an identical document");
    }
}

#[test]
fn zero_event_chaos_reproduces_the_chaos_free_fleet_bitwise() {
    use miriam::fleet::ChaosSpec;

    let sc = scenario::by_name("five-storm", DUR_US).unwrap();
    let fleet = hetero();
    for r in ROUTERS {
        let plain = run_fleet(
            &fleet, &sc,
            &FleetOpts { router: (*r).into(), ..FleetOpts::default() },
        )
        .expect("plain run");
        // A scripted-but-empty spec (as `--chaos ""` would never parse,
        // this is the library-level identity) must not perturb routing,
        // timing, or the document — not even by one byte.
        let zero = run_fleet(
            &fleet, &sc,
            &FleetOpts {
                router: (*r).into(),
                chaos: ChaosSpec { name: "scripted-empty".into(),
                                   events: Vec::new() },
                ..FleetOpts::default()
            },
        )
        .expect("zero-event run");
        assert_eq!(plain.to_json_value().to_canonical_string(),
                   zero.to_json_value().to_canonical_string(),
                   "{r}: an empty chaos script changed the fleet document");
    }
}

#[test]
fn resilience_grid_is_byte_identical_across_threads_and_repeats() {
    use miriam::fleet::{run_resilience_grid, AutoscaleConfig, STORMS};

    let scenarios = vec![
        scenario::flash_crowd(DUR_US),
        scenario::by_name("duo-burst", DUR_US).unwrap(),
    ];
    let fleet = hetero();
    let storms: Vec<String> = STORMS.iter().map(|s| s.to_string()).collect();
    let base = FleetOpts {
        autoscale: Some(AutoscaleConfig {
            pool: vec!["tx2".into()],
            ..AutoscaleConfig::default()
        }),
        ..FleetOpts::default()
    };
    let j1 = run_resilience_grid(&fleet, &scenarios, &storms, &routers(),
                                 &base, 1)
        .expect("threads=1")
        .to_json();
    let j4 = run_resilience_grid(&fleet, &scenarios, &storms, &routers(),
                                 &base, 4)
        .expect("threads=4")
        .to_json();
    assert_eq!(j1, j4, "BENCH_resilience.json differs across --threads");
    let j1b = run_resilience_grid(&fleet, &scenarios, &storms, &routers(),
                                  &base, 1)
        .expect("repeat")
        .to_json();
    assert_eq!(j1, j1b, "BENCH_resilience.json differs across repeat runs");
}

#[test]
fn inert_fault_spec_reproduces_the_fault_free_fleet_bitwise() {
    use miriam::fleet::FaultSpec;

    let sc = scenario::by_name("five-storm", DUR_US).unwrap();
    let fleet = hetero();
    for r in ROUTERS {
        let plain = run_fleet(
            &fleet, &sc,
            &FleetOpts { router: (*r).into(), ..FleetOpts::default() },
        )
        .expect("plain run");
        // All-zero probabilities: the spec is normalized away before the
        // loop starts, so routing, timing, and the document are
        // untouched — not even by one byte.
        let zero = run_fleet(
            &fleet, &sc,
            &FleetOpts {
                router: (*r).into(),
                faults: Some(FaultSpec::none()),
                ..FleetOpts::default()
            },
        )
        .expect("inert-fault run");
        assert_eq!(plain.to_json_value().to_canonical_string(),
                   zero.to_json_value().to_canonical_string(),
                   "{r}: an inert fault spec changed the fleet document");
    }
}

#[test]
fn faults_grid_is_byte_identical_across_threads_and_repeats() {
    use miriam::fleet::{faults, run_faults_grid, FaultSpec};

    let scenarios = vec![
        scenario::by_name("duo-burst", DUR_US).unwrap(),
        scenario::by_name("trio-skew", DUR_US).unwrap(),
    ];
    let fleet = hetero();
    let specs = vec![
        FaultSpec::none(),
        faults::storm("flaky-launches").unwrap(),
        faults::storm("full-fault-storm").unwrap(),
    ];
    let base = FleetOpts::default();
    let j1 = run_faults_grid(&fleet, &scenarios, &specs, &routers(),
                             &base, 1)
        .expect("threads=1")
        .to_json();
    let j4 = run_faults_grid(&fleet, &scenarios, &specs, &routers(),
                             &base, 4)
        .expect("threads=4")
        .to_json();
    assert_eq!(j1, j4, "BENCH_faults.json differs across --threads");
    let j1b = run_faults_grid(&fleet, &scenarios, &specs, &routers(),
                              &base, 1)
        .expect("repeat")
        .to_json();
    assert_eq!(j1, j1b, "BENCH_faults.json differs across repeat runs");
}

#[test]
fn routers_disagree_on_placement_but_share_the_arrival_stream() {
    // On a heterogeneous fleet the three routers must actually place
    // differently (otherwise the comparison is vacuous) while initial
    // open-loop arrivals — which do not depend on service — agree.
    let sc = scenario::by_name("quad-bursty", DUR_US).unwrap();
    let fleet = hetero();
    let reps: Vec<_> = ROUTERS
        .iter()
        .map(|r| {
            run_fleet(&fleet, &sc,
                      &FleetOpts { router: (*r).into(),
                                   ..FleetOpts::default() })
                .expect("run")
        })
        .collect();
    let placements: Vec<Vec<u64>> = reps
        .iter()
        .map(|r| r.devices.iter().map(|d| d.routed).collect())
        .collect();
    assert!(placements.iter().any(|p| p != &placements[0]),
            "all routers produced identical placements {placements:?}");
    for (r, rep) in ROUTERS.iter().zip(&reps) {
        assert_eq!(rep.routed(), rep.admitted(), "{r}");
        assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{r}");
    }
}
