//! Determinism contract of the 100k-tenant scale path (ISSUE 7
//! tentpole): `BENCH_scale.json` is a pure function of
//! (platform, scheduler, tenant counts, duration) — byte-identical
//! across repeat runs and across `--threads`, because every per-tenant
//! seed is derived from the scenario seed and the grid writes results
//! into position-indexed slots instead of completion order.

use miriam::gpu::spec::GpuSpec;
use miriam::server::scale::run_scale_grid;

const COUNTS: &[usize] = &[1000, 2000];
const DUR_US: f64 = 20_000.0;

#[test]
fn scale_grid_is_byte_identical_across_threads_and_repeats() {
    let gpu = GpuSpec::rtx2060();
    let base = run_scale_grid(&gpu, COUNTS, DUR_US, "miriam", 1)
        .expect("threads=1");
    let doc = base.to_json();
    for threads in [2usize, 4] {
        let other = run_scale_grid(&gpu, COUNTS, DUR_US, "miriam", threads)
            .expect("threaded grid");
        assert_eq!(doc, other.to_json(),
                   "BENCH_scale.json differs at threads={threads}");
    }
    let repeat = run_scale_grid(&gpu, COUNTS, DUR_US, "miriam", 1)
        .expect("repeat");
    assert_eq!(doc, repeat.to_json(),
               "BENCH_scale.json differs across repeat runs");
}

#[test]
fn scale_grid_document_is_canonical_and_complete() {
    let gpu = GpuSpec::rtx2060();
    let grid = run_scale_grid(&gpu, COUNTS, DUR_US, "miriam", 2)
        .expect("grid");
    let doc = grid.to_json();
    assert!(doc.contains("\"bench\":\"scale\""));
    // (`"nan"` would false-positive on the "tenants" key.)
    assert!(!doc.contains("inf") && !doc.contains("NaN"),
            "canonical JSON must not carry non-finite numbers");
    for &c in COUNTS {
        let cell = grid.cell(c).expect("cell present");
        assert_eq!(cell.tenants, c);
        assert!(cell.offered > 0 && cell.served > 0,
                "{c}-tenant cell served nothing");
        assert!(cell.served <= cell.offered);
        // Above the sketch threshold every tenant accounts in constant
        // memory; the per-tenant residency number the bench gate tracks
        // must stay small and positive.
        assert!(cell.sketch_tenants == c,
                "{c}-tenant cell left tenants on the exact path");
        assert!(cell.bytes_per_tenant > 0.0);
    }
}
