//! Property-based tests on coordinator/simulator invariants.
//!
//! `proptest` is not in the offline vendored crate set, so these use the
//! in-tree seeded RNG to sweep hundreds of randomized cases per property —
//! same idea, deterministic by construction (failures print the case).

use miriam::coordinator::admission::{
    AdmissionConfig, AdmissionController, AdmissionPolicy, Decision, POLICIES,
};
use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::scheduler_for;
use miriam::coordinator::shaded_tree::{Leftover, ShadedTree};
use miriam::elastic::candidate::Candidate;
use miriam::elastic::shrink::{self, CriticalProfile, ShrinkConfig};
use miriam::elastic::ElasticKernel;
use miriam::elastic::transformer;
use miriam::gpu::contention::{
    block_rates, block_rates_indexed, BlockWork, ContentionParams,
};
use miriam::gpu::engine::Engine;
use miriam::gpu::kernel::{Criticality, KernelDesc, LaunchConfig};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::mdtb;
use miriam::workloads::rng::Rng;

fn rand_kernel(rng: &mut Rng) -> KernelDesc {
    KernelDesc {
        name: format!("prop/k{}", rng.next_below(1_000_000)),
        grid: 1 + rng.next_below(256) as u32,
        block_threads: 1 + rng.next_below(1024) as u32,
        smem_per_block: (rng.next_below(48) * 1024) as u32,
        regs_per_thread: 16 + rng.next_below(48) as u32,
        flops: 1.0 + rng.next_f64() * 1e8,
        bytes: rng.next_f64() * 1e7,
    }
}

/// Property: every elastic transform is a partition of the kernel's
/// logical (block, thread) space — the §6.4 consistency theorem.
#[test]
fn prop_transform_partitions_logical_space() {
    let mut rng = Rng::new(0xE1A);
    for case in 0..300 {
        let grid = 1 + rng.next_below(64) as u32;
        let threads = 1 + rng.next_below(128) as u32;
        let k = KernelDesc {
            grid,
            block_threads: threads,
            ..rand_kernel(&mut rng)
        };
        let n_blocks = 1 + rng.next_below(grid as u64) as u32;
        let bt = 1 + rng.next_below(threads as u64) as u32;
        let maps = transformer::transform(&k, n_blocks, bt)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let covered: u32 = maps.iter().map(|m| m.logical_blocks).sum();
        assert_eq!(covered, grid, "case {case}: grid={grid} nb={n_blocks}");
        for m in maps {
            assert!(m.covers_exactly_once(),
                    "case {case}: grid={grid} threads={threads} nb={n_blocks} bt={bt}");
        }
    }
}

/// Property: shaded-tree shards always partition the kernel's grid and
/// its work totals, for arbitrary leftover sequences.
#[test]
fn prop_shaded_tree_partitions_grid_and_work() {
    let mut rng = Rng::new(0x7EE);
    for case in 0..300 {
        let k = rand_kernel(&mut rng);
        let candidates = vec![
            Candidate { n_blocks: 1 + rng.next_below(32) as u32,
                        block_threads: 32 },
            Candidate { n_blocks: 1 + rng.next_below(8) as u32,
                        block_threads: 64 },
            Candidate { n_blocks: k.grid, block_threads: k.block_threads },
        ];
        let mut tree = ShadedTree::new(std::sync::Arc::new(ElasticKernel {
            kernel: k.clone(),
            candidates,
        }));
        let mut blocks = 0u32;
        let mut flops = 0.0;
        let mut guard = 0;
        while !tree.fully_dispatched() {
            // Random leftover each round (the runtime's changing critical
            // context).
            let left = Leftover {
                blocks: 1 + rng.next_below(30) as u32,
                threads: 32 + rng.next_below(512) as u32,
                critical_active: rng.next_f64() < 0.7,
            };
            if let Some(s) = tree.next_shard(&left) {
                blocks += s.shape.grid;
                flops += s.shape.flops;
                tree.shard_done(s.shape.grid);
            }
            guard += 1;
            assert!(guard < 10_000, "case {case}: tree did not drain");
        }
        assert_eq!(blocks, k.grid, "case {case}");
        assert!((flops - k.flops).abs() < 1e-6 * k.flops.max(1.0),
                "case {case}: flops {flops} vs {}", k.flops);
        assert!(tree.finished());
    }
}

/// Property: every candidate kept by the design-space shrink satisfies
/// both Eq. 2 constraints for at least one profile, and the pruned
/// fraction is monotone in keep_frac.
#[test]
fn prop_shrink_keeps_only_feasible() {
    let mut rng = Rng::new(0x5112);
    let spec = GpuSpec::rtx2060();
    for case in 0..200 {
        let k = rand_kernel(&mut rng);
        let profiles: Vec<CriticalProfile> = (0..3)
            .map(|_| CriticalProfile {
                n_blk_rt: 1 + rng.next_below(128) as u32,
                s_blk_rt: 1 + rng.next_below(1024) as u32,
            })
            .collect();
        let cfg = ShrinkConfig::default();
        let out = shrink::shrink_design_space(&k, &profiles, &spec, &cfg);
        for c in &out.kept {
            assert!(profiles.iter().any(|p| shrink::feasible(c, p, &spec)),
                    "case {case}: kept infeasible candidate {c:?}");
            assert!(shrink::oscore(c, &k, &spec, cfg.max_overhead_us) > 0.0,
                    "case {case}: kept OScore-0 candidate");
        }
        assert!(out.pruned_frac >= 0.0 && out.pruned_frac <= 1.0);
    }
}

/// Property (ISSUE 2 satellite): every candidate the shrink keeps stays
/// inside the `GpuSpec` resource envelope at shard-launch granularity —
/// threads per block, blocks per SM, shared memory — and its shard
/// launches preserve the kernel's total work exactly.
#[test]
fn prop_shrink_candidates_respect_resource_limits_and_work() {
    let mut rng = Rng::new(0xE1A57);
    for case in 0..150 {
        let spec = if case % 2 == 0 {
            GpuSpec::rtx2060()
        } else {
            GpuSpec::xavier()
        };
        let k = rand_kernel(&mut rng);
        let profiles: Vec<CriticalProfile> = (0..3)
            .map(|_| CriticalProfile {
                n_blk_rt: 1 + rng.next_below(128) as u32,
                s_blk_rt: 1 + rng.next_below(1024) as u32,
            })
            .collect();
        let cfg = ShrinkConfig::default();
        let out = shrink::shrink_design_space(&k, &profiles, &spec, &cfg);
        for c in &out.kept {
            assert!(c.n_blocks >= 1, "case {case}: empty shard {c:?}");
            assert!(c.block_threads >= 1
                        && c.block_threads <= spec.max_threads_per_sm,
                    "case {case}: threads/block out of range {c:?}");
            // A shard spread over the SMs never needs more resident block
            // slots per SM than the hardware offers.
            assert!(c.n_blocks.div_ceil(spec.num_sms)
                        <= spec.max_blocks_per_sm,
                    "case {case}: blocks/SM overflow {c:?}");
            let launches = c.launches(&k);
            let blocks: u32 = launches.iter().map(|l| l.grid).sum();
            let flops: f64 = launches.iter().map(|l| l.flops).sum();
            let bytes: f64 = launches.iter().map(|l| l.bytes).sum();
            assert_eq!(blocks, k.grid, "case {case}: lost blocks {c:?}");
            assert!((flops - k.flops).abs() <= 1e-6 * k.flops.max(1.0),
                    "case {case}: flops drift {c:?}");
            assert!((bytes - k.bytes).abs() <= 1e-6 * k.bytes.max(1.0),
                    "case {case}: bytes drift {c:?}");
            for l in &launches {
                assert!(l.block_threads <= spec.max_threads_per_sm);
                assert!(l.smem_per_block <= k.smem_per_block,
                        "case {case}: smem grew {c:?}");
                assert!(l.smem_per_block <= spec.smem_per_sm);
                assert!(l.regs_per_thread * l.block_threads
                            <= spec.regs_per_sm,
                        "case {case}: register overflow {c:?}");
            }
        }
    }
}

/// Regression (ISSUE 2 satellite): the degenerate 1-block grid — the
/// slicing plan collapses to `[1]`, every candidate is a single shard,
/// and nothing panics or loses work.
#[test]
fn shrink_handles_degenerate_one_block_grid() {
    let spec = GpuSpec::rtx2060();
    let k = KernelDesc {
        name: "prop/one-block".into(),
        grid: 1,
        block_threads: 64,
        smem_per_block: 2048,
        regs_per_thread: 32,
        flops: 1e5,
        bytes: 4e4,
    };
    let crit = CriticalProfile { n_blk_rt: 10, s_blk_rt: 512 };
    let out = shrink::shrink_design_space(&k, &[crit], &spec,
                                          &ShrinkConfig::default());
    assert!(out.total >= 1);
    assert!(!out.kept.is_empty(),
            "a 1-block kernel always has a feasible identity-ish candidate");
    for c in &out.kept {
        assert_eq!(c.n_blocks, 1, "{c:?}");
        assert_eq!(c.num_shards(&k), 1, "{c:?}");
        let launches = c.launches(&k);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].grid, 1);
        assert!((launches[0].flops - k.flops).abs() < 1e-9);
        assert!((launches[0].bytes - k.bytes).abs() < 1e-9);
        assert!(launches[0].smem_per_block <= k.smem_per_block);
    }
}

/// Property: contention rates are positive and bounded by the SM peak for
/// arbitrary residencies; and for pure-compute workloads (no bandwidth
/// coupling) removing a block never slows the others. Full monotonicity
/// does NOT hold with memory in play — removing a co-resident lets a
/// compute block speed up, raising its bandwidth demand and slowing
/// memory-bound blocks elsewhere (real GPUs behave the same way).
#[test]
fn prop_rates_positive_bounded_monotone() {
    let mut rng = Rng::new(0xACE);
    let spec = GpuSpec::rtx2060();
    let params = ContentionParams::default();
    for case in 0..200 {
        let n = 1 + rng.next_below(64) as usize;
        let pure_compute = case % 2 == 0;
        let blocks: Vec<BlockWork> = (0..n)
            .map(|_| BlockWork {
                sm: rng.next_below(spec.num_sms as u64) as u32,
                threads: 1 + rng.next_below(512) as u32,
                flops: 1.0 + rng.next_f64() * 1e7,
                bytes: if pure_compute { 0.0 } else { rng.next_f64() * 1e6 },
                kernel: rng.next_below(6),
            })
            .collect();
        let rates = block_rates(&spec, &params, &blocks);
        for r in &rates {
            assert!(*r > 0.0, "case {case}: nonpositive rate");
            assert!(*r <= spec.flops_per_sm_us * 1.0001,
                    "case {case}: rate above SM peak");
        }
        // Monotonicity (compute-only): drop the last block; no survivor
        // slows down.
        if pure_compute && n > 1 {
            let fewer = &blocks[..n - 1];
            let rates2 = block_rates(&spec, &params, fewer);
            for i in 0..n - 1 {
                assert!(rates2[i] >= rates[i] - 1e-9,
                        "case {case}: removing a block slowed block {i}");
            }
        }
    }
}

/// Property (differential, §Perf change #4): for randomized residency
/// sets, the aggregate-indexed rate path must produce rates bitwise-close
/// (<= 1e-9 relative) to the retained full-recompute reference
/// implementation of `block_rates`.
#[test]
fn prop_indexed_rates_match_reference() {
    let mut rng = Rng::new(0x1D1);
    let params = ContentionParams::default();
    for case in 0..300 {
        let spec = if case % 3 == 0 { GpuSpec::tx2() } else { GpuSpec::rtx2060() };
        let n = 1 + rng.next_below(96) as usize;
        let blocks: Vec<BlockWork> = (0..n)
            .map(|_| BlockWork {
                sm: rng.next_below(spec.num_sms as u64) as u32,
                threads: 1 + rng.next_below(1024) as u32,
                flops: 1.0 + rng.next_f64() * 1e7,
                bytes: if rng.next_f64() < 0.3 {
                    0.0
                } else {
                    rng.next_f64() * 1e6
                },
                kernel: rng.next_below(8),
            })
            .collect();
        let reference = block_rates(&spec, &params, &blocks);
        let indexed = block_rates_indexed(&spec, &params, &blocks);
        assert_eq!(reference.len(), indexed.len());
        for (i, (a, b)) in reference.iter().zip(&indexed).enumerate() {
            let rel = (a - b).abs() / a.abs().max(1e-12);
            assert!(rel <= 1e-9,
                    "case {case} block {i}: reference {a} indexed {b} ({rel:e})");
        }
    }
}

/// Property (differential, §Perf change #4): driving seeded MDTB
/// workloads through the incremental engine and through the retained
/// full-recompute reference engine must produce identical completion
/// orders, equal event counts, and per-launch times within 1e-9 relative.
#[test]
fn prop_incremental_engine_matches_reference_trajectory() {
    for (wl_name, sched) in [("A", "multistream"), ("D", "miriam"),
                             ("C", "sequential"), ("B", "ib")] {
        let wl = mdtb::by_name(wl_name, 150_000.0).unwrap().build();
        let mut s1 = scheduler_for(sched, &wl).unwrap();
        let inc = driver::run_with(GpuSpec::rtx2060(), &wl, s1.as_mut(),
                                   RunOpts::default());
        let mut s2 = scheduler_for(sched, &wl).unwrap();
        let refr = driver::run_with(GpuSpec::rtx2060(), &wl, s2.as_mut(),
                                    RunOpts { reference_rates: true,
                                              trace: false });
        assert_eq!(inc.timeline.len(), refr.timeline.len(),
                   "{wl_name}/{sched}: launch count diverged");
        assert!(!inc.timeline.is_empty(), "{wl_name}/{sched}: empty run");
        for (a, b) in inc.timeline.iter().zip(&refr.timeline) {
            assert_eq!(a.tag, b.tag,
                       "{wl_name}/{sched}: completion order diverged");
            assert_eq!(a.name, b.name);
            let denom = b.end_us.abs().max(1.0);
            assert!((a.end_us - b.end_us).abs() / denom <= 1e-9,
                    "{wl_name}/{sched} tag {}: end {} vs {}", a.tag,
                    a.end_us, b.end_us);
            assert!((a.start_us - b.start_us).abs() / denom <= 1e-9,
                    "{wl_name}/{sched} tag {}: start {} vs {}", a.tag,
                    a.start_us, b.start_us);
        }
        assert_eq!(inc.events, refr.events,
                   "{wl_name}/{sched}: event count diverged");
        let occ = (inc.achieved_occupancy - refr.achieved_occupancy).abs();
        assert!(occ <= 1e-9, "{wl_name}/{sched}: occupancy diverged {occ}");
    }
}

/// Property (ISSUE 4 satellite): **critical requests are never shed**,
/// under any admission policy, scenario, or seed — checked end-to-end
/// through the online serving loop on generated random scenarios, with
/// the `offered == admitted + shed` balance held per tenant.
#[test]
fn prop_admission_never_sheds_critical_and_balances() {
    use miriam::server::online::{run_serve, ServeOpts};
    use miriam::workloads::scenario::ScenarioGen;

    let spec = GpuSpec::rtx2060();
    // Tight tunables so the policies actually bind on generated load.
    let admission = AdmissionConfig {
        bucket_capacity: 2.0,
        refill_hz: 25.0,
        max_queue_us: 3_000.0,
        ..AdmissionConfig::default()
    };
    let mut gen = ScenarioGen::new(0xAD31, 8_000.0);
    for case in 0..6 {
        let sc = gen.next_scenario();
        for policy in POLICIES {
            let opts = ServeOpts {
                policy,
                admission: admission.clone(),
                ..ServeOpts::default()
            };
            let r = run_serve(&spec, &sc, &opts)
                .unwrap_or_else(|e| panic!("case {case} {policy:?}: {e}"));
            assert_eq!(r.shed_critical(), 0,
                       "case {case} ({}) {policy:?}: critical shed",
                       sc.name);
            assert_eq!(r.offered(), r.admitted() + r.shed(),
                       "case {case} {policy:?}: unbalanced totals");
            for t in &r.tenants {
                assert_eq!(t.offered, t.admitted + t.shed,
                           "case {case} {policy:?} {}: unbalanced", t.label);
                assert!(t.served <= t.admitted,
                        "case {case} {policy:?} {}: served > admitted",
                        t.label);
                if t.criticality == Criticality::Critical {
                    assert_eq!(t.shed, 0);
                    assert_eq!(t.offered, t.admitted);
                }
            }
        }
    }
}

/// Property (ISSUE 4 satellite): token-bucket conservation — over any
/// arrival sequence in a window of length `T`, a tenant's admitted count
/// never exceeds `capacity + refill_hz * T` (initial fill plus refills);
/// and sheds resume being admits after a refill interval.
#[test]
fn prop_token_bucket_conservation() {
    let wl = mdtb::by_name("A", 1.0).unwrap().build(); // source 1 = normal
    let spec = GpuSpec::rtx2060();
    let params = ContentionParams::default();
    let mut rng = Rng::new(0x70CE);
    for case in 0..100 {
        let capacity = (rng.next_below(20) + 1) as f64;
        let refill_hz = 1.0 + rng.next_f64() * 500.0;
        let window_us = 10_000.0 + rng.next_f64() * 200_000.0;
        let cfg = AdmissionConfig {
            bucket_capacity: capacity,
            refill_hz,
            ..AdmissionConfig::default()
        };
        let mut ctrl = AdmissionController::new(
            AdmissionPolicy::TokenBucket, cfg, &wl, &spec, &params);
        // Random ascending arrival times across the window.
        let n = 1 + rng.next_below(400) as usize;
        let mut times: Vec<f64> =
            (0..n).map(|_| rng.next_f64() * window_us).collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut admitted = 0u64;
        for &t in &times {
            if ctrl.decide(1, t) == Decision::Admitted {
                admitted += 1;
            }
        }
        let bound = capacity + refill_hz * window_us / 1e6 + 1.0;
        assert!(admitted as f64 <= bound,
                "case {case}: admitted {admitted} > capacity {capacity} + \
                 refills ({bound})");
    }
}

/// Property (ISSUE 5 satellite): **router conservation** — across
/// generated scenarios × routers × fleets, every admitted request is
/// routed to exactly one device (`routed == admitted`, per-device splits
/// sum back, served never exceeds routed), and the fleet-wide
/// `offered == admitted + shed` balance holds per tenant.
#[test]
fn prop_fleet_router_conservation() {
    use miriam::fleet::{run_fleet, FleetOpts, FleetSpec, ROUTERS};
    use miriam::workloads::scenario::ScenarioGen;

    let fleets: Vec<FleetSpec> = [
        vec!["rtx2060"],
        vec!["xavier", "tx2"],
        vec!["rtx2060", "xavier", "tx2"],
    ]
    .iter()
    .map(|names| {
        let names: Vec<String> =
            names.iter().map(|s| s.to_string()).collect();
        FleetSpec::parse(&names, &["miriam".into()]).unwrap()
    })
    .collect();
    // Tight tunables so shedding actually happens on generated load.
    let admission = AdmissionConfig {
        bucket_capacity: 2.0,
        refill_hz: 25.0,
        max_queue_us: 3_000.0,
        ..AdmissionConfig::default()
    };
    let mut gen = ScenarioGen::new(0xF1EE7, 8_000.0);
    for case in 0..4 {
        let sc = gen.next_scenario();
        for fleet in &fleets {
            for router in ROUTERS {
                let opts = FleetOpts {
                    router: router.into(),
                    policy: AdmissionPolicy::TokenBucket,
                    admission: admission.clone(),
                    ..FleetOpts::default()
                };
                let r = run_fleet(fleet, &sc, &opts).unwrap_or_else(|e| {
                    panic!("case {case} {router} ({}): {e}", sc.name)
                });
                let ctx = format!("case {case} ({}) {router} x{} devices",
                                  sc.name, fleet.devices.len());
                assert_eq!(r.routed(), r.admitted(),
                           "{ctx}: admitted requests not routed exactly \
                            once");
                assert_eq!(r.offered(), r.admitted() + r.shed(), "{ctx}");
                let split: u64 = r
                    .devices
                    .iter()
                    .map(|d| d.routed_critical + d.routed_normal)
                    .sum();
                assert_eq!(split, r.routed(), "{ctx}: class split lost");
                let dev_served: u64 =
                    r.devices.iter().map(|d| d.served()).sum();
                assert_eq!(dev_served, r.served(), "{ctx}");
                for d in &r.devices {
                    assert!(d.served() <= d.routed, "{ctx}/{}",
                            d.desc.name);
                }
                for t in &r.tenants {
                    assert_eq!(t.offered, t.admitted + t.shed,
                               "{ctx} {}", t.label);
                    assert!(t.served <= t.admitted, "{ctx} {}", t.label);
                }
            }
        }
    }
}

/// Property (ISSUE 5 satellite): the `criticality-affinity` router never
/// places a critical request on a non-affine device — the pin target is
/// the fleet's fastest device, on every generated scenario and fleet
/// shape (including fleets where the fastest device is not index 0).
#[test]
fn prop_criticality_affinity_pins_critical_to_fastest() {
    use miriam::fleet::{run_fleet, FleetOpts, FleetSpec};
    use miriam::workloads::scenario::ScenarioGen;

    let shapes: [&[&str]; 3] = [
        &["rtx2060", "xavier", "tx2"],
        &["tx2", "rtx2060"],       // fastest is index 1
        &["xavier", "tx2", "xavier"],
    ];
    let mut gen = ScenarioGen::new(0xAFF1, 8_000.0);
    let mut any_critical_routed = false;
    for case in 0..4 {
        let sc = gen.next_scenario();
        for shape in shapes {
            let names: Vec<String> =
                shape.iter().map(|s| s.to_string()).collect();
            let fleet =
                FleetSpec::parse(&names, &["miriam".into()]).unwrap();
            let fastest = fleet.fastest();
            let opts = FleetOpts {
                router: "criticality-affinity".into(),
                ..FleetOpts::default()
            };
            let r = run_fleet(&fleet, &sc, &opts)
                .unwrap_or_else(|e| panic!("case {case}: {e}"));
            // Critical is never shed, so admitted == offered for the
            // class; every one of those must sit on the affine device.
            let crit_admitted: u64 = r
                .tenants
                .iter()
                .filter(|t| t.criticality == Criticality::Critical)
                .map(|t| t.admitted)
                .sum();
            any_critical_routed |= crit_admitted > 0;
            for (i, d) in r.devices.iter().enumerate() {
                if i != fastest {
                    assert_eq!(d.routed_critical, 0,
                               "case {case} ({}) fleet {shape:?}: critical \
                                request on non-affine device {}",
                               sc.name, d.desc.name);
                    assert!(d.critical_latencies_us.is_empty(),
                            "case {case}: critical served off-affinity");
                }
            }
            assert_eq!(r.devices[fastest].routed_critical, crit_admitted,
                       "case {case} ({}): affine device did not absorb the \
                        whole critical class", sc.name);
        }
    }
    // The property must not pass vacuously: some generated scenario has
    // to have offered critical work within the window (tenant 0 of every
    // generated scenario is critical, and uniform/ramp arrivals start at
    // t = 0, so across 4 scenarios this always holds).
    assert!(any_critical_routed, "no critical request in any case");
}

/// Property (ISSUE 5 satellite): **critical is never shed fleet-wide**,
/// under any admission policy × router × generated scenario — the
/// ISSUE 4 invariant survives the extra routing layer.
#[test]
fn prop_fleet_critical_never_shed_across_policies_and_routers() {
    use miriam::fleet::{run_fleet, FleetOpts, FleetSpec, ROUTERS};
    use miriam::workloads::scenario::ScenarioGen;

    let fleet = FleetSpec::parse(
        &["xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .unwrap();
    let admission = AdmissionConfig {
        bucket_capacity: 2.0,
        refill_hz: 25.0,
        max_queue_us: 3_000.0,
        ..AdmissionConfig::default()
    };
    let mut gen = ScenarioGen::new(0xF1CA, 8_000.0);
    for case in 0..4 {
        let sc = gen.next_scenario();
        for policy in POLICIES {
            for router in ROUTERS {
                let opts = FleetOpts {
                    router: router.into(),
                    policy,
                    admission: admission.clone(),
                    ..FleetOpts::default()
                };
                let r = run_fleet(&fleet, &sc, &opts).unwrap_or_else(|e| {
                    panic!("case {case} {policy:?}/{router}: {e}")
                });
                assert_eq!(r.shed_critical(), 0,
                           "case {case} ({}) {policy:?}/{router}: critical \
                            shed fleet-wide",
                           sc.name);
                assert_eq!(r.offered(), r.admitted() + r.shed(),
                           "case {case} {policy:?}/{router}");
                for t in &r.tenants {
                    if t.criticality == Criticality::Critical {
                        assert_eq!(t.shed, 0,
                                   "case {case} {policy:?}/{router} {}",
                                   t.label);
                        assert_eq!(t.offered, t.admitted);
                    }
                }
            }
        }
    }
}

/// Property (ISSUE 6): **conservation survives chaos** — under every
/// admission policy × router × storm preset on generated scenarios,
/// `offered == admitted + shed` and `admitted == served + lost`. Every
/// storm preset heals all of its outages, so nothing may be lost, every
/// admitted request is placed exactly once (`routed == admitted`),
/// per-device served counts sum to the fleet total (a request requeued
/// off a dead device is never served twice), critical is never shed,
/// and the requeue ledgers agree (device `requeued_in` sums to tenant
/// `requeues`).
#[test]
fn prop_chaos_conservation_and_critical_protection() {
    use miriam::fleet::chaos::storm;
    use miriam::fleet::{run_fleet, FleetOpts, FleetSpec, ROUTERS, STORMS};
    use miriam::workloads::scenario::ScenarioGen;

    let fleet = FleetSpec::parse(
        &["rtx2060".into(), "xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .unwrap();
    let admission = AdmissionConfig {
        bucket_capacity: 2.0,
        refill_hz: 25.0,
        max_queue_us: 3_000.0,
        ..AdmissionConfig::default()
    };
    let mut gen = ScenarioGen::new(0xC405, 8_000.0);
    let mut any_requeued = false;
    for case in 0..2 {
        let sc = gen.next_scenario();
        for policy in POLICIES {
            for router in ROUTERS {
                for storm_name in STORMS {
                    let opts = FleetOpts {
                        router: router.into(),
                        policy,
                        admission: admission.clone(),
                        chaos: storm(storm_name, fleet.devices.len(),
                                     sc.duration_us)
                            .expect("preset exists"),
                        ..FleetOpts::default()
                    };
                    let r =
                        run_fleet(&fleet, &sc, &opts).unwrap_or_else(|e| {
                            panic!("case {case} {policy:?}/{router}/\
                                    {storm_name}: {e}")
                        });
                    let ctx = format!(
                        "case {case} ({}) {policy:?}/{router}/{storm_name}",
                        sc.name);
                    assert_eq!(r.offered(), r.admitted() + r.shed(),
                               "{ctx}");
                    assert_eq!(r.admitted(), r.served() + r.lost(), "{ctx}");
                    assert_eq!(r.lost(), 0,
                               "{ctx}: every preset heals — nothing may \
                                be lost");
                    assert_eq!(r.routed(), r.admitted(),
                               "{ctx}: admitted requests not placed \
                                exactly once");
                    assert_eq!(r.shed_critical(), 0,
                               "{ctx}: critical shed under chaos");
                    let dev_requeued: u64 =
                        r.devices.iter().map(|d| d.requeued_in).sum();
                    assert_eq!(dev_requeued, r.requeues(),
                               "{ctx}: requeue ledgers disagree");
                    let dev_served: u64 =
                        r.devices.iter().map(|d| d.served()).sum();
                    assert_eq!(dev_served, r.served(),
                               "{ctx}: a request was served twice or \
                                dropped");
                    for t in &r.tenants {
                        assert!(t.served + t.lost <= t.admitted,
                                "{ctx} {}: tenant over-served", t.label);
                    }
                    any_requeued |= r.requeues() > 0;
                }
            }
        }
    }
    // The suite must not pass vacuously: the outage presets have to have
    // caught some request in flight (closed-loop tenants keep every
    // generated scenario busy, and rolling-outage kills each device in
    // turn, so this holds deterministically).
    assert!(any_requeued,
            "no storm ever forced a requeue — the chaos axis is vacuous");
}

/// Property (ISSUE 8): **extended conservation survives fault
/// injection** — under every fault-storm preset × router on generated
/// scenarios, `offered == admitted + shed` and
/// `admitted == served + lost + cancelled`; nothing is lost while every
/// device stays live; critical requests are never shed and **never
/// cancelled**; every admitted request is placed exactly once; hedge
/// winners are counted at most once per hedged request
/// (`hedge_wins <= hedges`); and per-device breaker trips sum to the
/// fleet ledger.
#[test]
fn prop_faults_conservation_and_critical_protection() {
    use miriam::fleet::faults::storm;
    use miriam::fleet::{run_fleet, FleetOpts, FleetSpec, FAULT_STORMS,
                        ROUTERS};
    use miriam::workloads::scenario::ScenarioGen;

    let fleet = FleetSpec::parse(
        &["rtx2060".into(), "xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .unwrap();
    let admission = AdmissionConfig {
        bucket_capacity: 2.0,
        refill_hz: 25.0,
        max_queue_us: 3_000.0,
        ..AdmissionConfig::default()
    };
    let mut gen = ScenarioGen::new(0xFA17, 8_000.0);
    let mut any_recovered = false;
    for case in 0..2 {
        let sc = gen.next_scenario();
        for router in ROUTERS {
            for storm_name in FAULT_STORMS {
                let opts = FleetOpts {
                    router: router.into(),
                    policy: AdmissionPolicy::TokenBucket,
                    admission: admission.clone(),
                    faults: Some(storm(storm_name).expect("preset exists")),
                    ..FleetOpts::default()
                };
                let r = run_fleet(&fleet, &sc, &opts).unwrap_or_else(|e| {
                    panic!("case {case} {router}/{storm_name}: {e}")
                });
                let ctx = format!("case {case} ({}) {router}/{storm_name}",
                                  sc.name);
                assert_eq!(r.offered(), r.admitted() + r.shed(), "{ctx}");
                assert_eq!(r.admitted(),
                           r.served() + r.lost() + r.cancelled(),
                           "{ctx}: extended conservation broke");
                assert_eq!(r.lost(), 0,
                           "{ctx}: lost with every device live");
                assert_eq!(r.shed_critical(), 0,
                           "{ctx}: critical shed under faults");
                assert_eq!(r.critical_cancelled(), 0,
                           "{ctx}: a critical request was cancelled");
                assert_eq!(r.routed(), r.admitted(),
                           "{ctx}: admitted requests not placed exactly \
                            once");
                assert!(r.hedge_wins() <= r.hedges(),
                        "{ctx}: more hedge wins than hedges");
                let dev_served: u64 =
                    r.devices.iter().map(|d| d.served()).sum();
                assert_eq!(dev_served, r.served(),
                           "{ctx}: a request was served twice or dropped");
                let dev_trips: u64 =
                    r.devices.iter().map(|d| d.breaker_trips).sum();
                assert_eq!(dev_trips, r.breaker_trips(),
                           "{ctx}: breaker ledgers disagree");
                for t in &r.tenants {
                    assert_eq!(t.offered, t.admitted + t.shed,
                               "{ctx} {}", t.label);
                    assert_eq!(t.admitted,
                               t.served + t.lost + t.cancelled,
                               "{ctx} {}: tenant conservation broke",
                               t.label);
                    if t.criticality == Criticality::Critical {
                        assert_eq!(t.cancelled, 0, "{ctx} {}", t.label);
                    }
                }
                any_recovered |= r.retries() > 0 || r.hedges() > 0;
            }
        }
    }
    // Non-vacuity: across the preset sweep some launch must actually
    // have failed or straggled into a recovery action (flaky-launches
    // alone injects a 5% launch-failure rate over hundreds of
    // launches, so this holds deterministically).
    assert!(any_recovered,
            "no fault ever forced a retry or hedge — the fault axis is \
             vacuous");
}

/// Property (ISSUE 6 satellite): killing the **fastest** device (the
/// criticality-affinity pin target, index 1 here — fleets where the
/// fastest is not device 0 are the audit case) with a scripted heal
/// loses nothing: the router re-pins critical work to the fastest
/// survivor and restores the pin on heal. The script is written in the
/// CLI `--chaos` grammar so the parser sits in the loop too.
#[test]
fn prop_affinity_survives_the_fastest_device_dying() {
    use miriam::fleet::{run_fleet, ChaosSpec, FleetOpts, FleetSpec};
    use miriam::workloads::scenario;

    let fleet = FleetSpec::parse(
        &["tx2".into(), "rtx2060".into()],
        &["miriam".into()],
    )
    .unwrap();
    assert_eq!(fleet.fastest(), 1, "rtx2060 must out-rate tx2");
    let sc = scenario::by_name("duo-burst", 8_000.0).unwrap();
    let chaos = ChaosSpec::parse("down:d1@2ms+3ms").expect("grammar");
    assert_eq!(chaos.events.len(), 1);
    let opts = FleetOpts {
        router: "criticality-affinity".into(),
        chaos,
        ..FleetOpts::default()
    };
    let r = run_fleet(&fleet, &sc, &opts).expect("run");
    // The placement assertion inside the fleet loop already guarantees
    // no request was ever placed on the dead device; here we pin the
    // outcome ledger.
    assert!(r.resilience, "chaos run must carry the resilience columns");
    assert_eq!(r.chaos_events, 1);
    assert!(r.devices[1].downtime_us > 0.0, "the kill never landed");
    assert_eq!(r.lost(), 0, "the pin target healed — nothing may be lost");
    assert_eq!(r.served(), r.admitted());
    assert_eq!(r.offered(), r.admitted() + r.shed());
    assert!(r.recovery_us > 0.0 || r.requeues() == 0,
            "an outage with open requests must record a recovery time");
}

/// Property: the engine conserves work — total simulated busy time on a
/// single-kernel workload equals work / allocated rate within tolerance,
/// and every submitted launch completes exactly once.
#[test]
fn prop_engine_completes_everything_once() {
    let mut rng = Rng::new(0xE46);
    for case in 0..60 {
        let spec = GpuSpec::tx2(); // small part -> more contention paths
        let mut eng = Engine::new(spec);
        let s0 = eng.add_stream(5);
        let s1 = eng.add_stream(0);
        let mut tags = Vec::new();
        let n = 2 + rng.next_below(12);
        for i in 0..n {
            let cfg = LaunchConfig {
                name: format!("k{i}"),
                grid: 1 + rng.next_below(16) as u32,
                block_threads: 32 + rng.next_below(512) as u32,
                smem_per_block: 0,
                regs_per_thread: 32,
                flops: 1e5 + rng.next_f64() * 1e7,
                bytes: rng.next_f64() * 1e6,
            };
            let stream = if rng.next_f64() < 0.5 { s0 } else { s1 };
            let crit = if stream == s0 {
                Criticality::Critical
            } else {
                Criticality::Normal
            };
            tags.push(eng.submit(stream, cfg, crit));
        }
        let done = eng.run_to_idle();
        assert_eq!(done.len(), tags.len(), "case {case}: lost launches");
        let mut seen: Vec<u64> = done.iter().map(|c| c.tag).collect();
        seen.sort_unstable();
        let mut want = tags.clone();
        want.sort_unstable();
        assert_eq!(seen, want, "case {case}: tag mismatch");
        assert!(eng.idle());
    }
}

/// Property (ISSUE 9): **partition disjointness** — under a strict
/// isolation split, no thread block ever lands on an SM outside its
/// class's partition, on any family scenario. The critical lane is
/// stream 0 (Isolation::init adds it first), and its partition is SMs
/// `[0, crit_sms)`; the normal lane is stream 1 on `[crit_sms, num_sms)`.
#[test]
fn prop_isolation_strict_partitions_are_disjoint() {
    use miriam::coordinator::IsolationConfig;
    use miriam::gpu::trace::TraceEventKind;
    use miriam::workloads::scenario;
    use std::collections::HashMap;

    let spec = GpuSpec::rtx2060();
    let crit_sms = IsolationConfig::parse("70/30")
        .unwrap()
        .partition(spec.num_sms)
        .unwrap();
    for sc in scenario::family(30_000.0) {
        let wl = sc.build();
        let mut s = scheduler_for("isolation:70/30", &wl).unwrap();
        let mut st = driver::run_with(
            spec.clone(), &wl, s.as_mut(),
            RunOpts { reference_rates: false, trace: true });
        let trace = st.trace.take().expect("trace was requested");
        let mut stream_of: HashMap<u64, u32> = HashMap::new();
        let mut crit_places = 0u64;
        let mut norm_places = 0u64;
        for ev in &trace.events {
            match ev.kind {
                TraceEventKind::Submit => {
                    stream_of.insert(ev.tag, ev.loc);
                }
                TraceEventKind::BlockPlace => {
                    let stream = stream_of[&ev.tag];
                    if stream == 0 {
                        crit_places += 1;
                        assert!(ev.loc < crit_sms,
                                "{}: critical block on SM {} outside \
                                 [0, {crit_sms})", sc.name, ev.loc);
                    } else {
                        norm_places += 1;
                        assert!(ev.loc >= crit_sms && ev.loc < spec.num_sms,
                                "{}: normal block on SM {} outside \
                                 [{crit_sms}, {})", sc.name, ev.loc,
                                spec.num_sms);
                    }
                }
                _ => {}
            }
        }
        // Non-vacuity: both partitions actually placed work.
        assert!(crit_places > 0, "{}: no critical placements", sc.name);
        assert!(norm_places > 0, "{}: no normal placements", sc.name);
    }
}

/// Property (ISSUE 9): **spillover conservation** — a lane places blocks
/// in the foreign partition only while the owning class has zero
/// submitted-but-incomplete launches. Because the loan is revoked in
/// `on_request` *before* the lender submits, every foreign placement
/// event precedes the lender's next Submit in the trace — replaying the
/// event stream with per-stream outstanding counters proves lent SMs are
/// reclaimed before the lender's next activation (resident foreign
/// blocks may still drain, but no *new* foreign block lands).
#[test]
fn prop_isolation_spillover_reclaims_before_the_lender_runs() {
    use miriam::coordinator::IsolationConfig;
    use miriam::gpu::trace::TraceEventKind;
    use miriam::workloads::scenario;
    use std::collections::HashMap;

    let spec = GpuSpec::rtx2060();
    let crit_sms = IsolationConfig::parse("70/30+spill")
        .unwrap()
        .partition(spec.num_sms)
        .unwrap();
    let mut any_foreign = false;
    for sc in scenario::family(30_000.0) {
        let wl = sc.build();
        let mut s = scheduler_for("isolation:70/30+spill", &wl).unwrap();
        let mut st = driver::run_with(
            spec.clone(), &wl, s.as_mut(),
            RunOpts { reference_rates: false, trace: true });
        let trace = st.trace.take().expect("trace was requested");
        let mut stream_of: HashMap<u64, u32> = HashMap::new();
        // Submitted-but-incomplete launches per lane (streams 0 and 1).
        let mut outstanding = [0i64; 2];
        for ev in &trace.events {
            match ev.kind {
                TraceEventKind::Submit => {
                    stream_of.insert(ev.tag, ev.loc);
                    outstanding[ev.loc as usize] += 1;
                }
                TraceEventKind::Complete => {
                    outstanding[ev.loc as usize] -= 1;
                    assert!(outstanding[ev.loc as usize] >= 0,
                            "{}: completion without submit", sc.name);
                }
                TraceEventKind::BlockPlace => {
                    let stream = stream_of[&ev.tag] as usize;
                    let foreign = if stream == 0 {
                        ev.loc >= crit_sms
                    } else {
                        ev.loc < crit_sms
                    };
                    if foreign {
                        any_foreign = true;
                        assert_eq!(
                            outstanding[1 - stream], 0,
                            "{} t={}: stream {stream} borrowed SM {} while \
                             the owning lane still had work in flight",
                            sc.name, ev.t_us, ev.loc);
                    }
                }
                _ => {}
            }
        }
    }
    // Non-vacuity: across the family some idle window must actually have
    // been lent out, or the property tested nothing.
    assert!(any_foreign, "spillover never engaged on any scenario");
}

/// Property (ISSUE 9): with the whole device reserved for the critical
/// class (`isolation:100/0`, no spill), per-request critical latency is
/// never worse than Sequential's on the same scenario and seed. Both
/// serve criticals FIFO, solo on the device, and open-loop critical
/// arrivals are pre-generated from the workload seed (identical across
/// schedulers) — Sequential just adds non-preemptible normal residuals
/// in front of critical starts, so dominance holds per matched request.
#[test]
fn prop_isolation_full_reserve_critical_dominates_sequential() {
    use miriam::workloads::scenario;

    for sc in scenario::family(30_000.0) {
        let wl = sc.build();
        let mut iso = scheduler_for("isolation:100/0", &wl).unwrap();
        let a = driver::run(GpuSpec::rtx2060(), &wl, iso.as_mut());
        let mut seq = scheduler_for("sequential", &wl).unwrap();
        let b = driver::run(GpuSpec::rtx2060(), &wl, seq.as_mut());
        assert_eq!(a.critical_latencies_us.len(),
                   b.critical_latencies_us.len(),
                   "{}: critical completion counts diverged", sc.name);
        assert!(!a.critical_latencies_us.is_empty(),
                "{}: no critical completions", sc.name);
        // Criticals complete in arrival order under both policies, so
        // index i is the same request in both runs.
        for (i, (ia, sb)) in a
            .critical_latencies_us
            .iter()
            .zip(&b.critical_latencies_us)
            .enumerate()
        {
            assert!(ia <= &(sb + 1e-6),
                    "{} request {i}: isolation {ia} > sequential {sb}",
                    sc.name);
        }
    }
}

/// Differential (ISSUE 9): on critical-only traffic, `isolation:100/0`
/// (no spill) IS the Sequential baseline — same FIFO, whole device, one
/// request in flight — and its full-device placement mask must also be
/// bitwise-equivalent to Sequential's unmasked heap placement. Timelines
/// must therefore match exactly, not approximately.
#[test]
fn diff_isolation_full_reserve_equals_sequential_on_critical_only() {
    use miriam::workloads::scenario;

    for mut sc in scenario::family(30_000.0) {
        for src in &mut sc.sources {
            src.criticality = Criticality::Critical;
        }
        let wl = sc.build();
        let mut iso = scheduler_for("isolation:100/0", &wl).unwrap();
        let a = driver::run(GpuSpec::rtx2060(), &wl, iso.as_mut());
        let mut seq = scheduler_for("sequential", &wl).unwrap();
        let b = driver::run(GpuSpec::rtx2060(), &wl, seq.as_mut());
        assert_eq!(a.timeline.len(), b.timeline.len(),
                   "{}: launch counts diverged", sc.name);
        assert!(!a.timeline.is_empty(), "{}: empty run", sc.name);
        for (x, y) in a.timeline.iter().zip(&b.timeline) {
            assert_eq!(x.tag, y.tag, "{}: submission order diverged",
                       sc.name);
            assert_eq!(x.name, y.name, "{}", sc.name);
            assert!(x.start_us == y.start_us,
                    "{} tag {}: start {} vs {}", sc.name, x.tag,
                    x.start_us, y.start_us);
            assert!(x.end_us == y.end_us,
                    "{} tag {}: end {} vs {}", sc.name, x.tag, x.end_us,
                    y.end_us);
        }
        assert_eq!(a.completed_critical(), b.completed_critical(),
                   "{}", sc.name);
    }
}

/// Properties (ISSUE 10): the generation serving loop's ledger
/// invariants hold across scenarios × schedulers × admission policies:
///
/// * token conservation — `sum(tokens emitted) == sum(drawn output
///   lengths)` over completed requests (and every admitted request
///   completes, so `admitted == served`);
/// * the KV budget is never exceeded at any event (`kv_peak <= budget`
///   — the peak is updated at every reservation, i.e. at every point
///   the ledger changes);
/// * criticals are never evicted;
/// * eviction→recompute re-issues exactly the evicted prefix
///   (`recompute_tokens == evicted_prefix_tokens`);
/// * TTFT ≤ end-to-end latency per request (`ttft_violations == 0`,
///   plus order-statistic dominance of the per-tenant samples);
/// * admission accounting balances (`offered == admitted + shed`, no
///   critical ever shed).
#[test]
fn prop_generation_ledger_invariants_hold_everywhere() {
    use miriam::server::gen::{run_gen, GenOpts};
    use miriam::workloads::generation;

    for sc in generation::gen_family(30_000.0) {
        for sched in ["miriam", "sequential"] {
            for &policy in &POLICIES {
                let opts = GenOpts {
                    scheduler: sched.into(),
                    policy,
                    ..GenOpts::default()
                };
                let r = run_gen(&GpuSpec::rtx2060(), &sc, &opts)
                    .unwrap_or_else(|e| {
                        panic!("{}/{sched}/{}: {e}", sc.name, policy.name())
                    });
                let case =
                    format!("{}/{sched}/{}", sc.name, policy.name());
                assert!(r.offered() > 0, "{case}: no arrivals");
                assert_eq!(r.offered(), r.admitted() + r.shed(), "{case}");
                assert_eq!(r.shed_critical(), 0, "{case}");
                assert_eq!(r.admitted(), r.served(),
                           "{case}: admitted requests must drain");
                assert_eq!(r.tokens, r.drawn_tokens,
                           "{case}: token conservation");
                assert!(r.kv_peak_bytes <= r.kv_budget_bytes + 1e-6,
                        "{case}: KV peak {} exceeded budget {}",
                        r.kv_peak_bytes, r.kv_budget_bytes);
                assert_eq!(r.critical_evictions(), 0,
                           "{case}: a critical was evicted");
                assert_eq!(r.recompute_tokens, r.evicted_prefix_tokens,
                           "{case}: recompute must re-issue exactly the \
                            evicted prefix");
                assert_eq!(r.ttft_violations, 0,
                           "{case}: TTFT exceeded end-to-end latency");
                for t in &r.tenants {
                    assert_eq!(t.offered, t.admitted + t.shed,
                               "{case}/{}", t.label);
                    assert_eq!(t.served, t.admitted, "{case}/{}", t.label);
                    assert_eq!(t.ttft_us.len() as u64, t.served,
                               "{case}/{}", t.label);
                    // Per request ttft <= latency, so the i-th order
                    // statistics dominate pairwise.
                    let mut ttft = t.ttft_us.clone();
                    let mut lat = t.latencies_us.clone();
                    ttft.sort_by(f64::total_cmp);
                    lat.sort_by(f64::total_cmp);
                    for (i, (a, b)) in ttft.iter().zip(&lat).enumerate() {
                        assert!(a <= &(b + 1e-9),
                                "{case}/{}: sorted TTFT[{i}]={a} > \
                                 latency[{i}]={b}", t.label);
                    }
                    if t.criticality == Criticality::Critical {
                        assert_eq!(t.evictions, 0, "{case}/{}", t.label);
                        assert_eq!(t.preempted_steps, 0,
                                   "{case}/{}", t.label);
                    }
                }
            }
        }
    }
}

/// Non-vacuity for the eviction properties above: gen-pressure is sized
/// so its KV budget actually binds — the run must evict, preempt or
/// park-and-recompute real work, and the prefix equality must hold on
/// non-zero counters.
#[test]
fn prop_generation_pressure_eviction_path_is_exercised() {
    use miriam::server::gen::{run_gen, GenOpts};
    use miriam::workloads::generation;

    let sc = generation::gen_by_name("gen-pressure", 40_000.0).unwrap();
    let r = run_gen(&GpuSpec::rtx2060(), &sc, &GenOpts::default()).unwrap();
    assert!(r.evictions > 0,
            "gen-pressure never evicted — the property suite above is \
             vacuous on the eviction path");
    assert!(r.evicted_prefix_tokens > 0);
    assert_eq!(r.recompute_tokens, r.evicted_prefix_tokens);
    assert_eq!(r.critical_evictions(), 0);
    assert_eq!(r.tokens, r.drawn_tokens);
    // Evictions hit only best-effort tenants, and at least one of them
    // recorded the hit in its per-tenant counters.
    assert!(r.tenants
                .iter()
                .filter(|t| t.criticality == Criticality::Normal)
                .any(|t| t.evictions > 0));
}

/// Exact Hyndman–Fan type 7 quantile, replicated locally (the crate's
/// `sorted_quantile` is `pub(crate)`): sort by `total_cmp`, then linear
/// interpolation at `q * (n - 1)`.
fn exact_quantile(sample: &[f64], q: f64) -> f64 {
    let mut v = sample.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    let frac = pos - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Property (ISSUE 7): the constant-memory P² sketch behind
/// `StreamingSummary` tracks the exact quantiles of heavy-tailed Pareto
/// samples — the worst realistic shape for a five-marker sketch — within
/// the error contract documented in `coordinator/stats.rs`: p50 relative
/// error ≤ 5%, p99 ≤ 20%. Every case is seeded, so these are exact
/// regression bounds, not statistical hopes.
#[test]
fn prop_streaming_sketch_tracks_exact_heavy_tailed_quantiles() {
    use miriam::coordinator::stats::StreamingSummary;
    for &seed in &[0x5CA1Eu64, 1, 42, 7, 0xBEEF, 1234] {
        for &alpha in &[1.5f64, 2.5] {
            for &n in &[2000usize, 50_000] {
                let mut rng = Rng::new(seed);
                let mut summary = StreamingSummary::new();
                let mut sample = Vec::with_capacity(n);
                for _ in 0..n {
                    // Pareto(alpha) via inverse CDF; `1 - next_f64()`
                    // keeps the argument in (0, 1] so powf never sees 0.
                    let u = 1.0 - rng.next_f64();
                    let x = u.powf(-1.0 / alpha);
                    summary.record(x);
                    sample.push(x);
                }
                assert_eq!(summary.count(), n as u64);
                let case = format!("seed={seed:#x} alpha={alpha} n={n}");
                for (q, est, bound) in [
                    (0.50, summary.p50(), 0.05),
                    (0.99, summary.p99(), 0.20),
                ] {
                    let exact = exact_quantile(&sample, q);
                    let rel = (est - exact).abs() / exact;
                    assert!(rel <= bound,
                            "{case}: q={q} sketch={est} exact={exact} \
                             rel_err={rel:.4} > {bound}");
                }
                let (min, max) = sample.iter().fold(
                    (f64::INFINITY, f64::NEG_INFINITY),
                    |(lo, hi), &x| (lo.min(x), hi.max(x)),
                );
                assert!(summary.min() == min && summary.max() == max,
                        "{case}: min/max drifted");
                assert!(summary.p50() >= min && summary.p99() <= max,
                        "{case}: estimates escaped the sample range");
            }
        }
    }
}
