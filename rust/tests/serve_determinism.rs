//! Determinism and SLO contracts of the online serving pipeline
//! (ISSUE 4 tentpole):
//!
//! * repeat runs at the same seed produce **byte-identical**
//!   `BENCH_serve.json` documents and SLO counters (the report carries no
//!   host timing by design);
//! * the `none` policy reproduces the batch driver's trajectory exactly
//!   (the serving loop adds accounting, not behavior);
//! * under `deadline-feasible`, critical-task p99 latency is no worse
//!   than the no-admission baseline (admission only trims best-effort
//!   load) and the policies actually bind (something is shed under
//!   pressure) while `offered == admitted + shed` stays balanced.

use miriam::coordinator::admission::{
    AdmissionConfig, AdmissionPolicy, POLICIES,
};
use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::scheduler_for;
use miriam::gpu::spec::GpuSpec;
use miriam::server::online::{run_serve, run_serve_grid, ServeOpts};
use miriam::workloads::scenario;

const DUR_US: f64 = 40_000.0;

fn opts(policy: AdmissionPolicy) -> ServeOpts {
    ServeOpts { policy, ..ServeOpts::default() }
}

#[test]
fn repeat_runs_are_byte_identical() {
    let scenarios: Vec<_> = scenario::family(DUR_US)
        .into_iter()
        .filter(|s| s.name == "duo-burst" || s.name == "five-storm")
        .collect();
    assert_eq!(scenarios.len(), 2);
    let a = run_serve_grid(&GpuSpec::rtx2060(), &scenarios, &POLICIES,
                           &ServeOpts::default())
        .expect("grid a");
    let b = run_serve_grid(&GpuSpec::rtx2060(), &scenarios, &POLICIES,
                           &ServeOpts::default())
        .expect("grid b");
    assert_eq!(a.to_json(), b.to_json(),
               "BENCH_serve.json differs across repeat runs");
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.offered(), y.offered());
        assert_eq!(x.admitted(), y.admitted());
        assert_eq!(x.shed(), y.shed());
        assert_eq!(x.served(), y.served());
        assert_eq!(x.events, y.events);
        assert_eq!(x.crit_p99_us().to_bits(), y.crit_p99_us().to_bits());
    }
}

#[test]
fn open_policy_reproduces_the_batch_driver() {
    // With nothing shed, the serving loop must walk the exact trajectory
    // of driver::run_with on the same workload: same event count, same
    // completion totals, same critical latency distribution to the bit.
    let sc = scenario::by_name("duo-burst", DUR_US).unwrap();
    let serve = run_serve(&GpuSpec::rtx2060(), &sc,
                          &opts(AdmissionPolicy::Open))
        .expect("serve");
    assert_eq!(serve.shed(), 0);

    let wl = sc.build();
    let mut s = scheduler_for("miriam", &wl).unwrap();
    let direct = driver::run_with(GpuSpec::rtx2060(), &wl, s.as_mut(),
                                  RunOpts::default());
    assert_eq!(serve.events, direct.events, "event counts diverged");
    assert_eq!(serve.served() as usize,
               direct.completed_critical() + direct.completed_normal());
    assert_eq!(serve.crit_p99_us().to_bits(),
               direct.critical_latency_p99_us().to_bits(),
               "critical p99 diverged from the batch driver");
    assert!((serve.span_us - direct.span_us).abs() < 1e-9);
    assert_eq!(serve.deadline_misses_critical(),
               direct.deadline_misses_critical);
}

#[test]
fn deadline_feasible_keeps_critical_p99_no_worse_than_baseline() {
    // The acceptance comparison on the heavier half of the family: the
    // admission controller only ever removes best-effort load, so the
    // critical class cannot get slower (tolerance covers FP noise from a
    // different padding interleaving). duo-burst's critical tenant is
    // pure-MMPP, so its completions are seed-dependent — its comparison
    // is conditional; five-storm and six-saturate carry uniform critical
    // arrivals (one at t=0 guaranteed), so at least two scenarios always
    // compare.
    let mut compared = 0;
    for name in ["duo-burst", "five-storm", "six-saturate"] {
        let sc = scenario::by_name(name, DUR_US).unwrap();
        let base = run_serve(&GpuSpec::rtx2060(), &sc,
                             &opts(AdmissionPolicy::Open))
            .expect("baseline");
        let feas = run_serve(&GpuSpec::rtx2060(), &sc,
                             &opts(AdmissionPolicy::DeadlineFeasible))
            .expect("deadline-feasible");
        assert_eq!(feas.shed_critical(), 0, "{name}: critical was shed");
        // Admission never drops critical work, and both runs drain, so
        // the two runs serve exactly the same critical request set.
        for (b, f) in base.tenants.iter().zip(&feas.tenants) {
            if b.criticality
                == miriam::gpu::kernel::Criticality::Critical
            {
                assert_eq!(b.served, f.served,
                           "{name}/{}: critical served diverged", b.label);
            }
        }
        let p_base = base.crit_p99_us();
        let p_feas = feas.crit_p99_us();
        if !(p_base.is_finite() && p_feas.is_finite()) {
            continue; // no critical completions at this seed/window
        }
        compared += 1;
        assert!(p_feas <= p_base * 1.10 + 5.0,
                "{name}: deadline-feasible critical p99 {p_feas} worse \
                 than baseline {p_base}");
    }
    assert!(compared >= 2,
            "expected at least the uniform-critical scenarios to compare");
}

#[test]
fn policies_bind_under_pressure_and_accounting_balances() {
    // five-storm offers hundreds of best-effort requests in 40ms; a
    // 40 Hz refill bucket must shed, and a tight burst guard must shed.
    let sc = scenario::by_name("five-storm", DUR_US).unwrap();
    let tb = run_serve(&GpuSpec::rtx2060(), &sc,
                       &opts(AdmissionPolicy::TokenBucket))
        .expect("token bucket");
    assert!(tb.shed() > 0, "token bucket never bound");
    assert_eq!(tb.shed_critical(), 0);
    assert_eq!(tb.offered(), tb.admitted() + tb.shed());

    let tight = ServeOpts {
        policy: AdmissionPolicy::DeadlineFeasible,
        admission: AdmissionConfig {
            max_queue_us: 500.0,
            ..AdmissionConfig::default()
        },
        ..ServeOpts::default()
    };
    let df = run_serve(&GpuSpec::rtx2060(), &sc, &tight).expect("feasible");
    assert!(df.shed() > 0, "burst guard never bound");
    assert_eq!(df.shed_critical(), 0);
    assert_eq!(df.offered(), df.admitted() + df.shed());
    for t in &df.tenants {
        assert_eq!(t.offered, t.admitted + t.shed, "{}", t.label);
        assert!(t.served <= t.admitted, "{}", t.label);
    }
}

#[test]
fn seed_changes_the_document_but_not_its_shape() {
    let sc = scenario::by_name("duo-burst", DUR_US).unwrap();
    let a = run_serve(&GpuSpec::rtx2060(), &sc,
                      &ServeOpts { seed: Some(21), ..ServeOpts::default() })
        .expect("seed 21");
    let b = run_serve(&GpuSpec::rtx2060(), &sc,
                      &ServeOpts { seed: Some(22), ..ServeOpts::default() })
        .expect("seed 22");
    assert_ne!(a.to_json_value().to_canonical_string(),
               b.to_json_value().to_canonical_string(),
               "different seeds produced identical serve runs");
    assert_eq!(a.tenants.len(), b.tenants.len());
}
