//! Differential test: the hierarchical timing wheel against a
//! `BinaryHeap<Reverse<(TimeKey, usize)>>` oracle — the exact structure
//! the wheel replaced in ISSUE 7. Over a million mixed arrivals
//! (tie-heavy bulk loads plus a closed-loop pop/push phase) the two
//! must agree on every single pop, including the (time, source-index)
//! tie-break order the golden traces depend on.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use miriam::runtime::timewheel::{TimeKey, TimingWheel};
use miriam::workloads::rng::Rng;

/// Oracle + wheel driven in lockstep; asserts every pop matches.
struct Pair {
    wheel: TimingWheel,
    heap: BinaryHeap<Reverse<(TimeKey, usize)>>,
    pops: u64,
}

impl Pair {
    fn new() -> Self {
        Pair { wheel: TimingWheel::new(), heap: BinaryHeap::new(), pops: 0 }
    }

    fn push(&mut self, t: f64, src: usize) {
        self.wheel.push(t, src);
        self.heap.push(Reverse((TimeKey(t), src)));
    }

    fn pop(&mut self) -> Option<(f64, usize)> {
        let got = self.wheel.pop();
        let want = self.heap.pop().map(|Reverse((TimeKey(t), s))| (t, s));
        match (got, want) {
            (Some((gt, gs)), Some((wt, ws))) => {
                assert!(
                    gt.to_bits() == wt.to_bits() && gs == ws,
                    "pop #{}: wheel ({gt}, {gs}) != heap ({wt}, {ws})",
                    self.pops
                );
            }
            (None, None) => {}
            (g, w) => panic!("pop #{}: wheel {g:?} != heap {w:?}", self.pops),
        }
        self.pops += 1;
        assert_eq!(self.wheel.len(), self.heap.len());
        got
    }
}

/// Tie-heavy time: a coarse grid (forcing exact-time and same-tick
/// collisions across many sources) with occasional sub-microsecond
/// fractional offsets drawn from a small quantized set (so fractions
/// collide too).
fn tie_heavy_time(rng: &mut Rng) -> f64 {
    let base = rng.next_below(200_000) as f64 * 7.5;
    match rng.next_below(4) {
        0 => base,
        1 => base + 0.25,
        2 => base + 0.5,
        _ => base + rng.next_f64() * 0.999,
    }
}

#[test]
fn wheel_matches_heap_over_a_million_mixed_arrivals() {
    let mut pair = Pair::new();
    let mut rng = Rng::new(0x5CA1E_D1FF);

    // Phase 1: bulk load ~700k tie-heavy arrivals across 1000 sources,
    // with interspersed partial drains so refill runs against slots
    // that are still being appended to.
    for i in 0..700_000u64 {
        let t = tie_heavy_time(&mut rng);
        let src = rng.next_below(1000) as usize;
        pair.push(t, src);
        if i % 97 == 0 {
            pair.pop();
        }
    }

    // Phase 2: ~300k closed-loop steps — pop the next event and push a
    // successor a short gap later (the serve/fleet loop shape). Gaps
    // are quantized so successors keep colliding with bulk entries.
    for _ in 0..300_000u64 {
        if let Some((t, _)) = pair.pop() {
            let gap = (1 + rng.next_below(64)) as f64 * 0.5;
            let src = rng.next_below(1000) as usize;
            pair.push(t + gap, src);
        }
    }

    // Drain to empty: every remaining pop must match, then both agree
    // the queue is exhausted.
    while pair.pop().is_some() {}
    assert!(pair.wheel.is_empty());
    assert!(pair.heap.is_empty());
    assert!(pair.pops >= 1_000_000, "exercised {} pops", pair.pops);
}

#[test]
fn wheel_matches_heap_on_adversarial_block_boundaries() {
    // Times chosen to straddle level boundaries: 64^k - epsilon vs
    // 64^k, plus duplicates of both, pushed in descending order so
    // the wheel's behind-cursor binary-insert path is exercised.
    let mut pair = Pair::new();
    let mut boundary_times = Vec::new();
    for k in 1..6u32 {
        let b = 64f64.powi(k as i32);
        for d in [-1.5, -1.0, -0.5, 0.0, 0.5, 1.0] {
            boundary_times.push(b + d);
        }
    }
    for &t in boundary_times.iter().rev() {
        for src in [3usize, 1, 2, 1] {
            pair.push(t, src);
        }
    }
    // Interleave pops with late pushes that land behind the cursor.
    for i in 0..boundary_times.len() * 2 {
        let (t, _) = pair.pop().expect("queue non-empty");
        if i % 3 == 0 {
            pair.push(t, 0); // exact tie with the event just popped
            pair.push(t + 0.1, 7);
        }
    }
    while pair.pop().is_some() {}
    assert!(pair.wheel.is_empty() && pair.heap.is_empty());
}
