//! Integration tests: workloads x schedulers x simulator, end to end.

use miriam::coordinator::{driver, scheduler_for, SCHEDULERS};
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::{lgsvl, mdtb};

const DUR: f64 = 300_000.0; // 0.3 simulated seconds per cell

#[test]
fn every_scheduler_completes_every_mdtb_workload() {
    for wl_name in ["A", "B", "C", "D"] {
        let wl = mdtb::by_name(wl_name, DUR).unwrap().build();
        for sched in SCHEDULERS {
            let mut s = scheduler_for(sched, &wl).unwrap();
            let st = driver::run(GpuSpec::rtx2060(), &wl, s.as_mut());
            assert!(st.completed_critical() > 0, "{wl_name}/{sched}: no critical");
            assert!(st.completed_normal() > 0, "{wl_name}/{sched}: no normal");
            assert!(st.achieved_occupancy > 0.0 && st.achieved_occupancy <= 1.0,
                    "{wl_name}/{sched}: occupancy {}", st.achieved_occupancy);
            assert!(st.span_us >= DUR * 0.5, "{wl_name}/{sched}: span too short");
        }
    }
}

#[test]
fn xavier_slower_than_rtx2060() {
    // The smaller edge part must show higher critical latency and lower
    // throughput on the same workload (paper Fig. 8 left vs right columns).
    let wl = mdtb::mdtb_a(DUR).build();
    let mut s1 = scheduler_for("miriam", &wl).unwrap();
    let big = driver::run(GpuSpec::rtx2060(), &wl, s1.as_mut());
    let mut s2 = scheduler_for("miriam", &wl).unwrap();
    let small = driver::run(GpuSpec::xavier(), &wl, s2.as_mut());
    assert!(small.critical_latency_mean_us() > big.critical_latency_mean_us());
    assert!(small.throughput_rps() < big.throughput_rps());
}

#[test]
fn paper_shape_mdtb_a() {
    // The Fig. 8 MDTB-A ordering on the 2060:
    //  - multistream inflates critical latency vs sequential;
    //  - miriam keeps critical latency at or below sequential's while
    //    beating its throughput;
    //  - IB throughput falls below sequential under closed-loop critical.
    let wl = mdtb::mdtb_a(800_000.0).build();
    let run = |name: &str| {
        let mut s = scheduler_for(name, &wl).unwrap();
        driver::run(GpuSpec::rtx2060(), &wl, s.as_mut())
    };
    let seq = run("sequential");
    let ms = run("multistream");
    let ib = run("ib");
    let mi = run("miriam");
    assert!(ms.critical_latency_mean_us() > seq.critical_latency_mean_us() * 1.1,
            "multistream should degrade critical latency: ms {} seq {}",
            ms.critical_latency_mean_us(), seq.critical_latency_mean_us());
    assert!(mi.critical_latency_mean_us() < seq.critical_latency_mean_us() * 1.28,
            "miriam latency overhead too high: mi {} seq {}",
            mi.critical_latency_mean_us(), seq.critical_latency_mean_us());
    assert!(mi.throughput_rps() > seq.throughput_rps() * 1.15,
            "miriam should beat sequential throughput: mi {} seq {}",
            mi.throughput_rps(), seq.throughput_rps());
    assert!(ib.throughput_rps() < seq.throughput_rps(),
            "IB throughput should fall below sequential on MDTB-A: ib {} seq {}",
            ib.throughput_rps(), seq.throughput_rps());
    assert!(ib.critical_latency_mean_us() < ms.critical_latency_mean_us(),
            "IB should protect latency better than multistream");
}

#[test]
fn miriam_latency_tracks_sequential_on_all_workloads() {
    // Paper: <=21% overhead on B-D, <=28% on A (we additionally allow the
    // cases where miriam lands *below* sequential, since sequential pays a
    // normal-task residual).
    for wl_name in ["A", "B", "C", "D"] {
        let wl = mdtb::by_name(wl_name, 600_000.0).unwrap().build();
        let mut s = scheduler_for("sequential", &wl).unwrap();
        let seq = driver::run(GpuSpec::rtx2060(), &wl, s.as_mut());
        let mut m = scheduler_for("miriam", &wl).unwrap();
        let mi = driver::run(GpuSpec::rtx2060(), &wl, m.as_mut());
        let ratio = mi.critical_latency_mean_us() / seq.critical_latency_mean_us();
        assert!(ratio < 1.30, "{wl_name}: miriam/seq latency {ratio:.2}");
    }
}

#[test]
fn lgsvl_case_study_shape() {
    let wl = lgsvl::workload(1_000_000.0);
    let run = |name: &str| {
        let mut s = scheduler_for(name, &wl).unwrap();
        driver::run(GpuSpec::rtx2060(), &wl, s.as_mut())
    };
    let seq = run("sequential");
    let mi = run("miriam");
    // Paper: +89% throughput at +11% latency. Shape: miriam >= sequential
    // tput, latency within a modest overhead.
    assert!(mi.throughput_rps() >= seq.throughput_rps() * 0.95);
    assert!(mi.critical_latency_mean_us()
            < seq.critical_latency_mean_us() * 1.25);
}

#[test]
fn miriam_critical_kernels_keep_original_geometry() {
    // Miriam never touches critical kernels (§5.1): every critical launch
    // in the timeline carries a bare kernel name (no shard suffix).
    let wl = mdtb::mdtb_b(DUR).build();
    let mut s = scheduler_for("miriam", &wl).unwrap();
    let st = driver::run(GpuSpec::rtx2060(), &wl, s.as_mut());
    for r in st.timeline.iter().filter(|r| r.criticality == Criticality::Critical) {
        assert!(!r.name.contains("#es"), "critical kernel sharded: {}", r.name);
    }
}

#[test]
fn poisson_seed_changes_arrivals_but_not_shape() {
    let mut spec_a = mdtb::mdtb_c(DUR);
    spec_a.seed = 1;
    let mut spec_b = mdtb::mdtb_c(DUR);
    spec_b.seed = 2;
    let mut s1 = scheduler_for("miriam", &spec_a.build()).unwrap();
    let a = driver::run(GpuSpec::rtx2060(), &spec_a.build(), s1.as_mut());
    let mut s2 = scheduler_for("miriam", &spec_b.build()).unwrap();
    let b = driver::run(GpuSpec::rtx2060(), &spec_b.build(), s2.as_mut());
    // Different arrivals...
    assert_ne!(a.completed_critical(), 0);
    assert_ne!(b.completed_critical(), 0);
    // ...but same qualitative behaviour (both complete work, finite stats).
    assert!(a.critical_latency_mean_us().is_finite());
    assert!(b.critical_latency_mean_us().is_finite());
}
