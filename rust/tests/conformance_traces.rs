//! Golden-trace conformance suite (ISSUE 2 tentpole).
//!
//! Drives the scenario family (`workloads::scenario`) through every
//! scheduler — the four paper schedulers plus the pinned hard-isolation
//! splits (ISSUE 9) — with the engine trace recorder on and pins three
//! contracts:
//!
//! 1. **Determinism** — the same (scenario, scheduler, seed) cell run
//!    twice produces a byte-identical canonical trace.
//! 2. **Rate-path conformance** — the incremental O(Δ)-per-event engine
//!    and the retained full-recompute reference oracle
//!    (`RunOpts::reference_rates`) walk identical trajectories on every
//!    cell (structural equality, timestamps within 1e-9 relative).
//! 3. **Golden anchors** — a pinned subset of cells is compared against
//!    checked-in canonical traces (`rust/tests/golden/`), so any semantic
//!    drift in the engine or a scheduler fails loudly. Missing goldens
//!    are recorded on first run (and `UPDATE_GOLDEN=1` refreshes them) —
//!    record via `miriam scenarios --record-golden rust/tests/golden`
//!    and commit the files (EXPERIMENTS.md §Scenarios).
//!
//! On failure, the offending canonical traces are written under
//! `target/conformance/` (uploaded as a CI artifact).

use std::fs;
use std::path::{Path, PathBuf};

use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::{scheduler_for, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::gpu::trace::{Trace, TraceEventKind};
use miriam::workloads::scenario::{self, ScenarioSpec};

/// Simulated window per conformance cell (us). Short but long enough
/// that every arrival process in the family fires and queues build.
const DUR_US: f64 = 40_000.0;

/// The full conformance scheduler set: the four paper schedulers plus
/// the two pinned hard-isolation splits (ISSUE 9). `SCHEDULERS` itself
/// stays the paper quartet — the isolation family is an opt-in column
/// everywhere else — but the determinism and rate-path contracts must
/// hold for every resolvable scheduler, so the suite iterates this.
fn conformance_schedulers() -> Vec<&'static str> {
    SCHEDULERS
        .iter()
        .chain(scenario::ISOLATION_GOLDEN_SCHEDULERS.iter())
        .copied()
        .collect()
}

fn run_traced_on(spec: GpuSpec, sc: &ScenarioSpec, sched: &str,
                 reference: bool)
                 -> (miriam::coordinator::RunStats, Trace) {
    let wl = sc.build();
    let mut s = scheduler_for(sched, &wl)
        .unwrap_or_else(|| panic!("unknown scheduler {sched}"));
    let mut st = driver::run_with(spec, &wl, s.as_mut(),
                                  RunOpts { reference_rates: reference,
                                            trace: true });
    let trace = st.trace.take().expect("trace was requested");
    (st, trace)
}

fn run_traced(sc: &ScenarioSpec, sched: &str, reference: bool)
              -> (miriam::coordinator::RunStats, Trace) {
    run_traced_on(GpuSpec::rtx2060(), sc, sched, reference)
}

fn dump_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("target/conformance")
}

/// Persist a failing cell's canonical trace for the CI artifact upload.
fn dump(file: &str, content: &str) {
    let dir = dump_dir();
    let _ = fs::create_dir_all(&dir);
    let _ = fs::write(dir.join(file), content);
}

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

#[test]
fn family_covers_at_least_eight_scenarios_for_all_schedulers() {
    let fam = scenario::family(DUR_US);
    assert!(fam.len() >= 8, "family has only {}", fam.len());
    assert_eq!(SCHEDULERS.len(), 4);
    for sc in &fam {
        assert!((2..=6).contains(&sc.tenants()), "{}", sc.name);
        assert!(sc.criticals() >= 1 && sc.criticals() < sc.tenants(),
                "{}: not mixed-criticality", sc.name);
        // Every scheduler — paper set and isolation splits — can be
        // built for every scenario.
        let wl = sc.build();
        for sched in conformance_schedulers() {
            assert!(scheduler_for(sched, &wl).is_some(), "{}/{sched}",
                    sc.name);
        }
    }
    assert_eq!(conformance_schedulers().len(), 6);
    for (sc_name, sched) in scenario::GOLDEN_CELLS {
        assert!(scenario::by_name(sc_name, DUR_US).is_some(),
                "golden cell names unknown scenario {sc_name}");
        assert!(SCHEDULERS.contains(&sched),
                "golden cell names unknown scheduler {sched}");
    }
    for (sc_name, sched) in scenario::ISOLATION_GOLDEN_CELLS {
        assert!(scenario::by_name(sc_name, DUR_US).is_some(),
                "isolation golden cell names unknown scenario {sc_name}");
        assert!(scenario::ISOLATION_GOLDEN_SCHEDULERS.contains(&sched),
                "isolation golden cell names unpinned scheduler {sched}");
    }
}

#[test]
fn same_seed_runs_produce_byte_identical_canonical_traces() {
    for sc in scenario::family(DUR_US) {
        for sched in conformance_schedulers() {
            let (_, t1) = run_traced(&sc, sched, false);
            let (_, t2) = run_traced(&sc, sched, false);
            assert!(!t1.is_empty(), "{}/{sched}: empty trace", sc.name);
            let a = t1.to_canonical_json();
            let b = t2.to_canonical_json();
            if a != b {
                let slug = scenario::scheduler_file_slug(sched);
                dump(&format!("determinism__{}__{slug}.run1.json", sc.name),
                     &a);
                dump(&format!("determinism__{}__{slug}.run2.json", sc.name),
                     &b);
                panic!("{}/{sched}: same-seed canonical traces differ \
                        ({} vs {} bytes; dumps in {:?})",
                       sc.name, a.len(), b.len(), dump_dir());
            }
        }
    }
}

#[test]
fn incremental_rate_path_traces_match_reference_oracle() {
    for sc in scenario::family(DUR_US) {
        for sched in conformance_schedulers() {
            let (inc_stats, inc) = run_traced(&sc, sched, false);
            let (ref_stats, refr) = run_traced(&sc, sched, true);
            assert_eq!(inc_stats.events, ref_stats.events,
                       "{}/{sched}: event counts diverged", sc.name);
            let divs = inc.diff(&refr);
            if !divs.is_empty() {
                let slug = scenario::scheduler_file_slug(sched);
                dump(&format!("ratepath__{}__{slug}.incremental.json",
                              sc.name),
                     &inc.to_canonical_json());
                dump(&format!("ratepath__{}__{slug}.reference.json",
                              sc.name),
                     &refr.to_canonical_json());
                panic!("{}/{sched}: incremental vs reference traces \
                        diverge at {} point(s); first: {} (dumps in {:?})",
                       sc.name, divs.len(), divs[0], dump_dir());
            }
        }
    }
}

#[test]
fn traces_are_structurally_sane() {
    // Per cell: submits == completes == timeline length, block placements
    // land on real SMs, and the canonical form round-trips exactly.
    let spec = GpuSpec::rtx2060();
    for sc in scenario::family(DUR_US) {
        let sched = "miriam";
        let (st, t) = run_traced(&sc, sched, false);
        let submits = t.count_of(TraceEventKind::Submit);
        let completes = t.count_of(TraceEventKind::Complete);
        assert_eq!(submits, st.timeline.len(), "{}", sc.name);
        assert_eq!(completes, st.timeline.len(), "{}", sc.name);
        for ev in &t.events {
            assert!(ev.t_us >= -1e-9, "{}: negative time", sc.name);
            if ev.kind == TraceEventKind::BlockPlace {
                assert!(ev.loc < spec.num_sms, "{}: bad SM id {}", sc.name,
                        ev.loc);
            }
            assert_ne!(t.name_of(ev), "?", "{}: unresolvable name", sc.name);
        }
        let s = t.to_canonical_json();
        let back = Trace::from_json_str(&s)
            .unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        assert_eq!(back, t, "{}: canonical round trip lost data", sc.name);
        assert_eq!(back.to_canonical_json(), s, "{}: not canonical", sc.name);
    }
}

#[test]
fn trace_recording_is_observation_only() {
    // Trace on vs off: identical trajectory (event counts, completions,
    // span) — recording must never perturb the run.
    for sc in scenario::family(DUR_US).into_iter().take(2) {
        for sched in SCHEDULERS {
            let wl = sc.build();
            let mut s1 = scheduler_for(sched, &wl).unwrap();
            let plain = driver::run_with(
                GpuSpec::rtx2060(), &wl, s1.as_mut(), RunOpts::default());
            let (traced, _) = run_traced(&sc, sched, false);
            assert!(plain.trace.is_none());
            assert_eq!(plain.events, traced.events, "{}/{sched}", sc.name);
            assert_eq!(plain.timeline.len(), traced.timeline.len());
            assert_eq!(plain.completed_critical(),
                       traced.completed_critical());
            assert_eq!(plain.completed_normal(), traced.completed_normal());
            assert!((plain.span_us - traced.span_us).abs() < 1e-9);
        }
    }
}

#[test]
fn golden_traces_pin_engine_and_scheduler_semantics() {
    // `run_traced` replays on rtx2060; goldens are pinned to the same
    // preset so CLI recordings and test replays can never disagree on
    // platform.
    assert_eq!(scenario::GOLDEN_PLATFORM, "rtx2060");
    let dir = golden_dir();
    let update = !matches!(
        std::env::var("UPDATE_GOLDEN").as_deref(),
        Err(_) | Ok("") | Ok("0") | Ok("false")
    );
    // Bootstrap (no goldens at all, e.g. before the first toolchain run
    // records them) records via the same shared writer the CLI uses,
    // then still runs the comparison below — a bootstrap run therefore
    // proves record→replay consistency. Once ANY golden exists, a
    // missing pinned cell means a deleted/renamed anchor and fails
    // instead of silently re-recording.
    let have_any = fs::read_dir(&dir)
        .map(|mut d| d.next().is_some())
        .unwrap_or(false);
    if update || !have_any {
        let recorded = driver::record_golden_traces(&dir).unwrap();
        eprintln!("recorded {} golden trace(s) into {} — commit \
                   rust/tests/golden/ to pin them",
                  recorded.len(), dir.display());
    }
    for (sc_name, sched) in scenario::GOLDEN_CELLS
        .into_iter()
        .chain(scenario::ISOLATION_GOLDEN_CELLS)
    {
        let sc = scenario::by_name(sc_name, scenario::GOLDEN_DURATION_US)
            .unwrap_or_else(|| panic!("unknown golden scenario {sc_name}"));
        let (_, actual) = run_traced(&sc, sched, false);
        let path = dir.join(scenario::golden_file_name(sc_name, sched));
        assert!(path.exists(),
                "golden {} is missing while other goldens exist — deleted \
                 or renamed? re-record deliberately with UPDATE_GOLDEN=1",
                path.display());
        let text = fs::read_to_string(&path).unwrap();
        let golden = Trace::from_json_str(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        // Bytes would over-pin: libm (ln in the Poisson/MMPP draws) may
        // differ in the last ulp across hosts, so goldens compare
        // structurally with a tiny time tolerance.
        let divs = actual.diff_with_tolerance(&golden, 1e-6);
        if !divs.is_empty() {
            dump(&format!("golden__{sc_name}__{}.actual.json",
                          scenario::scheduler_file_slug(sched)),
                 &actual.to_canonical_json());
            panic!("{sc_name}/{sched}: trace drifted from golden {} at {} \
                    point(s); first: {} (actual dumped in {:?}; regenerate \
                    with UPDATE_GOLDEN=1 or `miriam scenarios \
                    --record-golden rust/tests/golden` only if the change \
                    is intended)",
                   path.display(), divs.len(), divs[0], dump_dir());
        }
    }
}

#[test]
fn device_golden_traces_pin_per_platform_semantics() {
    // ISSUE 5 satellite: golden anchors per *device preset* — xavier and
    // tx2 × every scheduler on two family scenarios — so a contention or
    // scheduler change that only misbehaves on a small edge part (fewer
    // SMs, tighter bandwidth) fails loudly. Same bootstrap-on-first-run /
    // UPDATE_GOLDEN protocol as the main set, with its own bootstrap
    // state (a repo carrying only the rtx2060 goldens still bootstraps
    // the device set instead of failing).
    let dir = golden_dir().join(scenario::DEVICE_GOLDEN_SUBDIR);
    let update = !matches!(
        std::env::var("UPDATE_GOLDEN").as_deref(),
        Err(_) | Ok("") | Ok("0") | Ok("false")
    );
    let have_any = fs::read_dir(&dir)
        .map(|mut d| d.next().is_some())
        .unwrap_or(false);
    if update || !have_any {
        let recorded = driver::record_device_golden_traces(&dir).unwrap();
        eprintln!("recorded {} device golden trace(s) into {} — commit \
                   rust/tests/golden/devices/ to pin them",
                  recorded.len(), dir.display());
    }
    for platform in scenario::DEVICE_GOLDEN_PLATFORMS {
        let spec = GpuSpec::by_name(platform)
            .unwrap_or_else(|| panic!("unknown platform {platform}"));
        for sc_name in scenario::DEVICE_GOLDEN_SCENARIOS {
            let sc =
                scenario::by_name(sc_name, scenario::GOLDEN_DURATION_US)
                    .unwrap_or_else(|| {
                        panic!("unknown device golden scenario {sc_name}")
                    });
            for sched in conformance_schedulers() {
                let (_, actual) =
                    run_traced_on(spec.clone(), &sc, sched, false);
                assert!(!actual.is_empty(),
                        "{platform}/{sc_name}/{sched}: empty trace");
                let path = dir.join(scenario::device_golden_file_name(
                    platform, sc_name, sched));
                assert!(path.exists(),
                        "device golden {} is missing while other device \
                         goldens exist — deleted or renamed? re-record \
                         deliberately with UPDATE_GOLDEN=1",
                        path.display());
                let text = fs::read_to_string(&path).unwrap();
                let golden = Trace::from_json_str(&text)
                    .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                // Same tolerance rationale as the main goldens: libm may
                // differ in the last ulp across hosts, so compare
                // structurally with a tiny time tolerance.
                let divs = actual.diff_with_tolerance(&golden, 1e-6);
                if !divs.is_empty() {
                    dump(&format!(
                             "device_golden__{platform}__{sc_name}__{}\
                              .actual.json",
                             scenario::scheduler_file_slug(sched)),
                         &actual.to_canonical_json());
                    panic!("{platform}/{sc_name}/{sched}: trace drifted \
                            from device golden {} at {} point(s); first: {} \
                            (actual dumped in {:?}; regenerate with \
                            UPDATE_GOLDEN=1 or `miriam scenarios \
                            --record-golden rust/tests/golden` only if the \
                            change is intended)",
                           path.display(), divs.len(), divs[0],
                           dump_dir());
                }
            }
        }
    }
}

#[test]
fn gen_golden_traces_pin_generation_semantics() {
    // ISSUE 10 satellite: golden anchors for the generation serving
    // loop — 2 gen scenarios × miriam/sequential, traced through the
    // same `DeviceCore` the gen loop serves on, so per-step decode
    // resubmission, KV eviction ordering, and recompute placement are
    // all pinned at the engine-event level. Same bootstrap-on-first-run
    // / UPDATE_GOLDEN protocol as the main set, with its own bootstrap
    // state under rust/tests/golden/gen/.
    use miriam::server::gen::{run_gen_traced, record_gen_golden_traces,
                              GenOpts};
    use miriam::workloads::generation;

    let dir = golden_dir().join(generation::GEN_GOLDEN_SUBDIR);
    let update = !matches!(
        std::env::var("UPDATE_GOLDEN").as_deref(),
        Err(_) | Ok("") | Ok("0") | Ok("false")
    );
    let have_any = fs::read_dir(&dir)
        .map(|mut d| d.next().is_some())
        .unwrap_or(false);
    if update || !have_any {
        let recorded = record_gen_golden_traces(&dir).unwrap();
        eprintln!("recorded {} gen golden trace(s) into {} — commit \
                   rust/tests/golden/gen/ to pin them",
                  recorded.len(), dir.display());
    }
    for (sc_name, sched) in generation::GEN_GOLDEN_CELLS {
        let sc =
            generation::gen_by_name(sc_name, scenario::GOLDEN_DURATION_US)
                .unwrap_or_else(|| {
                    panic!("unknown gen golden scenario {sc_name}")
                });
        let opts = GenOpts { scheduler: sched.into(), ..GenOpts::default() };
        let (report, actual) =
            run_gen_traced(&GpuSpec::rtx2060(), &sc, &opts)
                .unwrap_or_else(|e| panic!("{sc_name}/{sched}: {e}"));
        assert!(!actual.is_empty(), "{sc_name}/{sched}: empty trace");
        assert_eq!(report.tokens, report.drawn_tokens,
                   "{sc_name}/{sched}: token conservation broke under \
                    tracing");
        let path = dir.join(scenario::golden_file_name(sc_name, sched));
        assert!(path.exists(),
                "gen golden {} is missing while other gen goldens exist — \
                 deleted or renamed? re-record deliberately with \
                 UPDATE_GOLDEN=1",
                path.display());
        let text = fs::read_to_string(&path).unwrap();
        let golden = Trace::from_json_str(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        // Same tolerance rationale as the main goldens: libm may differ
        // in the last ulp across hosts, so compare structurally with a
        // tiny time tolerance.
        let divs = actual.diff_with_tolerance(&golden, 1e-6);
        if !divs.is_empty() {
            dump(&format!("gen_golden__{sc_name}__{}.actual.json",
                          scenario::scheduler_file_slug(sched)),
                 &actual.to_canonical_json());
            panic!("{sc_name}/{sched}: trace drifted from gen golden {} at \
                    {} point(s); first: {} (actual dumped in {:?}; \
                    regenerate with UPDATE_GOLDEN=1 or `miriam scenarios \
                    --record-golden rust/tests/golden` only if the change \
                    is intended)",
                   path.display(), divs.len(), divs[0], dump_dir());
        }
    }
}

#[test]
fn deadline_tagged_scenarios_score_misses_consistently() {
    // duo-burst tags its critical source with a 30ms deadline; whatever
    // the scheduler, misses never exceed completions and an impossible
    // deadline variant scores every completion as a miss.
    let sc = scenario::by_name("duo-burst", DUR_US).unwrap();
    for sched in conformance_schedulers() {
        let wl = sc.build();
        let mut s = scheduler_for(sched, &wl).unwrap();
        let st = driver::run(GpuSpec::rtx2060(), &wl, s.as_mut());
        assert!(st.deadline_misses_critical as usize
                    <= st.completed_critical(),
                "{sched}");
        assert_eq!(st.deadline_misses_normal, 0, "{sched}");
    }
    let mut tight = sc.clone();
    tight.sources[0].deadline_us = Some(0.001);
    let wl = tight.build();
    let mut s = scheduler_for("sequential", &wl).unwrap();
    let st = driver::run(GpuSpec::rtx2060(), &wl, s.as_mut());
    assert!(st.completed_critical() > 0);
    assert_eq!(st.deadline_misses_critical as usize, st.completed_critical());
    assert!((st.critical_deadline_miss_rate() - 1.0).abs() < 1e-12);
}
