//! Integration tests across the language boundary: the AOT artifacts
//! produced by python/compile/aot.py executed through the Rust PJRT
//! runtime, checked against the manifest goldens.
//!
//! These tests skip (with a message) when `make artifacts` has not run,
//! or when the crate was built without the `pjrt` feature (the stub
//! runtime cannot execute anything) — everything else in the crate is
//! artifact-independent.

use miriam::runtime::artifacts::npy_rand;
use miriam::runtime::{Manifest, Runtime};

fn manifest() -> Option<Manifest> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping runtime tests: built without the `pjrt` feature");
        return None;
    }
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime tests: run `make artifacts`");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

#[test]
fn all_model_artifacts_execute_and_match_goldens() {
    let Some(manifest) = manifest() else { return };
    let mut rt = Runtime::new(manifest).expect("PJRT CPU client");
    let names = rt.model_names();
    assert!(names.len() >= 6, "expected the 6 MDTB models");
    for name in names {
        let entry = rt.manifest.entry(&name).unwrap().clone();
        let m = rt.load(&name).expect("compiles");
        let n: usize = m.input_shapes[0].iter().product();
        let golden = entry.golden.as_ref().expect("golden present");
        let input = npy_rand::randn(golden.input_seed as u32, n);
        let out = m.run_f32(&[input]).expect("executes");
        assert_eq!(out.len(), 10, "{name}: logit count");
        let max_err = out
            .iter()
            .zip(&golden.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "{name}: max err {max_err}");
    }
}

#[test]
fn elastic_grid_shards_stitch_to_full_product() {
    // The paper's §6.4 consistency property demonstrated across the
    // language boundary: the matmul shard executables (one per dichotomy
    // degree, Eq. 1) must reassemble the same product the full kernel
    // computes, for every slicing degree.
    let Some(manifest) = manifest() else { return };
    let golden = manifest
        .of_kind("golden")
        .next()
        .expect("matmul golden present")
        .clone();
    let m = golden.m.unwrap();
    let k = golden.k.unwrap();
    let n = golden.n.unwrap();
    let x = npy_rand::randn(golden.x_seed.unwrap() as u32, m * k);
    let w = npy_rand::randn(golden.w_seed.unwrap() as u32, k * n);
    let want8 = golden.output_first8.clone().unwrap();

    let shard_names: Vec<(String, u32)> = manifest
        .of_kind("matmul_shard")
        .map(|e| (e.name.clone(), e.rows.unwrap()))
        .collect();
    assert_eq!(shard_names.len(), 4, "degrees 0..3");

    let mut rt = Runtime::new(manifest).expect("client");
    for (name, rows) in shard_names {
        let shards = m / rows as usize;
        let exe = rt.load(&name).expect("shard compiles");
        let mut full = Vec::with_capacity(m * n);
        for s in 0..shards {
            let xs = x[s * rows as usize * k..(s + 1) * rows as usize * k].to_vec();
            let out = exe.run_f32(&[xs, w.clone()]).expect("shard executes");
            assert_eq!(out.len(), rows as usize * n);
            full.extend(out);
        }
        assert_eq!(full.len(), m * n, "{name}: stitched size");
        for (i, want) in want8.iter().enumerate() {
            assert!((full[i] - want).abs() < 1e-2 + want.abs() * 1e-4,
                    "{name}: element {i}: {} vs {want}", full[i]);
        }
    }
}

#[test]
fn runtime_rejects_bad_inputs() {
    let Some(manifest) = manifest() else { return };
    let mut rt = Runtime::new(manifest).expect("client");
    let m = rt.load("cifarnet").expect("compiles");
    // Wrong input count.
    assert!(m.run_f32(&[]).is_err());
    // Wrong input length.
    assert!(m.run_f32(&[vec![0.0; 7]]).is_err());
}

#[test]
fn server_routes_critical_first_and_serves() {
    use miriam::gpu::kernel::Criticality;
    use miriam::server::Server;
    if manifest().is_none() {
        return;
    }
    let dir = Manifest::default_dir();
    let server = Server::start(&dir, &["cifarnet".into(), "gru".into()])
        .expect("server starts");
    let h = server.handle.clone();
    // A few round-trips of both classes.
    for i in 0..6 {
        let (model, crit, n) = if i % 2 == 0 {
            ("cifarnet", Criticality::Critical, 32 * 32 * 3)
        } else {
            ("gru", Criticality::Normal, 16 * 32)
        };
        let reply = h.infer(model, crit, npy_rand::randn(i, n));
        assert!(reply.ok, "{:?}", reply.error);
        assert_eq!(reply.output.len(), 10);
    }
    assert_eq!(h.stats.errors.load(std::sync::atomic::Ordering::Relaxed), 0);
    server.stop();
}
