//! Determinism, differential, and SLO contracts of the generation
//! serving pipeline (ISSUE 10):
//!
//! * the gen grid produces **byte-identical** `BENCH_gen.json`
//!   documents across repeat runs and across `--threads 1/4` (the
//!   report carries no host timing by design);
//! * a 1-token-output generation scenario reproduces the equivalent
//!   fixed-chain batch-driver run **bitwise** per request class (the
//!   decode machinery is inert, so pre-gen paths are provably
//!   untouched);
//! * on the mixed scenarios, criticals' TTFT p99 under
//!   deadline-feasible admission stays within 1.10x of their solo-run
//!   TTFT p99 (the acceptance bound);
//! * a seed override changes the document, not its shape.

use miriam::coordinator::admission::{AdmissionPolicy, POLICIES};
use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::scheduler_for;
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::server::gen::{run_gen, run_gen_grid, GenOpts};
use miriam::workloads::generation;

const DUR_US: f64 = 40_000.0;

fn opts(policy: AdmissionPolicy) -> GenOpts {
    GenOpts { policy, ..GenOpts::default() }
}

#[test]
fn gen_grid_is_byte_identical_across_threads_and_repeats() {
    let scenarios: Vec<_> = generation::gen_family(DUR_US)
        .into_iter()
        .filter(|s| s.name == "gen-duo" || s.name == "gen-pressure")
        .collect();
    assert_eq!(scenarios.len(), 2);
    let base = GenOpts::default();
    let a = run_gen_grid(&GpuSpec::rtx2060(), &scenarios, &POLICIES, &base, 1)
        .expect("grid threads=1");
    let b = run_gen_grid(&GpuSpec::rtx2060(), &scenarios, &POLICIES, &base, 4)
        .expect("grid threads=4");
    let c = run_gen_grid(&GpuSpec::rtx2060(), &scenarios, &POLICIES, &base, 4)
        .expect("grid repeat");
    assert_eq!(a.to_json(), b.to_json(),
               "BENCH_gen.json differs across thread counts");
    assert_eq!(b.to_json(), c.to_json(),
               "BENCH_gen.json differs across repeat runs");
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.scenario, y.scenario);
        assert_eq!(x.kind, y.kind);
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.evictions, y.evictions);
        assert_eq!(x.events, y.events);
        assert_eq!(x.crit_ttft_p99_us().to_bits(),
                   y.crit_ttft_p99_us().to_bits());
    }
}

#[test]
fn one_token_generation_reproduces_the_fixed_chain_driver_bitwise() {
    // gen-diff draws output_len == 1 for every request (mean 1, max 1):
    // each request is exactly its prefill graph, submitted through the
    // same per-source interned path the batch driver uses. The KV budget
    // is sized so nothing ever parks. The per-request latency multisets
    // must therefore match driver::run_with on the base workload to the
    // bit, per class — pinning that the decode/eviction machinery is
    // inert and pre-gen serving paths are untouched.
    let sc = generation::gen_diff(DUR_US);
    let gen = run_gen(&GpuSpec::rtx2060(), &sc, &opts(AdmissionPolicy::Open))
        .expect("gen run");
    assert_eq!(gen.shed(), 0);
    assert_eq!(gen.evictions, 0, "1-token scenario must never evict");
    assert_eq!(gen.tokens, gen.served(), "one token per request");

    let wl = sc.base_workload();
    let mut sched = scheduler_for("miriam", &wl).expect("scheduler");
    let direct = driver::run_with(GpuSpec::rtx2060(), &wl, sched.as_mut(),
                                  RunOpts::default());

    let mut gen_crit: Vec<f64> = Vec::new();
    let mut gen_norm: Vec<f64> = Vec::new();
    for t in &gen.tenants {
        match t.criticality {
            Criticality::Critical => gen_crit.extend(&t.latencies_us),
            Criticality::Normal => gen_norm.extend(&t.latencies_us),
        }
    }
    let mut dir_crit = direct.critical_latencies_us.clone();
    let mut dir_norm = direct.normal_latencies_us.clone();
    for v in [&mut gen_crit, &mut gen_norm, &mut dir_crit, &mut dir_norm] {
        v.sort_by(f64::total_cmp);
    }
    assert!(!gen_crit.is_empty(), "no critical completions in window");
    assert_eq!(gen_crit.len(), dir_crit.len(), "critical counts diverged");
    assert_eq!(gen_norm.len(), dir_norm.len(), "normal counts diverged");
    for (i, (a, b)) in gen_crit.iter().zip(&dir_crit).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "critical latency {i} diverged: {a} vs {b}");
    }
    for (i, (a, b)) in gen_norm.iter().zip(&dir_norm).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "normal latency {i} diverged: {a} vs {b}");
    }
}

#[test]
fn deadline_feasible_ttft_p99_stays_within_110pct_of_solo() {
    // The acceptance bound: a critical tenant sharing the device with
    // long-generation best-effort tenants must keep its TTFT p99 within
    // 1.10x of what it gets running alone (+5us absolute slack for FP
    // noise on near-zero quantiles). Both scenarios carry uniform
    // critical arrivals (one at t=0 guaranteed), so both must compare —
    // the assertion cannot go vacuous.
    let mut compared = 0;
    for sc in generation::gen_family(DUR_US)
        .iter()
        .filter(|s| s.name == "gen-duo" || s.name == "gen-pressure")
    {
        let mixed = run_gen(&GpuSpec::rtx2060(), sc,
                            &opts(AdmissionPolicy::DeadlineFeasible))
            .expect("mixed run");
        assert_eq!(mixed.shed_critical(), 0, "{}: critical shed", sc.name);
        let solo = run_gen(&GpuSpec::rtx2060(), &sc.solo_criticals(),
                           &opts(AdmissionPolicy::Open))
            .expect("solo run");
        let p_mixed = mixed.crit_ttft_p99_us();
        let p_solo = solo.crit_ttft_p99_us();
        assert!(p_mixed.is_finite() && p_solo.is_finite(),
                "{}: no critical TTFT samples (mixed {p_mixed}, solo \
                 {p_solo})", sc.name);
        compared += 1;
        assert!(p_mixed <= p_solo * 1.10 + 5.0,
                "{}: mixed TTFT p99 {p_mixed}us exceeds 1.10x solo \
                 {p_solo}us", sc.name);
        // Solo criticals see the identical arrival stream and output
        // draws (request seeds are keyed per source, not globally), so
        // the served critical population matches exactly.
        for (m, s) in mixed.tenants.iter().zip(&solo.tenants) {
            if m.criticality == Criticality::Critical {
                assert_eq!(m.served, s.served,
                           "{}/{}: critical served diverged",
                           sc.name, m.label);
            }
        }
    }
    assert_eq!(compared, 2);
}

#[test]
fn seed_override_changes_the_document_but_not_its_shape() {
    let sc = &generation::gen_family(DUR_US)[0];
    let a = run_gen(&GpuSpec::rtx2060(), sc,
                    &GenOpts { seed: Some(31), ..GenOpts::default() })
        .expect("seed 31");
    let b = run_gen(&GpuSpec::rtx2060(), sc,
                    &GenOpts { seed: Some(32), ..GenOpts::default() })
        .expect("seed 32");
    assert_ne!(a.to_json_value().to_canonical_string(),
               b.to_json_value().to_canonical_string(),
               "different seeds produced identical gen runs");
    assert_eq!(a.tenants.len(), b.tenants.len());
    assert_eq!(a.seed, 31);
    assert_eq!(b.seed, 32);
}
