//! Fig. 11 + 12 — autonomous-driving case study: a regenerated LGSVL
//! perception trace (ResNet obstacle detection critical @10 Hz uniform,
//! SqueezeNet pose estimation normal @12.5 Hz uniform) on the RTX 2060.
//!
//! Paper: vs Sequential, Multi-stream and IB raise throughput 1.41x/1.25x
//! while inflating critical latency 82%/56%; Miriam reaches +89%
//! throughput with only an 11% latency overhead and the highest SM
//! occupancy.
//!
//! Run: `cargo bench --bench fig11_lgsvl`

use miriam::coordinator::{driver, scheduler_for, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::lgsvl;

fn main() {
    let duration_us = 2_000_000.0;
    let spec = GpuSpec::rtx2060();
    let wl = lgsvl::workload(duration_us);
    println!("# Fig. 11/12: LGSVL trace — critical ResNet @10Hz, normal \
              SqueezeNet @12.5Hz, {}s simulated, rtx2060", duration_us / 1e6);

    // Fig. 12 (c): the arrival trace itself.
    let trace = lgsvl::trace(duration_us.min(500_000.0), 2_000.0, wl.seed);
    println!("\n## regenerated trace excerpt (first 12 arrivals, 2ms jitter)");
    for (t, src) in trace.iter().take(12) {
        println!("  t={:>9.3} ms  {}", t / 1e3,
                 if *src == 0 { "camera->resnet (critical)" }
                 else { "lidar->squeezenet (normal)" });
    }

    println!("\n{:<12} {:>10} {:>10} {:>12} {:>8}",
             "scheduler", "crit(ms)", "crit p99", "tput(req/s)", "occup");
    let mut seq = (f64::NAN, f64::NAN);
    let mut rows = Vec::new();
    for sched in SCHEDULERS {
        let mut s = scheduler_for(sched, &wl).unwrap();
        let st = driver::run(spec.clone(), &wl, s.as_mut());
        if sched == "sequential" {
            seq = (st.critical_latency_mean_us(), st.throughput_rps());
        }
        rows.push((sched, st));
    }
    for (sched, st) in &rows {
        println!("{:<12} {:>10.2} {:>10.2} {:>12.1} {:>8.3}",
                 sched,
                 st.critical_latency_mean_us() / 1e3,
                 st.critical_latency_p99_us() / 1e3,
                 st.throughput_rps(),
                 st.achieved_occupancy);
    }
    println!("\n{:<12} {:>10} {:>12}", "-- ratio", "lat/seq", "tput/seq");
    for (sched, st) in &rows {
        println!("{:<12} {:>10.2} {:>12.2}",
                 sched,
                 st.critical_latency_mean_us() / seq.0,
                 st.throughput_rps() / seq.1);
    }
    println!("\n# paper: multistream 1.41x tput @ +82% lat; ib 1.25x @ +56%;");
    println!("# miriam +89% tput @ +11% lat, highest occupancy.");
}
