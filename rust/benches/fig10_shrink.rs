//! Fig. 10 — design-space shrinking: fraction of elastic-kernel candidates
//! pruned per MDTB model by the hardware limiters + WIScore/OScore ranking
//! (§6.3). Paper: 84%–95.2% pruned across models, with the kept candidates
//! lying on the elasticized-scale vs scheduling-granularity trade-off
//! frontier.
//!
//! Run: `cargo bench --bench fig10_shrink`

use miriam::elastic::shrink::{shrink_design_space, CriticalProfile, ShrinkConfig};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::models;

fn main() {
    let spec = GpuSpec::rtx2060();
    let cfg = ShrinkConfig::default();
    // Representative critical co-runners: the MDTB critical set (Table 2).
    let crit_models = ["alexnet", "squeezenet", "gru", "lstm"];
    let mut crits: Vec<CriticalProfile> = Vec::new();
    for m in crit_models {
        for k in models::by_name(m).unwrap().kernels {
            let p = CriticalProfile::from_kernel(&k);
            if !crits.contains(&p) {
                crits.push(p);
            }
        }
    }
    crits.truncate(32);

    println!("# Fig. 10: design-space shrinking per MDTB model (rtx2060)");
    println!("{:<12} {:>8} {:>10} {:>8} {:>9} {:>10} {:>12}",
             "model", "kernels", "space", "kept", "pruned%", "min-degree",
             "max-degree");
    for name in models::MDTB_MODELS {
        let model = models::by_name(name).unwrap();
        let mut total_space = 0usize;
        let mut total_kept = 0usize;
        let mut min_deg = u32::MAX;
        let mut max_deg = 0u32;
        for k in &model.kernels {
            let out = shrink_design_space(k, &crits, &spec, &cfg);
            total_space += out.total;
            total_kept += out.kept.len();
            for c in &out.kept {
                // Sharding degree = log2(#shards) when power-of-two.
                let shards = k.grid.div_ceil(c.n_blocks);
                let deg = 32 - shards.leading_zeros() - 1;
                min_deg = min_deg.min(deg);
                max_deg = max_deg.max(deg);
            }
        }
        let pruned = 100.0 * (1.0 - total_kept as f64 / total_space.max(1) as f64);
        println!("{:<12} {:>8} {:>10} {:>8} {:>8.1}% {:>10} {:>12}",
                 name,
                 model.kernels.len(),
                 total_space,
                 total_kept,
                 pruned,
                 if min_deg == u32::MAX { 0 } else { min_deg },
                 max_deg);
    }
    println!("\n# paper: pruned fraction ranges 84%-95.2% across MDTB models;");
    println!("# kept candidates span the sharding-degree (elasticized scale)");
    println!("# vs scheduling-granularity frontier.");
}
