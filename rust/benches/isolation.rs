//! Hard SM isolation vs elastic scheduling (ISSUE 9).
//!
//! The comparison the isolation literature asks for: the full rtx2060
//! scenario family served under `sequential`, `miriam`, a strict
//! MPS-style `isolation:70/30` split (criticals own 21 of 30 SMs,
//! normals the rest, never shared), and the same split with
//! work-conserving spillover (`isolation:70/30+spill`). Per (scenario,
//! scheduler) the table reports mean critical p50/p99, throughput, and
//! deadline misses; the summary pits each isolation variant against
//! `miriam` — the headline read: elasticity must dominate hard
//! partitioning on throughput while hard partitioning buys, at most, a
//! marginal critical-latency edge.
//!
//! Hard gate (exit 1), not a remark: on every (scenario, isolation
//! scheduler) aggregate, isolation's mean critical p99 must sit at or
//! below miriam's × 1.05 — a dedicated critical partition that is
//! *slower* than sharing the whole device means the mask plumbing is
//! broken, regardless of what any baseline says.
//!
//! Writes `BENCH_isolation.json` (canonical; every `comparisons` field
//! is simulated and therefore byte-deterministic per seed and across
//! worker threads — schema in EXPERIMENTS.md §Isolation). CI smoke
//! mode: append `-- --smoke` (or set `BENCH_SMOKE=1`).

use std::collections::BTreeMap;

use miriam::coordinator::sweep::{run_sweep, Aggregate, SweepSpec};
use miriam::runtime::json::Json;
use miriam::workloads::scenario;

/// Invariant headroom: isolation critical p99 may exceed miriam's by at
/// most this factor before the bench fails.
const CRIT_P99_TOLERANCE: f64 = 1.05;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 20_000.0 } else { 200_000.0 };
    let seeds = if smoke { 2 } else { 3 };
    let schedulers = ["sequential", "miriam", "isolation:70/30",
                      "isolation:70/30+spill"];
    let spec = SweepSpec {
        platform: "rtx2060".into(),
        duration_us,
        scenarios: scenario::family(duration_us),
        schedulers: schedulers.iter().map(|s| s.to_string()).collect(),
        seeds,
        trace: false,
        reference_rates: false,
    };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("# isolation: {} scenarios x {} schedulers x {seeds} seed(s) \
              on {}, {}s of arrivals per cell, {threads} thread(s){}",
             spec.scenarios.len(), spec.schedulers.len(), spec.platform,
             duration_us / 1e6, if smoke { " (smoke)" } else { "" });
    println!("{:<16} {:<22} {:>9} {:>9} {:>9} {:>7}",
             "scenario", "scheduler", "crit p50", "crit p99", "thru",
             "misses");
    println!("{:<16} {:<22} {:>9} {:>9} {:>9} {:>7}",
             "", "", "(ms)", "(ms)", "(r/s)", "(crit)");

    let report = run_sweep(&spec, threads).expect("isolation sweep runs");
    let aggs = report.aggregates();
    for a in &aggs {
        println!("{:<16} {:<22} {:>9.2} {:>9.2} {:>9.1} {:>7}",
                 a.scenario, a.scheduler, a.mean_crit_p50_us / 1e3,
                 a.mean_crit_p99_us / 1e3, a.mean_throughput_rps,
                 a.deadline_misses_critical);
    }

    // Isolation vs elasticity, per scenario — the headline table. Ratios
    // > 1 in the p99 column mean the dedicated partition is *slower*
    // than sharing (an invariant violation past the tolerance); ratios
    // < 1 in the throughput column are the cost of walling off SMs.
    fn find<'a>(aggs: &'a [Aggregate], sc: &str, sched: &str)
                -> Option<&'a Aggregate> {
        aggs.iter().find(|a| a.scenario == sc && a.scheduler == sched)
    }
    println!("\n{:<16} {:<22} {:>10} {:>10} {:>9} {:>9}",
             "scenario", "scheduler", "crit p99", "p99", "thru", "thru");
    println!("{:<16} {:<22} {:>10} {:>10} {:>9} {:>9}",
             "", "", "(ms)", "(x miriam)", "(r/s)", "(x miriam)");
    let mut violations = 0u32;
    let mut rows: Vec<Json> = Vec::new();
    for sc in &report.scenarios {
        let miriam =
            find(&aggs, sc, "miriam").expect("miriam ran everywhere");
        let seq = find(&aggs, sc, "sequential")
            .expect("sequential ran everywhere");
        for &sched in
            schedulers.iter().filter(|s| s.starts_with("isolation"))
        {
            let a = find(&aggs, sc, sched).expect("isolation ran everywhere");
            let p99_x = a.mean_crit_p99_us / miriam.mean_crit_p99_us;
            let thru_x = a.mean_throughput_rps / miriam.mean_throughput_rps;
            let ok = !(a.mean_crit_p99_us.is_finite()
                       && miriam.mean_crit_p99_us.is_finite()
                       && miriam.mean_crit_p99_us > 0.0
                       && a.mean_crit_p99_us
                           > miriam.mean_crit_p99_us * CRIT_P99_TOLERANCE);
            if !ok {
                violations += 1;
            }
            println!("{:<16} {:<22} {:>10.2} {:>10.2} {:>9.1} {:>9.2}{}",
                     sc, sched, a.mean_crit_p99_us / 1e3, p99_x,
                     a.mean_throughput_rps, thru_x,
                     if ok { "" } else { "  << INVARIANT" });
            let num = Json::Num;
            let mut m = BTreeMap::new();
            m.insert("scenario".into(), Json::Str(sc.clone()));
            m.insert("scheduler".into(), Json::Str(sched.to_string()));
            m.insert("crit_p99_us".into(), num(a.mean_crit_p99_us));
            m.insert("crit_p50_us".into(), num(a.mean_crit_p50_us));
            m.insert("throughput_rps".into(), num(a.mean_throughput_rps));
            m.insert("deadline_misses_critical".into(),
                     num(a.deadline_misses_critical as f64));
            m.insert("miriam_crit_p99_us".into(),
                     num(miriam.mean_crit_p99_us));
            m.insert("miriam_throughput_rps".into(),
                     num(miriam.mean_throughput_rps));
            m.insert("sequential_crit_p99_us".into(),
                     num(seq.mean_crit_p99_us));
            m.insert("crit_p99_vs_miriam".into(), num(p99_x));
            m.insert("throughput_vs_miriam".into(), num(thru_x));
            rows.push(Json::Obj(m));
        }
    }
    println!("\nisolation crit p99 <= miriam x {CRIT_P99_TOLERANCE} on \
              every cell: {}",
             if violations == 0 {
                 "yes".to_string()
             } else {
                 format!("NO ({violations} violation(s))")
             });

    // BENCH_isolation.json: comparison rows only carry simulated
    // quantities, so the document is byte-deterministic per seed and
    // across thread counts (host timing stays in the stdout table and
    // BENCH_sweep.json, never here).
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("isolation".into()));
    doc.insert("platform".into(), Json::Str(spec.platform.clone()));
    doc.insert("duration_us".into(), num(duration_us));
    doc.insert("seeds".into(), num(f64::from(seeds)));
    doc.insert("smoke".into(), Json::Bool(smoke));
    doc.insert(
        "scenarios".into(),
        Json::Arr(report.scenarios.iter().cloned().map(Json::Str).collect()),
    );
    doc.insert(
        "schedulers".into(),
        Json::Arr(schedulers.iter().map(|s| Json::Str(s.to_string()))
                      .collect()),
    );
    doc.insert("crit_p99_tolerance".into(), num(CRIT_P99_TOLERANCE));
    doc.insert("violations".into(), num(f64::from(violations)));
    doc.insert("comparisons".into(), Json::Arr(rows));
    doc.insert("version".into(), num(1.0));
    std::fs::write("BENCH_isolation.json",
                   Json::Obj(doc).to_canonical_string())
        .expect("write BENCH_isolation.json");
    println!("wrote BENCH_isolation.json");

    if violations > 0 {
        std::process::exit(1);
    }
}
