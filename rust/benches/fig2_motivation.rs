//! Fig. 2 (left) — motivation: latency distribution of ResNet50 when
//! co-running with different DNN models under plain multi-stream.
//!
//! Paper observation (RTX 2060): solo ResNet50 ~4.2 ms; co-running with
//! VGG16 spreads the distribution from 4.4 ms to ~16.2 ms, and the spread
//! pattern differs per co-runner. We regenerate the CDF rows (p10..p99).
//!
//! Run: `cargo bench --bench fig2_motivation`

use std::sync::Arc;

use miriam::coordinator::{baselines::multistream::MultiStream, driver};
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::arrival::Arrival;
use miriam::workloads::mdtb::{Source, Workload};
use miriam::workloads::models;

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
}

fn run_pair(co: Option<&str>, duration_us: f64) -> Vec<f64> {
    let mut sources = vec![Source {
        model: Arc::new(models::resnet50()),
        arrival: Arrival::ClosedLoop { clients: 1 },
        criticality: Criticality::Critical,
        deadline_us: None,
    }];
    if let Some(name) = co {
        sources.push(Source {
            model: Arc::new(models::by_name(name).unwrap()),
            arrival: Arrival::ClosedLoop { clients: 1 },
            criticality: Criticality::Normal,
            deadline_us: None,
        });
    }
    let wl = Workload {
        name: format!("fig2/{}", co.unwrap_or("solo")),
        sources,
        duration_us,
        seed: 2,
    };
    let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut MultiStream::new());
    let mut lats: Vec<f64> = stats.critical_latencies_us.clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lats
}

fn main() {
    let duration_us = 1_000_000.0;
    println!("# Fig. 2 (left): ResNet50 latency CDF under multi-stream co-running");
    println!("# (rtx2060 preset, closed-loop, {}s simulated)", duration_us / 1e6);
    println!("{:<12} {:>6} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
             "co-runner", "n", "p10(ms)", "p25(ms)", "p50(ms)", "p75(ms)",
             "p90(ms)", "p99(ms)");
    let solo = run_pair(None, duration_us);
    let solo_p50 = quantile(&solo, 0.5);
    for co in [None, Some("vgg16"), Some("alexnet"), Some("squeezenet")] {
        let lats = run_pair(co, duration_us);
        println!("{:<12} {:>6} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
                 co.unwrap_or("solo"),
                 lats.len(),
                 quantile(&lats, 0.10) / 1e3,
                 quantile(&lats, 0.25) / 1e3,
                 quantile(&lats, 0.50) / 1e3,
                 quantile(&lats, 0.75) / 1e3,
                 quantile(&lats, 0.90) / 1e3,
                 quantile(&lats, 0.99) / 1e3);
    }
    // Paper-shape check: co-running shifts + widens the distribution.
    let vgg = run_pair(Some("vgg16"), duration_us);
    let shift = quantile(&vgg, 0.5) / solo_p50;
    let spread = (quantile(&vgg, 0.99) - quantile(&vgg, 0.10))
        / (quantile(&solo, 0.99) - quantile(&solo, 0.10)).max(1.0);
    println!("\n# shape: vgg16 shifts the median x{shift:.2} and widens the \
              p10-p99 band x{spread:.1} vs solo");
    println!("# paper: solo 4.2 ms; with vgg16 the range is 4.4-16.2 ms \
              (median shift >1, wide spread)");
}
