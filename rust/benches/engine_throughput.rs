//! Engine event-throughput benchmark (EXPERIMENTS.md §Perf), re-based on
//! the parallel sweep runner (ISSUE 3).
//!
//! Three legs, all over MDTB-shaped cells expressed as scenarios:
//!
//! 1. **Rate model** — the full scheduler grid (MDTB-A and MDTB-D ×
//!    sequential/multistream/ib/miriam), once on the retained
//!    full-recompute `reference` rate model (the seed's O(events ×
//!    resident) per-event algorithm, the "before") and once on the
//!    `incremental` O(Δ) path. Cells run on one worker so per-cell wall
//!    times are uncontended.
//! 2. **Coordinator-in-the-loop** — `miriam` (zero-clone fast path)
//!    vs `miriam-ref` (retained String-keyed/cloning coordinator) on the
//!    incremental engine: measures the ISSUE 3 coordinator win, not just
//!    the engine win.
//! 3. **Sweep scaling** — the same grid at `--threads 1` vs all cores:
//!    wall-clock speedup of the parallel sweep runner itself (per-cell
//!    results are byte-identical; `rust/tests/sweep_determinism.rs` pins
//!    that).
//!
//! Writes `BENCH_engine.json` (schema keys of the PR 1 harness kept, new
//! `coordinator` and `sweep_scaling` sections added). CI smoke mode:
//! append `-- --smoke` (or set `BENCH_SMOKE=1`).

use std::fmt::Write as _;

use miriam::coordinator::sweep::{run_sweep, SweepReport, SweepSpec};
use miriam::coordinator::SCHEDULERS;
use miriam::workloads::scenario;

fn mdtb_ad(duration_us: f64) -> Vec<scenario::ScenarioSpec> {
    scenario::mdtb_scenarios(duration_us)
        .into_iter()
        .filter(|s| s.name == "MDTB-A" || s.name == "MDTB-D")
        .collect()
}

fn grid_spec(duration_us: f64, schedulers: &[&str], seeds: u32,
             reference_rates: bool) -> SweepSpec {
    SweepSpec {
        platform: "rtx2060".into(),
        duration_us,
        scenarios: mdtb_ad(duration_us),
        schedulers: schedulers.iter().map(|s| s.to_string()).collect(),
        seeds,
        trace: false,
        reference_rates,
    }
}

fn print_cells(mode: &str, report: &SweepReport) {
    for c in &report.cells {
        println!("{:<12} {:<8} {:<12} {:>9} {:>10} {:>9.3} {:>12.0}",
                 mode, c.scenario, c.scheduler, c.launches, c.events,
                 c.wall_ns as f64 / 1e9, c.events_per_sec());
    }
}

fn cells_json(out: &mut String, mode: &str, report: &SweepReport,
              first: &mut bool) {
    for c in &report.cells {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        let _ = write!(
            out,
            "    {{\"mode\": \"{}\", \"workload\": \"{}\", \
             \"scheduler\": \"{}\", \"launches\": {}, \"events\": {}, \
             \"wall_s\": {:.6}, \"events_per_sec\": {:.1}}}",
            mode, c.scenario, c.scheduler, c.launches, c.events,
            c.wall_ns as f64 / 1e9, c.events_per_sec()
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    // 2 simulated seconds of closed-loop MDTB traffic drives >10k launches
    // across the scheduler grid; smoke mode only proves the harness runs.
    let duration_us = if smoke { 30_000.0 } else { 2_000_000.0 };
    println!("# engine_throughput: {}s simulated per cell{}",
             duration_us / 1e6, if smoke { " (smoke)" } else { "" });
    println!("{:<12} {:<8} {:<12} {:>9} {:>10} {:>9} {:>12}",
             "mode", "wl", "scheduler", "launches", "events", "wall(s)",
             "events/s");

    // ---- leg 1: rate model, before/after -------------------------------
    let refr = run_sweep(&grid_spec(duration_us, &SCHEDULERS, 1, true), 1)
        .expect("reference sweep");
    print_cells("reference", &refr);
    let incr = run_sweep(&grid_spec(duration_us, &SCHEDULERS, 1, false), 1)
        .expect("incremental sweep");
    print_cells("incremental", &incr);
    let before = refr.events_per_sec();
    let after = incr.events_per_sec();
    let speedup = after / before.max(1e-12);
    let total_launches: usize = incr.cells.iter().map(|c| c.launches).sum();
    println!("\ntotal launches (incremental leg): {total_launches}");
    println!("aggregate events/s: reference {before:.0}, \
              incremental {after:.0}, speedup {speedup:.2}x");

    // ---- leg 2: coordinator in the loop --------------------------------
    let coord = run_sweep(
        &grid_spec(duration_us, &["miriam-ref", "miriam"], 1, false), 1)
        .expect("coordinator sweep");
    print_cells("coordinator", &coord);
    let coord_ref = coord.events_per_sec_for("miriam-ref");
    let coord_fast = coord.events_per_sec_for("miriam");
    let coord_gain = coord_fast / coord_ref.max(1e-12) - 1.0;
    println!("coordinator leg: miriam {coord_fast:.0} events/s vs \
              miriam-ref {coord_ref:.0} ({:+.1}%)", coord_gain * 100.0);

    // ---- leg 3: sweep scaling (threads 1 vs all cores) -----------------
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let scale_dur = if smoke { 20_000.0 } else { 400_000.0 };
    let scale_seeds = if smoke { 2 } else { 4 };
    let sspec = grid_spec(scale_dur, &SCHEDULERS, scale_seeds, false);
    let s1 = run_sweep(&sspec, 1).expect("scaling sweep, 1 thread");
    let sn = run_sweep(&sspec, max_threads).expect("scaling sweep, N threads");
    let scale = s1.wall_s / sn.wall_s.max(1e-12);
    println!("sweep scaling: {} cells, wall {:.3}s @1 thread vs {:.3}s \
              @{max_threads} threads ({scale:.2}x)",
             s1.cells.len(), s1.wall_s, sn.wall_s);

    // ---- BENCH_engine.json ---------------------------------------------
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"engine_throughput\",");
    let _ = writeln!(j, "  \"platform\": \"rtx2060\",");
    let _ = writeln!(j, "  \"duration_us\": {duration_us},");
    let _ = writeln!(j, "  \"smoke\": {smoke},");
    let _ = writeln!(j, "  \"total_launches\": {total_launches},");
    let _ = writeln!(j, "  \"events_per_sec_reference\": {before:.1},");
    let _ = writeln!(j, "  \"events_per_sec_incremental\": {after:.1},");
    let _ = writeln!(j, "  \"speedup\": {speedup:.3},");
    let _ = writeln!(j, "  \"coordinator\": {{\"events_per_sec_ref\": \
                          {coord_ref:.1}, \"events_per_sec_fast\": \
                          {coord_fast:.1}, \"improvement\": \
                          {coord_gain:.4}}},");
    let _ = writeln!(j, "  \"sweep_scaling\": {{\"cells\": {}, \
                          \"threads\": {max_threads}, \"wall_s_1\": {:.4}, \
                          \"wall_s_n\": {:.4}, \"speedup\": {scale:.3}}},",
                     s1.cells.len(), s1.wall_s, sn.wall_s);
    j.push_str("  \"cells\": [\n");
    let mut first = true;
    cells_json(&mut j, "reference", &refr, &mut first);
    cells_json(&mut j, "incremental", &incr, &mut first);
    cells_json(&mut j, "coordinator", &coord, &mut first);
    j.push_str("\n  ]\n}\n");
    std::fs::write("BENCH_engine.json", &j).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}
