//! Engine event-throughput benchmark (EXPERIMENTS.md §Perf change #4).
//!
//! Drives ~10k launches of MDTB-shaped kernels (MDTB-A and MDTB-D,
//! closed-loop critical + normal sources) through every scheduler, twice:
//!
//! * `reference`  — the retained full-recompute rate model, the seed's
//!   O(events × resident) per-event algorithm ("before");
//! * `incremental` — the O(Δ)-per-event aggregate path ("after").
//!
//! Reports per-cell launches, events, wall time and events/sec, plus the
//! aggregate speedup, and writes everything as JSON to `BENCH_engine.json`
//! so the perf trajectory is tracked from this PR onward.
//!
//! Run: `cargo bench --bench engine_throughput`
//! CI smoke mode (short duration): append `-- --smoke` (or set
//! `BENCH_SMOKE=1`).

use std::fmt::Write as _;
use std::time::Instant;

use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::{scheduler_for, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::mdtb;

struct Cell {
    mode: &'static str,
    workload: String,
    scheduler: &'static str,
    launches: usize,
    events: u64,
    wall_s: f64,
    events_per_sec: f64,
}

fn run_cell(mode: &'static str, wl_name: &str, sched: &'static str,
            duration_us: f64) -> Cell {
    let wl = mdtb::by_name(wl_name, duration_us).unwrap().build();
    let mut s = scheduler_for(sched, &wl).unwrap();
    let opts = RunOpts { reference_rates: mode == "reference", trace: false };
    let t0 = Instant::now();
    let st = driver::run_with(GpuSpec::rtx2060(), &wl, s.as_mut(), opts);
    let wall_s = t0.elapsed().as_secs_f64();
    Cell {
        mode,
        workload: format!("MDTB-{wl_name}"),
        scheduler: sched,
        launches: st.timeline.len(),
        events: st.events,
        wall_s,
        events_per_sec: st.events as f64 / wall_s.max(1e-12),
    }
}

fn aggregate_events_per_sec(cells: &[Cell], mode: &str) -> f64 {
    let (events, wall) = cells
        .iter()
        .filter(|c| c.mode == mode)
        .fold((0u64, 0.0f64), |(e, w), c| (e + c.events, w + c.wall_s));
    events as f64 / wall.max(1e-12)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    // 2 simulated seconds of closed-loop MDTB traffic drives >10k launches
    // across the scheduler grid; smoke mode only proves the harness runs.
    let duration_us = if smoke { 30_000.0 } else { 2_000_000.0 };
    println!("# engine_throughput: {}s simulated per cell{}",
             duration_us / 1e6, if smoke { " (smoke)" } else { "" });
    println!("{:<12} {:<8} {:<12} {:>9} {:>10} {:>9} {:>12}",
             "mode", "wl", "scheduler", "launches", "events", "wall(s)",
             "events/s");

    let mut cells = Vec::new();
    for mode in ["reference", "incremental"] {
        for wl in ["A", "D"] {
            for sched in SCHEDULERS {
                let c = run_cell(mode, wl, sched, duration_us);
                println!("{:<12} {:<8} {:<12} {:>9} {:>10} {:>9.3} {:>12.0}",
                         c.mode, c.workload, c.scheduler, c.launches,
                         c.events, c.wall_s, c.events_per_sec);
                cells.push(c);
            }
        }
    }

    let total_launches: usize = cells
        .iter()
        .filter(|c| c.mode == "incremental")
        .map(|c| c.launches)
        .sum();
    let before = aggregate_events_per_sec(&cells, "reference");
    let after = aggregate_events_per_sec(&cells, "incremental");
    let speedup = after / before.max(1e-12);
    println!("\ntotal launches (incremental leg): {total_launches}");
    println!("aggregate events/s: reference {before:.0}, \
              incremental {after:.0}, speedup {speedup:.2}x");

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut j = String::new();
    j.push_str("{\n");
    let _ = writeln!(j, "  \"bench\": \"engine_throughput\",");
    let _ = writeln!(j, "  \"platform\": \"rtx2060\",");
    let _ = writeln!(j, "  \"duration_us\": {duration_us},");
    let _ = writeln!(j, "  \"smoke\": {smoke},");
    let _ = writeln!(j, "  \"total_launches\": {total_launches},");
    let _ = writeln!(j, "  \"events_per_sec_reference\": {before:.1},");
    let _ = writeln!(j, "  \"events_per_sec_incremental\": {after:.1},");
    let _ = writeln!(j, "  \"speedup\": {speedup:.3},");
    j.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        let _ = write!(
            j,
            "    {{\"mode\": \"{}\", \"workload\": \"{}\", \
             \"scheduler\": \"{}\", \"launches\": {}, \"events\": {}, \
             \"wall_s\": {:.6}, \"events_per_sec\": {:.1}}}",
            c.mode, c.workload, c.scheduler, c.launches, c.events, c.wall_s,
            c.events_per_sec
        );
        j.push_str(if i + 1 < cells.len() { ",\n" } else { "\n" });
    }
    j.push_str("  ]\n}\n");
    std::fs::write("BENCH_engine.json", &j).expect("write BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}
