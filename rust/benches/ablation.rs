//! Ablations of Miriam's design choices (DESIGN.md calls these out):
//!
//!  1. **pad fill fraction** — how much of the intra-SM leftover elastic
//!     blocks may take (Eq. 2's "not too much"): sweeps the
//!     latency/throughput trade-off that motivates the WIScore balance.
//!  2. **dynamic vs static sharding** — the shaded binary tree re-sizes
//!     every shard against the *current* critical context; the static
//!     ablation fixes one candidate offline (what §7 argues against).
//!  3. **beyond pair-wise co-running** (paper §9 scalability): MDTB-A
//!     extended with a second normal source.
//!
//! Run: `cargo bench --bench ablation`

use std::sync::Arc;

use miriam::coordinator::{driver, scheduler_for, Miriam};
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::arrival::Arrival;
use miriam::workloads::mdtb::{self, Source, Workload};
use miriam::workloads::models;

fn main() {
    let spec = GpuSpec::rtx2060();
    let duration = 800_000.0;

    // ----- (1) pad fill fraction sweep -----------------------------------
    println!("# ablation 1: Miriam pad_fill_frac (MDTB-A, rtx2060)");
    println!("{:>6} {:>10} {:>12} {:>8}", "fill", "crit(ms)", "tput(req/s)",
             "occup");
    let wl = mdtb::mdtb_a(duration).build();
    let crit_models: Vec<_> = wl
        .sources
        .iter()
        .filter(|s| s.criticality == Criticality::Critical)
        .map(|s| s.model.clone())
        .collect();
    for fill in [0.25, 0.5, 0.6, 0.75, 1.0] {
        let mut m = Miriam::new(&crit_models).with_fill(fill);
        let st = driver::run(spec.clone(), &wl, &mut m);
        println!("{:>6.2} {:>10.2} {:>12.1} {:>8.3}", fill,
                 st.critical_latency_mean_us() / 1e3, st.throughput_rps(),
                 st.achieved_occupancy);
    }
    println!("# low fill protects latency but throttles padding; high fill");
    println!("# converges to multistream behaviour — the Eq. 2/WIScore");
    println!("# middle ground is the design point.\n");

    // ----- (2) dynamic vs static sharding --------------------------------
    println!("# ablation 2: dynamic (shaded-tree) vs static sharding (MDTB-A)");
    println!("{:<22} {:>10} {:>12}", "variant", "crit(ms)", "tput(req/s)");
    for (label, static_shards) in [("dynamic (paper §7)", false),
                                   ("static one-candidate", true)] {
        let mut m = Miriam::new(&crit_models).with_static_sharding(static_shards);
        let st = driver::run(spec.clone(), &wl, &mut m);
        println!("{:<22} {:>10.2} {:>12.1}", label,
                 st.critical_latency_mean_us() / 1e3, st.throughput_rps());
    }
    println!("# static sharding cannot adapt when the co-resident critical");
    println!("# kernel changes mid-kernel — §7's motivating failure mode.\n");

    // ----- (3) beyond pair-wise co-running (paper §9) ---------------------
    println!("# ablation 3: scalability beyond pair-wise (MDTB-A + squeezenet)");
    let wl3 = Workload {
        name: "A+squeezenet".into(),
        sources: vec![
            Source {
                model: Arc::new(models::alexnet()),
                arrival: Arrival::ClosedLoop { clients: 1 },
                criticality: Criticality::Critical,
                deadline_us: None,
            },
            Source {
                model: Arc::new(models::cifarnet()),
                arrival: Arrival::ClosedLoop { clients: 2 },
                criticality: Criticality::Normal,
                deadline_us: None,
            },
            Source {
                model: Arc::new(models::squeezenet()),
                arrival: Arrival::ClosedLoop { clients: 1 },
                criticality: Criticality::Normal,
                deadline_us: None,
            },
        ],
        duration_us: duration,
        seed: 0x3A,
    };
    println!("{:<12} {:>10} {:>12} {:>8}", "scheduler", "crit(ms)",
             "tput(req/s)", "occup");
    for sched in ["sequential", "multistream", "miriam"] {
        let mut s = scheduler_for(sched, &wl3).unwrap();
        let st = driver::run(spec.clone(), &wl3, s.as_mut());
        println!("{:<12} {:>10.2} {:>12.1} {:>8.3}", sched,
                 st.critical_latency_mean_us() / 1e3, st.throughput_rps(),
                 st.achieved_occupancy);
    }
    println!("# miriam's queue-order padding generalizes to >1 normal source");
    println!("# (paper §9's scalability discussion).");
}
