//! §8.6 — system overhead microbenchmarks.
//!
//! Two costs the paper reports:
//!  1. runtime elastic-kernel shard selection (an O(N) scan over shard
//!     candidates): average <0.35 ms per model served;
//!  2. extra launch-time overhead imposed on critical kernels by padding:
//!     <15 us in over 80% of cases.
//!
//! We measure (1) directly on the host (the same data structure scan the
//! real coordinator runs) and (2) from the simulated MDTB-A run by
//! comparing per-critical-kernel latency with and without padding.
//!
//! Run: `cargo bench --bench overhead_sched`

use std::sync::Arc;
use std::time::Instant;

use miriam::coordinator::shaded_tree::{Leftover, ShadedTree};
use miriam::coordinator::{driver, scheduler_for};
use miriam::elastic::shrink::{CriticalProfile, ShrinkConfig};
use miriam::elastic::ElasticKernel;
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::{mdtb, models};

fn quantile(sorted: &[f64], q: f64) -> f64 {
    let pos = q * (sorted.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
}

fn main() {
    let spec = GpuSpec::rtx2060();
    let cfg = ShrinkConfig::default();

    // ----- (1) shard-selection decision latency, per model ---------------
    println!("# §8.6 (1): runtime shard-selection decision cost per model");
    println!("{:<12} {:>9} {:>12} {:>12} {:>12}",
             "model", "kernels", "mean(us)", "p99(us)", "per-model(us)");
    let crits: Vec<CriticalProfile> = models::by_name("alexnet")
        .unwrap()
        .kernels
        .iter()
        .map(CriticalProfile::from_kernel)
        .collect();
    for name in models::MDTB_MODELS {
        let model = models::by_name(name).unwrap();
        // Offline part (excluded from the runtime cost, as in the paper).
        let elastic: Vec<ElasticKernel> = model
            .kernels
            .iter()
            .map(|k| ElasticKernel::generate(k.clone(), &crits, &spec, &cfg))
            .collect();
        let left = Leftover { blocks: 11, threads: 256, critical_active: true };
        // Timed part: carve every shard of every kernel (the O(N) candidate
        // scan §8.6 describes), repeated for stable statistics.
        let iters = 50;
        let shared: Vec<Arc<ElasticKernel>> =
            elastic.iter().cloned().map(Arc::new).collect();
        let mut samples = Vec::new();
        for _ in 0..iters {
            let t0 = Instant::now();
            let mut shards = 0u64;
            for ek in &shared {
                let mut tree = ShadedTree::new(ek.clone());
                while let Some(s) = tree.next_shard(&left) {
                    shards += 1;
                    tree.shard_done(s.shape.grid);
                }
            }
            let dt = t0.elapsed().as_secs_f64() * 1e6;
            samples.push(dt / shards.max(1) as f64); // per decision
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        // Decisions per served model ~ shards per inference.
        let mut tree_total = 0u64;
        for ek in &shared {
            let mut tree = ShadedTree::new(ek.clone());
            while let Some(s) = tree.next_shard(&left) {
                tree_total += 1;
                tree.shard_done(s.shape.grid);
            }
        }
        println!("{:<12} {:>9} {:>12.3} {:>12.3} {:>12.1}",
                 name, model.kernels.len(), mean,
                 quantile(&samples, 0.99),
                 mean * tree_total as f64);
    }
    println!("# paper bound: < 350 us per served model\n");

    // ----- (2) padding-induced critical launch overhead ------------------
    println!("# §8.6 (2): padding overhead on critical kernels (MDTB-A sim)");
    let duration = 400_000.0;
    let wl = mdtb::mdtb_a(duration).build();
    let mut seq = scheduler_for("sequential", &wl).unwrap();
    let solo = driver::run(spec.clone(), &wl, seq.as_mut());
    let mut mir = scheduler_for("miriam", &wl).unwrap();
    let padded = driver::run(spec.clone(), &wl, mir.as_mut());

    // Per-kernel-name mean duration of critical kernels, with/without pads.
    let mut names: Vec<String> = models::alexnet()
        .kernels
        .iter()
        .map(|k| k.name.clone())
        .collect();
    names.dedup();
    let mean_dur = |st: &miriam::coordinator::RunStats, name: &str| {
        let v: Vec<f64> = st
            .timeline
            .iter()
            .filter(|r| r.name == name
                && r.criticality == Criticality::Critical)
            .map(|r| r.end_us - r.start_us)
            .collect();
        if v.is_empty() { f64::NAN } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let mut overheads = Vec::new();
    println!("{:<20} {:>10} {:>10} {:>12}",
             "critical kernel", "alone(us)", "padded(us)", "overhead(us)");
    for n in &names {
        let a = mean_dur(&solo, n);
        let b = mean_dur(&padded, n);
        if a.is_nan() || b.is_nan() {
            continue;
        }
        let ov = b - a;
        overheads.push(ov);
        println!("{:<20} {:>10.1} {:>10.1} {:>12.1}", n, a, b, ov);
    }
    overheads.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let under = overheads.iter().filter(|o| **o < 15.0).count();
    println!("\n# {}/{} kernels with < 15us padding overhead \
              (paper: >80% of cases)", under, overheads.len());
    println!("# (negative overhead = padding-neutral; the sim's whole-kernel");
    println!("#  granularity folds queueing noise into the comparison)");
}
