//! Request-level fault injection against the self-healing execution
//! layer (ISSUE 8).
//!
//! One leg: the duo-burst and five-storm family scenarios served across
//! the default rtx2060 + xavier + tx2 fleet under every fault-storm
//! preset (`none` baseline, `flaky-launches`, `straggler-swarm`,
//! `bitflip-storm`, `full-fault-storm`) and every router. Per cell the
//! table reports the served/cancelled split, retries, hedges and hedge
//! wins, breaker trips, and critical p99; the summary compares each
//! fault column against the same (scenario, router) cell under `none` —
//! the critical-p99 degradation the recovery layer (retries, hedged
//! re-launches, deadline-aware cancellation, circuit breakers, elastic
//! brownout) is built to bound.
//!
//! Hard gates (exit 1), not remarks:
//!   * extended conservation on every cell — `offered == admitted +
//!     shed` and `admitted == served + lost + cancelled`;
//!   * every device stays live under pure fault injection, so
//!     `lost == 0` and `routed == admitted` everywhere;
//!   * critical tenants are never shed and **never cancelled**;
//!   * hedge winners are counted at most once (`hedge_wins <= hedges`);
//!   * breaker ledgers agree — device `breaker_trips` sums to the
//!     fleet total.
//!
//! Writes `BENCH_faults.json` (canonical, byte-deterministic per seed
//! and across worker threads — schema in EXPERIMENTS.md §Faults). CI
//! smoke mode: append `-- --smoke` (or set `BENCH_SMOKE=1`).

use miriam::fleet::{
    faults, run_faults_grid, FaultSpec, FleetOpts, FleetSpec, FAULT_STORMS,
    ROUTERS,
};
use miriam::workloads::scenario;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 20_000.0 } else { 200_000.0 };
    let fleet = FleetSpec::parse(
        &["rtx2060".into(), "xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .expect("default fleet parses");
    let scenarios = vec![
        scenario::by_name("duo-burst", duration_us)
            .expect("duo-burst is a family scenario"),
        scenario::by_name("five-storm", duration_us)
            .expect("five-storm is a family scenario"),
    ];
    let specs: Vec<FaultSpec> = FAULT_STORMS
        .iter()
        .map(|name| faults::storm(name).expect("preset exists"))
        .collect();
    let routers: Vec<String> = ROUTERS.iter().map(|r| r.to_string()).collect();
    let opts = FleetOpts::default();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("# faults: {} scenarios x {} fault scripts x {} routers on {} \
              devices, {}s of arrivals per cell, {threads} thread(s){}",
             scenarios.len(), specs.len(), routers.len(),
             fleet.devices.len(), duration_us / 1e6,
             if smoke { " (smoke)" } else { "" });
    println!("{:<12} {:<18} {:<22} {:>8} {:>7} {:>6} {:>5} {:>7} {:>6} \
              {:>10}",
             "scenario", "faults", "router", "served", "retries", "hedges",
             "wins", "cancel", "trips", "crit p99");
    println!("{:<12} {:<18} {:<22} {:>8} {:>7} {:>6} {:>5} {:>7} {:>6} \
              {:>10}",
             "", "", "", "", "", "", "", "", "", "(ms)");

    let grid = run_faults_grid(&fleet, &scenarios, &specs, &routers, &opts,
                               threads)
        .expect("faults grid");
    let mut conserved = true;
    let mut live = true;
    let mut crit_kept = true;
    let mut hedged_once = true;
    let mut ledgers = true;
    for c in &grid.cells {
        conserved &= c.offered() == c.admitted() + c.shed()
            && c.admitted() == c.served() + c.lost() + c.cancelled();
        live &= c.lost() == 0 && c.routed() == c.admitted();
        crit_kept &= c.shed_critical() == 0 && c.critical_cancelled() == 0;
        hedged_once &= c.hedge_wins() <= c.hedges();
        ledgers &= c.devices.iter().map(|d| d.breaker_trips).sum::<u64>()
            == c.breaker_trips();
        println!("{:<12} {:<18} {:<22} {:>8} {:>7} {:>6} {:>5} {:>7} {:>6} \
                  {:>10.2}",
                 c.scenario, c.fault_script, c.router, c.served(),
                 c.retries(), c.hedges(), c.hedge_wins(), c.cancelled(),
                 c.breaker_trips(), c.crit_p99_us() / 1e3);
    }

    // Fault impact vs the calm baseline, per (scenario, router) — the
    // hedging-effectiveness read: how far each storm pushes critical
    // p99 with the full recovery layer answering it.
    println!("\n{:<12} {:<22} {:>10} {:>10} {:>12} {:>10} {:>12}",
             "scenario", "router", "calm p99", "flaky", "stragglers",
             "bitflips", "full storm");
    println!("{:<12} {:<22} {:>10} {:>10} {:>12} {:>10} {:>12}",
             "", "", "(ms)", "(x calm)", "(x calm)", "(x calm)",
             "(x calm)");
    for sc in &grid.scenarios {
        for r in &grid.routers {
            let cell =
                |script: &str| grid.cell(sc, script, r).expect("cell ran");
            let calm = cell("none").crit_p99_us();
            let degr = |script: &str| cell(script).crit_p99_us() / calm;
            println!("{:<12} {:<22} {:>10.2} {:>10.2} {:>12.2} {:>10.2} \
                      {:>12.2}",
                     sc, r, calm / 1e3,
                     degr("flaky-launches"),
                     degr("straggler-swarm"),
                     degr("bitflip-storm"),
                     degr("full-fault-storm"));
        }
    }
    println!("\nextended conservation on every cell: {}",
             if conserved { "yes" } else { "NO" });
    println!("nothing lost with every device live: {}",
             if live { "yes" } else { "NO" });
    println!("critical never shed, never cancelled: {}",
             if crit_kept { "yes" } else { "NO" });
    println!("hedge winners counted at most once: {}",
             if hedged_once { "yes" } else { "NO" });
    println!("breaker ledgers agree: {}",
             if ledgers { "yes" } else { "NO" });

    std::fs::write("BENCH_faults.json", grid.to_json())
        .expect("write BENCH_faults.json");
    println!("wrote BENCH_faults.json");

    if !(conserved && live && crit_kept && hedged_once && ledgers) {
        std::process::exit(1);
    }
}
