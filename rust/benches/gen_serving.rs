//! Generation-serving comparison across the gen scenario family
//! (ISSUE 10).
//!
//! One leg: every gen scenario served through the live coordinator
//! under each admission policy, plus the three reference cells the grid
//! adds per scenario — `solo` (criticals alone, the TTFT yardstick),
//! `sequential` (no elastic sharing) and `batched` (decode-aware
//! continuous batching). Per cell the table reports the SLO split,
//! token throughput, eviction/recompute traffic and critical TTFT
//! quantiles; a summary line per scenario states the acceptance
//! comparison — under `deadline-feasible` admission, criticals' TTFT
//! p99 must stay within 1.10x of their solo-run TTFT p99.
//!
//! Unconditional invariants (token conservation, criticals never
//! evicted, zero TTFT>latency violations) are asserted on every cell;
//! any failure exits non-zero so the CI step fails.
//!
//! Writes `BENCH_gen.json` (canonical, byte-deterministic per seed and
//! across thread counts — schema in EXPERIMENTS.md §Generation). CI
//! smoke mode: append `-- --smoke` (or set `BENCH_SMOKE=1`).

use miriam::coordinator::admission::{AdmissionPolicy, POLICIES};
use miriam::gpu::spec::GpuSpec;
use miriam::server::gen::{run_gen_grid, GenOpts};
use miriam::workloads::generation;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 40_000.0 } else { 200_000.0 };
    let gpu = GpuSpec::rtx2060();
    let scenarios = generation::gen_family(duration_us);
    let opts = GenOpts::default();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("# gen_serving: {} scenarios x {} policies (+solo/sequential/\
              batched), {}s of arrivals per cell{}",
             scenarios.len(), POLICIES.len(), duration_us / 1e6,
             if smoke { " (smoke)" } else { "" });
    println!("{:<14} {:<11} {:<18} {:>7} {:>6} {:>8} {:>6} {:>9} {:>9} {:>9}",
             "scenario", "kind", "policy", "admit", "shed", "tokens",
             "evict", "ttft p99", "gap p99", "tok/s");
    println!("{:<14} {:<11} {:<18} {:>7} {:>6} {:>8} {:>6} {:>9} {:>9} {:>9}",
             "", "", "", "", "", "", "", "(ms)", "(ms)", "");

    let grid = run_gen_grid(&gpu, &scenarios, &POLICIES, &opts, threads)
        .expect("gen grid");
    let mut invariants_ok = true;
    for c in &grid.cells {
        println!("{:<14} {:<11} {:<18} {:>7} {:>6} {:>8} {:>6} {:>9.2} \
                  {:>9.2} {:>9.0}",
                 c.scenario, c.kind, c.policy.name(), c.admitted(), c.shed(),
                 c.tokens, c.evictions, c.crit_ttft_p99_us() / 1e3,
                 c.inter_token_quantile_us(0.99) / 1e3, c.tokens_per_sec());
        // Unconditional invariants — hold for every cell of every run.
        for (name, ok) in [
            ("token conservation", c.tokens == c.drawn_tokens),
            ("criticals never evicted", c.critical_evictions() == 0),
            ("TTFT <= e2e latency", c.ttft_violations == 0),
            ("accounting balance", c.offered() == c.admitted() + c.shed()),
            ("recompute == evicted prefix",
             c.recompute_tokens == c.evicted_prefix_tokens),
        ] {
            if !ok {
                println!("INVARIANT VIOLATED [{}/{}/{}]: {name}",
                         c.scenario, c.kind, c.policy.name());
                invariants_ok = false;
            }
        }
    }

    // Acceptance comparison: deadline-feasible TTFT p99 vs solo run.
    println!("\n{:<14} {:>14} {:>14} {:>8} {:>12} {:>12}",
             "scenario", "ttft feas(ms)", "ttft solo(ms)", "ok",
             "tok/s miriam", "tok/s batch");
    let mut all_ok = true;
    for sc in &grid.scenarios {
        let feas = grid
            .cell(sc, "policy", Some(AdmissionPolicy::DeadlineFeasible))
            .expect("deadline-feasible cell");
        let solo = grid
            .cell(&format!("{sc}-solo"), "solo", None)
            .expect("solo cell");
        let bat = grid.cell(sc, "batched", None).expect("batched cell");
        let p_mixed = feas.crit_ttft_p99_us();
        let p_solo = solo.crit_ttft_p99_us();
        // NaN-tolerant: a cell with zero critical completions (possible
        // in very short smoke windows) compares as ok. The 10% + 5us
        // slack is the ISSUE 10 acceptance bound.
        let ok = !(p_mixed.is_finite() && p_solo.is_finite())
            || p_mixed <= p_solo * 1.10 + 5.0;
        all_ok &= ok;
        println!("{:<14} {:>14.2} {:>14.2} {:>8} {:>12.0} {:>12.0}",
                 sc, p_mixed / 1e3, p_solo / 1e3,
                 if ok { "yes" } else { "NO" },
                 feas.tokens_per_sec(), bat.tokens_per_sec());
    }
    println!("\ncritical TTFT p99 within 1.10x of solo on every scenario: \
              {}",
             if all_ok { "yes" } else { "NO" });

    std::fs::write("BENCH_gen.json", grid.to_json())
        .expect("write BENCH_gen.json");
    println!("wrote BENCH_gen.json");

    // Both the invariants and the TTFT acceptance comparison are gates,
    // not remarks: a run violating either must fail the CI step.
    if !all_ok || !invariants_ok {
        std::process::exit(1);
    }
}
