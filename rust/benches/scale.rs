//! Scale-sim bench (ISSUE 7): the event core at 1k → 100k tenants.
//!
//! Each cell runs a tiered-tenant `ScaleSpec` population through the
//! live coordinator on lazy arrival streams, a hierarchical timing
//! wheel, and P² streaming quantile sketches. The table reports SLO
//! outcomes plus the two numbers the tentpole exists for: host-side
//! engine events/sec (O(1)-amortized dispatch, stdout only) and
//! latency-accounting bytes per tenant (constant under the sketch).
//!
//! Writes `BENCH_scale.json` (canonical, byte-deterministic per
//! tenant-count list — no host timing in the document; schema in
//! EXPERIMENTS.md §Scale). CI smoke mode: append `-- --smoke` (or set
//! `BENCH_SMOKE=1`).

use std::time::Instant;

use miriam::gpu::spec::GpuSpec;
use miriam::server::scale::run_scale_grid;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 25_000.0 } else { 500_000.0 };
    let counts: &[usize] =
        if smoke { &[1000, 5000] } else { &[1000, 10_000, 100_000] };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let gpu = GpuSpec::rtx2060();

    println!("# scale: {} tenant counts, {}s of arrivals per cell, \
              {threads} threads{}",
             counts.len(), duration_us / 1e6,
             if smoke { " (smoke)" } else { "" });

    let t0 = Instant::now();
    let grid = run_scale_grid(&gpu, counts, duration_us, "miriam", threads)
        .expect("scale grid");
    let wall = t0.elapsed().as_secs_f64();

    println!("{:>8} {:>9} {:>9} {:>7} {:>8} {:>9} {:>11}",
             "tenants", "offered", "served", "miss", "sketch", "B/tenant",
             "worst p99");
    println!("{:>8} {:>9} {:>9} {:>7} {:>8} {:>9} {:>11}",
             "", "", "", "", "", "", "(ms)");
    let mut events: u64 = 0;
    let mut ok = true;
    for c in &grid.cells {
        events += c.events;
        let p99 = if c.worst_tenant_p99_us.is_finite() {
            format!("{:.2}", c.worst_tenant_p99_us / 1e3)
        } else {
            "-".to_string()
        };
        println!("{:>8} {:>9} {:>9} {:>7} {:>8} {:>9.0} {:>11}",
                 c.tenants, c.offered, c.served, c.deadline_misses,
                 c.sketch_tenants, c.bytes_per_tenant, p99);
        // The constant-memory contract: per-tenant accounting never
        // grows past a few hundred bytes, however many requests ran.
        ok &= c.sketch_tenants == c.tenants;
        ok &= c.bytes_per_tenant <= 512.0;
        ok &= c.served > 0;
    }
    // Host-side throughput stays on stdout so the JSON document remains
    // byte-deterministic.
    println!("\n# {events} engine events in {wall:.2}s wall \
              ({:.0} events/sec)",
             events as f64 / wall.max(1e-9));

    std::fs::write("BENCH_scale.json", grid.to_json())
        .expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");

    println!("every cell sketched and served under the constant-memory \
              contract: {}",
             if ok { "yes" } else { "NO" });
    if !ok {
        std::process::exit(1);
    }
}
