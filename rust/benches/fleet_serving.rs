//! Router comparison across the 8-scenario family on a heterogeneous
//! fleet (ISSUE 5).
//!
//! One leg: every family scenario served across the default
//! rtx2060 + xavier + tx2 fleet (Miriam on every device) under each
//! router — `round-robin` baseline, `least-outstanding-work`,
//! `criticality-affinity`. Per cell the table reports the SLO split,
//! fleet-level critical p50/p99, critical deadline misses, and fleet
//! throughput; the summary compares each router against the round-robin
//! baseline per scenario (critical p99 and misses — the placement win
//! the ISSUE 5 motivation predicts), and a conservation gate checks
//! `routed == admitted` on every cell.
//!
//! Writes `BENCH_fleet.json` (canonical, byte-deterministic per seed and
//! across worker threads — schema in EXPERIMENTS.md §Fleet). CI smoke
//! mode: append `-- --smoke` (or set `BENCH_SMOKE=1`).

use miriam::fleet::{run_fleet_grid, FleetOpts, FleetSpec, ROUTERS};
use miriam::workloads::scenario;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 20_000.0 } else { 300_000.0 };
    let fleet = FleetSpec::parse(
        &["rtx2060".into(), "xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .expect("default fleet parses");
    let scenarios = scenario::family(duration_us);
    let routers: Vec<String> = ROUTERS.iter().map(|r| r.to_string()).collect();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("# fleet_serving: {} scenarios x {} routers on {} devices, \
              {}s of arrivals per cell, {threads} thread(s){}",
             scenarios.len(), routers.len(), fleet.devices.len(),
             duration_us / 1e6, if smoke { " (smoke)" } else { "" });
    println!("{:<16} {:<22} {:>8} {:>6} {:>8} {:>10} {:>10} {:>6} {:>9}",
             "scenario", "router", "offered", "shed", "served", "crit p50",
             "crit p99", "miss", "fleet r/s");
    println!("{:<16} {:<22} {:>8} {:>6} {:>8} {:>10} {:>10} {:>6} {:>9}",
             "", "", "", "", "", "(ms)", "(ms)", "(crit)", "");

    let grid = run_fleet_grid(&fleet, &scenarios, &routers,
                              &FleetOpts::default(), threads)
        .expect("fleet grid");
    let mut conserved = true;
    for c in &grid.cells {
        conserved &= c.routed() == c.admitted();
        println!("{:<16} {:<22} {:>8} {:>6} {:>8} {:>10.2} {:>10.2} {:>6} \
                  {:>9.1}",
                 c.scenario, c.router, c.offered(), c.shed(), c.served(),
                 c.crit_quantile_us(0.5) / 1e3,
                 c.crit_p99_us() / 1e3,
                 c.deadline_misses_critical(),
                 c.throughput_rps());
    }

    // Router comparison vs the round-robin placement baseline.
    println!("\n{:<16} {:>12} {:>12} {:>12} {:>8} {:>8} {:>8}",
             "scenario", "p99 rr(ms)", "p99 low(ms)", "p99 aff(ms)",
             "miss rr", "miss low", "miss aff");
    for sc in &grid.scenarios {
        let cell = |r: &str| grid.cell(sc, r).expect("cell ran");
        let rr = cell("round-robin");
        let low = cell("least-outstanding-work");
        let aff = cell("criticality-affinity");
        println!("{:<16} {:>12.2} {:>12.2} {:>12.2} {:>8} {:>8} {:>8}",
                 sc,
                 rr.crit_p99_us() / 1e3,
                 low.crit_p99_us() / 1e3,
                 aff.crit_p99_us() / 1e3,
                 rr.deadline_misses_critical(),
                 low.deadline_misses_critical(),
                 aff.deadline_misses_critical());
    }
    println!("\nrouted == admitted on every cell: {}",
             if conserved { "yes" } else { "NO" });

    std::fs::write("BENCH_fleet.json", grid.to_json())
        .expect("write BENCH_fleet.json");
    println!("wrote BENCH_fleet.json");

    // Conservation is a gate, not a remark: a run where a request was
    // lost or double-placed must fail the CI step.
    if !conserved {
        std::process::exit(1);
    }
}
