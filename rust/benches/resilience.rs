//! Fleet resilience under deterministic chaos (ISSUE 6).
//!
//! One leg: the flash-crowd stress scenario plus the heaviest family
//! member (five-storm) served across the default rtx2060 + xavier + tx2
//! fleet under every storm preset (`none` baseline, `straggler-storm`,
//! `rolling-outage`, `flash-crowd-outage`) and every router, with a tx2
//! standby pool armed behind the reactive autoscaler. Per cell the table
//! reports the served/requeued/lost split, critical p99, and recovery
//! time; the summary compares each storm column against the same
//! (scenario, router) cell under `none` — the critical-p99 degradation
//! the chaos layer is built to bound.
//!
//! Hard gates (exit 1), not remarks:
//!   * conservation on every cell — `offered == admitted + shed` and
//!     `admitted == served + lost`;
//!   * every storm preset heals, so `lost == 0` and `routed == admitted`
//!     everywhere;
//!   * critical tenants are never shed;
//!   * requeue ledgers agree — device `requeued_in` sums to tenant
//!     `requeues`.
//!
//! Writes `BENCH_resilience.json` (canonical, byte-deterministic per
//! seed and across worker threads — schema in EXPERIMENTS.md
//! §Resilience). CI smoke mode: append `-- --smoke` (or set
//! `BENCH_SMOKE=1`).

use miriam::fleet::{
    run_resilience_grid, AutoscaleConfig, FleetOpts, FleetSpec, ROUTERS,
    STORMS,
};
use miriam::workloads::scenario;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 20_000.0 } else { 200_000.0 };
    let fleet = FleetSpec::parse(
        &["rtx2060".into(), "xavier".into(), "tx2".into()],
        &["miriam".into()],
    )
    .expect("default fleet parses");
    let scenarios = vec![
        scenario::flash_crowd(duration_us),
        scenario::by_name("five-storm", duration_us)
            .expect("five-storm is a family scenario"),
    ];
    let storms: Vec<String> = STORMS.iter().map(|s| s.to_string()).collect();
    let routers: Vec<String> = ROUTERS.iter().map(|r| r.to_string()).collect();
    let opts = FleetOpts {
        autoscale: Some(AutoscaleConfig {
            pool: vec!["tx2".into()],
            ..AutoscaleConfig::default()
        }),
        ..FleetOpts::default()
    };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("# resilience: {} scenarios x {} storms x {} routers on {} \
              devices (+1 standby), {}s of arrivals per cell, {threads} \
              thread(s){}",
             scenarios.len(), storms.len(), routers.len(),
             fleet.devices.len(), duration_us / 1e6,
             if smoke { " (smoke)" } else { "" });
    println!("{:<12} {:<20} {:<22} {:>8} {:>8} {:>6} {:>10} {:>10}",
             "scenario", "storm", "router", "served", "requeues", "lost",
             "crit p99", "recovery");
    println!("{:<12} {:<20} {:<22} {:>8} {:>8} {:>6} {:>10} {:>10}",
             "", "", "", "", "", "", "(ms)", "(ms)");

    let grid = run_resilience_grid(&fleet, &scenarios, &storms, &routers,
                                   &opts, threads)
        .expect("resilience grid");
    let mut conserved = true;
    let mut healed = true;
    let mut crit_kept = true;
    let mut ledgers = true;
    for c in &grid.cells {
        conserved &= c.offered() == c.admitted() + c.shed()
            && c.admitted() == c.served() + c.lost();
        healed &= c.lost() == 0 && c.routed() == c.admitted();
        crit_kept &= c.shed_critical() == 0;
        ledgers &= c.devices.iter().map(|d| d.requeued_in).sum::<u64>()
            == c.requeues();
        let recovery = if c.recovery_us.is_nan() {
            "-".to_string()
        } else {
            format!("{:.2}", c.recovery_us / 1e3)
        };
        println!("{:<12} {:<20} {:<22} {:>8} {:>8} {:>6} {:>10.2} {:>10}",
                 c.scenario, c.chaos, c.router, c.served(), c.requeues(),
                 c.lost(), c.crit_p99_us() / 1e3, recovery);
    }

    // Storm impact vs the calm baseline, per (scenario, router).
    println!("\n{:<12} {:<22} {:>10} {:>12} {:>12} {:>12}",
             "scenario", "router", "calm p99", "straggler", "rolling",
             "flash+out");
    println!("{:<12} {:<22} {:>10} {:>12} {:>12} {:>12}",
             "", "", "(ms)", "(x calm)", "(x calm)", "(x calm)");
    for sc in &grid.scenarios {
        for r in &grid.routers {
            let cell = |storm: &str| {
                grid.cell(sc, storm, r).expect("cell ran")
            };
            let calm = cell("none").crit_p99_us();
            let degr = |storm: &str| cell(storm).crit_p99_us() / calm;
            println!("{:<12} {:<22} {:>10.2} {:>12.2} {:>12.2} {:>12.2}",
                     sc, r, calm / 1e3,
                     degr("straggler-storm"),
                     degr("rolling-outage"),
                     degr("flash-crowd-outage"));
        }
    }
    println!("\nconservation on every cell: {}",
             if conserved { "yes" } else { "NO" });
    println!("all storms heal (lost == 0, routed == admitted): {}",
             if healed { "yes" } else { "NO" });
    println!("critical tenants never shed: {}",
             if crit_kept { "yes" } else { "NO" });
    println!("requeue ledgers agree: {}",
             if ledgers { "yes" } else { "NO" });

    std::fs::write("BENCH_resilience.json", grid.to_json())
        .expect("write BENCH_resilience.json");
    println!("wrote BENCH_resilience.json");

    if !(conserved && healed && crit_kept && ledgers) {
        std::process::exit(1);
    }
}
