//! Admission-policy comparison across the 8-scenario family (ISSUE 4).
//!
//! One leg: the whole scenario family served through the live Miriam
//! coordinator under each admission policy (`none` baseline,
//! `token-bucket`, `deadline-feasible`). Per cell the table reports the
//! SLO split (offered/admitted/shed/served), critical p99 latency,
//! critical deadline misses, and best-effort throughput; a summary line
//! per scenario states the acceptance comparison — under
//! `deadline-feasible`, critical p99 must be no worse than the `none`
//! baseline (admission only trims best-effort load) while best-effort
//! throughput is reported per policy as the explicit trade.
//!
//! Writes `BENCH_serve.json` (canonical, byte-deterministic per seed —
//! schema in EXPERIMENTS.md §Serve). CI smoke mode: append `-- --smoke`
//! (or set `BENCH_SMOKE=1`).

use miriam::coordinator::admission::{AdmissionPolicy, POLICIES};
use miriam::gpu::spec::GpuSpec;
use miriam::server::online::{run_serve_grid, ServeOpts};
use miriam::workloads::scenario;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    let duration_us = if smoke { 25_000.0 } else { 500_000.0 };
    let gpu = GpuSpec::rtx2060();
    let scenarios = scenario::family(duration_us);
    let opts = ServeOpts::default();

    println!("# serve_online: {} scenarios x {} policies, {}s of arrivals \
              per cell{}",
             scenarios.len(), POLICIES.len(), duration_us / 1e6,
             if smoke { " (smoke)" } else { "" });
    println!("{:<16} {:<18} {:>8} {:>6} {:>8} {:>10} {:>6} {:>10}",
             "scenario", "policy", "offered", "shed", "served", "crit p99",
             "miss", "norm/s");
    println!("{:<16} {:<18} {:>8} {:>6} {:>8} {:>10} {:>6} {:>10}",
             "", "", "", "", "", "(ms)", "(crit)", "(req/s)");

    let grid = run_serve_grid(&gpu, &scenarios, &POLICIES, &opts)
        .expect("serve grid");
    for c in &grid.cells {
        println!("{:<16} {:<18} {:>8} {:>6} {:>8} {:>10.2} {:>6} {:>10.1}",
                 c.scenario, c.policy.name(), c.offered(), c.shed(),
                 c.served(), c.crit_p99_us() / 1e3,
                 c.deadline_misses_critical(), c.normal_throughput_rps());
    }

    // Acceptance comparison: deadline-feasible critical p99 vs baseline.
    println!("\n{:<16} {:>14} {:>14} {:>8} {:>12} {:>12}",
             "scenario", "p99 none(ms)", "p99 feas(ms)", "ok",
             "norm/s none", "norm/s feas");
    let mut all_ok = true;
    for sc in &grid.scenarios {
        let base = grid.cell(sc, AdmissionPolicy::Open).expect("baseline");
        let feas = grid
            .cell(sc, AdmissionPolicy::DeadlineFeasible)
            .expect("deadline-feasible cell");
        let p_base = base.crit_p99_us();
        let p_feas = feas.crit_p99_us();
        // NaN-tolerant: a cell with zero critical completions (possible in
        // very short smoke windows) compares as ok. The 5% + 5us slack
        // covers FP-level padding-interleaving noise; anything beyond it
        // is a real regression and fails the bench (and CI).
        let ok = !(p_feas.is_finite() && p_base.is_finite())
            || p_feas <= p_base * 1.05 + 5.0;
        all_ok &= ok;
        println!("{:<16} {:>14.2} {:>14.2} {:>8} {:>12.1} {:>12.1}",
                 sc, p_base / 1e3, p_feas / 1e3,
                 if ok { "yes" } else { "NO" },
                 base.normal_throughput_rps(), feas.normal_throughput_rps());
    }
    println!("\ndeadline-feasible critical p99 no worse than baseline on \
              every scenario: {}",
             if all_ok { "yes" } else { "NO" });

    std::fs::write("BENCH_serve.json", grid.to_json())
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");

    // The acceptance comparison is a gate, not a remark: a run where
    // admission control worsened critical p99 must fail the CI step.
    if !all_ok {
        std::process::exit(1);
    }
}
