//! Fig. 9 — in-depth analysis: two AlexNet instances, one critical and one
//! normal, both closed-loop on the RTX 2060. Upper: kernel-activity
//! timeline (Miriam's elastic shards pad tightly around critical kernels);
//! lower: per-layer achieved occupancy of the critical AlexNet.
//!
//! Paper: average layer-wise achieved occupancy 65.25% under Miriam vs
//! 32.9% under Multi-stream, and AlexNet-C end-to-end latency much lower
//! under Miriam.
//!
//! Run: `cargo bench --bench fig9_casestudy`

use std::sync::Arc;

use miriam::coordinator::{baselines::multistream::MultiStream, driver, Miriam};
use miriam::gpu::kernel::Criticality;
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::arrival::Arrival;
use miriam::workloads::mdtb::{Source, Workload};
use miriam::workloads::models;

fn workload(duration_us: f64) -> Workload {
    Workload {
        name: "fig9/alexnet-x2".into(),
        sources: vec![
            Source {
                model: Arc::new(models::alexnet()),
                arrival: Arrival::ClosedLoop { clients: 1 },
                criticality: Criticality::Critical,
                deadline_us: None,
            },
            Source {
                // Rename the normal instance's kernels so per-layer
                // occupancy attribution separates AlexNet-C from AlexNet-N.
                model: Arc::new({
                    let mut m = models::alexnet();
                    m.name = "alexnetN".into();
                    for k in &mut m.kernels {
                        k.name = k.name.replace("alexnet/", "alexnetN/");
                    }
                    m
                }),
                arrival: Arrival::ClosedLoop { clients: 1 },
                criticality: Criticality::Normal,
                deadline_us: None,
            },
        ],
        duration_us,
        seed: 9,
    }
}

fn main() {
    let duration_us = 500_000.0;
    let spec = GpuSpec::rtx2060();
    println!("# Fig. 9: AlexNet-C (critical) vs AlexNet-N (normal), \
              closed-loop, rtx2060");

    let wl = workload(duration_us);
    let ms = driver::run(spec.clone(), &wl, &mut MultiStream::new());
    let mut miriam = Miriam::new(&[wl.sources[0].model.clone()]);
    let mi = driver::run(spec.clone(), &wl, &mut miriam);

    // (upper) timeline excerpt: the first 24 launches of each run.
    for (name, st) in [("multistream", &ms), ("miriam", &mi)] {
        println!("\n## timeline ({name}) — first 24 launches");
        println!("{:<28} {:>5} {:>10} {:>10} {:>9}",
                 "kernel", "crit", "start(ms)", "end(ms)", "dur(us)");
        let mut recs = st.timeline.clone();
        recs.sort_by(|a, b| a.start_us.partial_cmp(&b.start_us).unwrap());
        for r in recs.iter().take(24) {
            println!("{:<28} {:>5} {:>10.3} {:>10.3} {:>9.1}",
                     r.name,
                     if r.criticality == Criticality::Critical { "C" } else { "N" },
                     r.start_us / 1e3,
                     r.end_us / 1e3,
                     r.end_us - r.start_us);
        }
    }

    // (lower) per-layer achieved occupancy of the critical AlexNet.
    println!("\n## per-layer achieved occupancy of critical AlexNet");
    println!("{:<20} {:>12} {:>12}", "layer", "multistream", "miriam");
    let layers: Vec<String> = models::alexnet()
        .kernels
        .iter()
        .map(|k| k.name.clone())
        .collect();
    let mut sum_ms = 0.0;
    let mut sum_mi = 0.0;
    let mut n = 0.0;
    for l in &layers {
        let o_ms = ms.per_name_occupancy.get(l).copied().unwrap_or(0.0);
        let o_mi = mi.per_name_occupancy.get(l).copied().unwrap_or(0.0);
        println!("{:<20} {:>12.3} {:>12.3}", l, o_ms, o_mi);
        sum_ms += o_ms;
        sum_mi += o_mi;
        n += 1.0;
    }
    println!("{:<20} {:>12.3} {:>12.3}", "AVERAGE", sum_ms / n, sum_mi / n);

    println!("\n## end-to-end critical latency");
    println!("multistream: {:.2} ms   miriam: {:.2} ms   (miriam/{:.2}x)",
             ms.critical_latency_mean_us() / 1e3,
             mi.critical_latency_mean_us() / 1e3,
             ms.critical_latency_mean_us() / mi.critical_latency_mean_us());
    println!("\n## whole-GPU achieved occupancy");
    println!("multistream: {:.3}   miriam: {:.3}", ms.achieved_occupancy,
             mi.achieved_occupancy);
    println!("\n# paper: layer-wise avg occupancy 65.25% (miriam) vs 32.9% \
              (multistream); AlexNet-C latency much lower under Miriam");
}
