//! Fig. 8 (a)–(f) — the headline evaluation: end-to-end critical-task
//! latency, overall throughput, and average achieved occupancy for
//! {Sequential, Multi-stream+Priority, IB, Miriam} on MDTB A–D, on both
//! platforms (RTX 2060 and Jetson AGX Xavier).
//!
//! Paper shapes to reproduce:
//!  * Sequential: lowest critical latency reference, lowest throughput;
//!  * Multi-stream: highest raw throughput, critical latency blown up
//!    (1.95x / 2.02x on MDTB-A);
//!  * IB: latency between the two, throughput can drop below Sequential
//!    under frequent critical launches (MDTB-A);
//!  * Miriam: throughput well above Sequential (paper: +64% / +83% on A,
//!    1.79x–1.91x on B–D) at a small critical-latency overhead (<= ~28%).
//!
//! Run: `cargo bench --bench fig8_mdtb`

use miriam::coordinator::{driver, scheduler_for, RunStats, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::mdtb;

fn run_cell(platform: &GpuSpec, wl_name: &str, sched: &str,
            duration_us: f64) -> RunStats {
    let wl = mdtb::by_name(wl_name, duration_us).unwrap().build();
    let mut s = scheduler_for(sched, &wl).unwrap();
    driver::run(platform.clone(), &wl, s.as_mut())
}

fn main() {
    let duration_us = 1_000_000.0;
    println!("# Fig. 8: MDTB A-D x {{rtx2060, xavier}} x 4 schedulers, \
              {}s simulated each", duration_us / 1e6);
    for spec in [GpuSpec::rtx2060(), GpuSpec::xavier()] {
        for wl in ["A", "B", "C", "D"] {
            println!("\n## MDTB-{wl} on {}", spec.name);
            println!("{:<12} {:>10} {:>10} {:>12} {:>10} {:>8}",
                     "scheduler", "crit(ms)", "crit p99", "tput(req/s)",
                     "norm(1/s)", "occup");
            let mut seq_lat = f64::NAN;
            let mut seq_tput = f64::NAN;
            let mut rows = Vec::new();
            for sched in SCHEDULERS {
                let st = run_cell(&spec, wl, sched, duration_us);
                if sched == "sequential" {
                    seq_lat = st.critical_latency_mean_us();
                    seq_tput = st.throughput_rps();
                }
                rows.push((sched, st));
            }
            for (sched, st) in &rows {
                println!("{:<12} {:>10.2} {:>10.2} {:>12.1} {:>10.1} {:>8.3}",
                         sched,
                         st.critical_latency_mean_us() / 1e3,
                         st.critical_latency_p99_us() / 1e3,
                         st.throughput_rps(),
                         st.completed_normal() as f64 / (st.span_us / 1e6),
                         st.achieved_occupancy);
            }
            // Normalized summary (the ratios the paper quotes).
            println!("{:<12} {:>10} {:>22}", "-- ratio", "lat/seq", "tput/seq");
            for (sched, st) in &rows {
                println!("{:<12} {:>10.2} {:>22.2}",
                         sched,
                         st.critical_latency_mean_us() / seq_lat,
                         st.throughput_rps() / seq_tput);
            }
        }
    }
    println!("\n# paper targets: Miriam tput/seq ~1.64-1.91 with lat/seq <= ~1.28;");
    println!("# multistream lat/seq ~1.3-2.0; IB tput/seq < 1 under closed-loop");
    println!("# critical (MDTB-A). See EXPERIMENTS.md for measured-vs-paper.");
}
