//! Request-level fault injection and the recovery policy knobs that
//! survive it (ISSUE 8 tentpole).
//!
//! A [`FaultSpec`] describes a seeded per-launch fault model — transient
//! launch failures, straggler slowdown multipliers, and corrupted-output
//! faults detectable at completion — scripted via a `--faults` DSL
//! (`fail:p=0.001,straggle:p=0.01*4x,corrupt:p=0.0005`) or one of the
//! [`FAULT_STORMS`] presets. Fault draws are a pure function of
//! `(spec.seed, request id, attempt)` via [`FaultSpec::draw`], so the
//! fault schedule is independent of worker-thread interleaving and the
//! whole faults grid stays byte-deterministic.
//!
//! The module also holds the two pure per-device recovery state
//! machines the fleet loop drives: a consecutive-failure circuit
//! [`Breaker`] (trip → route-around → half-open probe in simulated
//! time) and a [`Brownout`] controller with autoscaler-style hysteresis
//! that trades best-effort shard width for critical deadline safety.
//!
//! An inert spec ([`FaultSpec::is_inert`]) injects nothing, and
//! `fleet::run_fleet` normalizes it away entirely, so zero-fault runs
//! are bitwise identical to fault-free builds — the contract
//! `rust/tests/fleet_determinism.rs` pins.

use crate::workloads::rng::Rng;

/// Default seed for the fault-draw stream (distinct from arrival and
/// chaos seeds so fault schedules never correlate with arrivals).
pub const DEFAULT_FAULT_SEED: u64 = 0xFA17_5EED;

/// Recovery-policy knobs consumed by the fleet loop's self-healing
/// layer. Defaults are the production posture: retry, hedge, cancel,
/// break, and brown out.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryConfig {
    /// Retry budget per best-effort request (critical requests retry
    /// without bound — they are never dropped by policy).
    pub max_retries: u32,
    /// Base retry backoff (us); attempt `k` waits
    /// `backoff_us * 2^min(k, 10)` in simulated time.
    pub backoff_us: f64,
    /// Hedge critical requests past the deadline-risk watermark onto a
    /// second device (first reported completion wins).
    pub hedge: bool,
    /// Fraction of a critical request's deadline after which a hedge
    /// copy is launched (0.6 = hedge once 60% of the deadline elapsed
    /// without a completion).
    pub hedge_watermark: f64,
    /// Cancel best-effort requests that passed their deadline while
    /// still queued (counted `cancelled`, never applied to critical).
    pub cancel: bool,
    /// Consecutive launch/corruption failures on one device that trip
    /// its circuit breaker.
    pub breaker_threshold: u32,
    /// Simulated time a tripped breaker stays open before admitting a
    /// half-open probe (us).
    pub breaker_cooldown_us: f64,
    /// Enable brownout: degrade best-effort shard width instead of
    /// shedding when critical deadline-risk crosses the watermark.
    pub brownout: bool,
    /// Deadline-risk EWMA level that turns brownout on.
    pub brownout_high: f64,
    /// Deadline-risk EWMA level that turns brownout back off
    /// (hysteresis; must be below `brownout_high`).
    pub brownout_low: f64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            max_retries: 3,
            backoff_us: 500.0,
            hedge: true,
            hedge_watermark: 0.6,
            cancel: true,
            breaker_threshold: 4,
            breaker_cooldown_us: 10_000.0,
            brownout: true,
            brownout_high: 0.85,
            brownout_low: 0.55,
        }
    }
}

/// One per-launch fault draw: what the injection layer decided for a
/// given `(request, attempt)` pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultDraw {
    /// The launch fails transiently at submit time (nothing runs).
    pub fail: bool,
    /// The completion is delayed by this slowdown multiplier (post-run
    /// stall; `None` = no straggle).
    pub straggle: Option<f64>,
    /// The output is corrupted — detected at completion, forcing a
    /// retry.
    pub corrupt: bool,
}

impl FaultDraw {
    /// A draw that injects nothing.
    pub const CLEAN: FaultDraw =
        FaultDraw { fail: false, straggle: None, corrupt: false };
}

/// A seeded request-level fault model plus the recovery policy that
/// answers it.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Script name (`"none"`, `"cli"`, or a [`FAULT_STORMS`] preset).
    pub name: String,
    /// Probability a launch fails transiently at submit.
    pub fail_p: f64,
    /// Probability a launch straggles (completion stalls).
    pub straggle_p: f64,
    /// Slowdown multiplier applied to a straggled launch's service time
    /// (≥ 1).
    pub straggle_factor: f64,
    /// Probability a completion carries corrupted output.
    pub corrupt_p: f64,
    /// Seed of the fault-draw stream (independent of arrival seeds).
    pub seed: u64,
    /// Recovery policy the fleet loop runs against this fault model.
    pub recovery: RecoveryConfig,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::none()
    }
}

/// Named fault-storm presets accepted by `--fault-storm` (`"none"` is
/// the fault-free baseline cell).
pub const FAULT_STORMS: [&str; 5] = [
    "none",
    "flaky-launches",
    "straggler-swarm",
    "bitflip-storm",
    "full-fault-storm",
];

impl FaultSpec {
    /// The inert spec: no faults, default recovery posture.
    pub fn none() -> Self {
        FaultSpec {
            name: "none".into(),
            fail_p: 0.0,
            straggle_p: 0.0,
            straggle_factor: 1.0,
            corrupt_p: 0.0,
            seed: DEFAULT_FAULT_SEED,
            recovery: RecoveryConfig::default(),
        }
    }

    /// True when the spec injects nothing — `run_fleet` normalizes an
    /// inert spec to "no fault layer at all" so zero-fault runs stay
    /// bitwise identical to pre-fault builds.
    pub fn is_inert(&self) -> bool {
        self.fail_p == 0.0 && self.straggle_p == 0.0 && self.corrupt_p == 0.0
    }

    /// Parse the `--faults` DSL: comma-separated items
    /// `fail:p=F` | `straggle:p=F*Gx` | `corrupt:p=F`,
    /// e.g. `fail:p=0.001,straggle:p=0.01*4x,corrupt:p=0.0005`.
    /// Each kind may appear at most once. The parsed spec is named
    /// `"cli"` and carries the default recovery posture.
    pub fn parse(script: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::none();
        spec.name = "cli".into();
        let (mut saw_fail, mut saw_straggle, mut saw_corrupt) =
            (false, false, false);
        for item in script.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (kind, body) = item.split_once(':').ok_or_else(|| {
                format!("fault item `{item}` is missing a `:` separator \
                         (expected e.g. `fail:p=0.001`)")
            })?;
            let body = body.strip_prefix("p=").ok_or_else(|| {
                format!("fault item `{item}` must give a probability as \
                         `p=<float>`")
            })?;
            match kind {
                "fail" => {
                    if saw_fail {
                        return Err(format!(
                            "duplicate fault kind `fail` in `{script}`"
                        ));
                    }
                    saw_fail = true;
                    spec.fail_p = parse_prob(body, item)?;
                }
                "straggle" => {
                    if saw_straggle {
                        return Err(format!(
                            "duplicate fault kind `straggle` in `{script}`"
                        ));
                    }
                    saw_straggle = true;
                    let (p, factor) =
                        body.split_once('*').ok_or_else(|| {
                            format!("straggle item `{item}` must give a \
                                     slowdown as `*<factor>x` (e.g. \
                                     `straggle:p=0.01*4x`)")
                        })?;
                    spec.straggle_p = parse_prob(p, item)?;
                    let factor =
                        factor.strip_suffix('x').ok_or_else(|| {
                            format!("straggle factor in `{item}` must end \
                                     in `x` (e.g. `4x`)")
                        })?;
                    spec.straggle_factor =
                        factor.parse::<f64>().map_err(|_| {
                            format!("bad straggle factor `{factor}` in \
                                     `{item}`")
                        })?;
                }
                "corrupt" => {
                    if saw_corrupt {
                        return Err(format!(
                            "duplicate fault kind `corrupt` in `{script}`"
                        ));
                    }
                    saw_corrupt = true;
                    spec.corrupt_p = parse_prob(body, item)?;
                }
                other => {
                    return Err(format!(
                        "unknown fault kind `{other}` in `{item}` (valid \
                         kinds: fail, straggle, corrupt)"
                    ));
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Check the spec is physically sensible: probabilities finite in
    /// [0, 1], `fail`/`corrupt` strictly below 1 (a certain fault never
    /// terminates), straggle factor finite and ≥ 1, recovery watermarks
    /// ordered.
    pub fn validate(&self) -> Result<(), String> {
        for (what, p) in [
            ("fail", self.fail_p),
            ("straggle", self.straggle_p),
            ("corrupt", self.corrupt_p),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!(
                    "fault `{what}` probability {p} is outside [0, 1]"
                ));
            }
        }
        if self.fail_p >= 1.0 && self.fail_p != 0.0 {
            return Err("fail probability 1 never terminates (every retry \
                        fails forever); use p < 1"
                .into());
        }
        if self.corrupt_p >= 1.0 && self.corrupt_p != 0.0 {
            return Err("corrupt probability 1 never terminates (every \
                        completion retries forever); use p < 1"
                .into());
        }
        if !self.straggle_factor.is_finite() || self.straggle_factor < 1.0 {
            return Err(format!(
                "straggle factor {} must be finite and >= 1",
                self.straggle_factor
            ));
        }
        let r = &self.recovery;
        if !r.backoff_us.is_finite() || r.backoff_us < 0.0 {
            return Err(format!(
                "retry backoff {}us must be finite and >= 0",
                r.backoff_us
            ));
        }
        if !r.hedge_watermark.is_finite()
            || !(0.0..=1.0).contains(&r.hedge_watermark)
        {
            return Err(format!(
                "hedge watermark {} is outside [0, 1]",
                r.hedge_watermark
            ));
        }
        if r.breaker_threshold == 0 {
            return Err("breaker threshold must be >= 1".into());
        }
        if !r.breaker_cooldown_us.is_finite() || r.breaker_cooldown_us <= 0.0
        {
            return Err(format!(
                "breaker cooldown {}us must be finite and > 0",
                r.breaker_cooldown_us
            ));
        }
        if !(r.brownout_low.is_finite() && r.brownout_high.is_finite())
            || r.brownout_low < 0.0
            || r.brownout_low >= r.brownout_high
        {
            return Err(format!(
                "brownout watermarks must satisfy 0 <= low < high \
                 (got low={} high={})",
                r.brownout_low, r.brownout_high
            ));
        }
        Ok(())
    }

    /// The fault decision for attempt `attempt` of request `req_id`: a
    /// pure function of `(seed, req_id, attempt)` with a fixed draw
    /// order (fail, straggle, corrupt), so fault schedules are
    /// identical across thread counts and loop interleavings.
    pub fn draw(&self, req_id: u64, attempt: u32) -> FaultDraw {
        if self.is_inert() {
            return FaultDraw::CLEAN;
        }
        let mut rng = Rng::new(
            self.seed
                ^ req_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64 + 1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        let fail = rng.next_f64() < self.fail_p;
        let straggle = if rng.next_f64() < self.straggle_p {
            Some(self.straggle_factor)
        } else {
            None
        };
        let corrupt = rng.next_f64() < self.corrupt_p;
        FaultDraw { fail, straggle, corrupt }
    }
}

fn parse_prob(s: &str, item: &str) -> Result<f64, String> {
    let p = s
        .parse::<f64>()
        .map_err(|_| format!("bad probability `{s}` in `{item}`"))?;
    if !p.is_finite() || !(0.0..=1.0).contains(&p) {
        return Err(format!(
            "probability {p} in `{item}` is outside [0, 1]"
        ));
    }
    Ok(p)
}

/// The [`FAULT_STORMS`] preset named `name`, or `None` for an unknown
/// name. `"none"` yields the inert spec (the fault-free baseline cell).
pub fn storm(name: &str) -> Option<FaultSpec> {
    let mut spec = FaultSpec::none();
    spec.name = name.into();
    match name {
        "none" => {}
        "flaky-launches" => {
            spec.fail_p = 0.05;
        }
        "straggler-swarm" => {
            spec.straggle_p = 0.08;
            spec.straggle_factor = 4.0;
        }
        "bitflip-storm" => {
            spec.corrupt_p = 0.03;
        }
        "full-fault-storm" => {
            spec.fail_p = 0.02;
            spec.straggle_p = 0.04;
            spec.straggle_factor = 4.0;
            spec.corrupt_p = 0.01;
        }
        _ => return None,
    }
    Some(spec)
}

/// Resolve a `--fault-storm` name list (`"all"` or comma-separated
/// preset names) into specs, failing fast with the valid set on an
/// unknown name — the same contract `--storm` has for chaos presets.
pub fn resolve_storms(which: &str) -> Result<Vec<FaultSpec>, String> {
    let names: Vec<&str> = if which == "all" {
        FAULT_STORMS.to_vec()
    } else {
        which.split(',').map(str::trim).collect()
    };
    let mut specs = Vec::new();
    for name in names {
        match storm(name) {
            Some(s) => specs.push(s),
            None => {
                return Err(format!(
                    "unknown fault storm `{name}` (valid: {})",
                    FAULT_STORMS.join(", ")
                ))
            }
        }
    }
    Ok(specs)
}

/// Per-device circuit-breaker state.
#[derive(Debug, Clone, Copy, PartialEq)]
enum BreakerState {
    /// Healthy; counts consecutive failures toward the trip threshold.
    Closed { consec: u32 },
    /// Tripped; routes around this device until `until_us`.
    Open { until_us: f64 },
    /// Cooldown elapsed; one probe launch is allowed to decide.
    HalfOpen,
}

/// A per-device consecutive-failure circuit breaker on simulated time:
/// `threshold` consecutive launch/corruption failures trip it open for
/// `cooldown_us`, after which one half-open probe either closes it
/// (success) or re-trips it instantly (failure).
#[derive(Debug, Clone)]
pub struct Breaker {
    threshold: u32,
    cooldown_us: f64,
    state: BreakerState,
    trips: u64,
}

impl Breaker {
    /// A closed breaker with the given trip policy.
    pub fn new(threshold: u32, cooldown_us: f64) -> Self {
        Breaker {
            threshold: threshold.max(1),
            cooldown_us,
            state: BreakerState::Closed { consec: 0 },
            trips: 0,
        }
    }

    /// Whether the router may place work here at simulated time `now`.
    /// An open breaker whose cooldown has elapsed transitions to
    /// half-open and admits the probe.
    pub fn allows(&mut self, now_us: f64) -> bool {
        match self.state {
            BreakerState::Closed { .. } | BreakerState::HalfOpen => true,
            BreakerState::Open { until_us } => {
                if now_us >= until_us {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a launch failure / corrupted completion at `now`. A
    /// half-open probe failure re-trips instantly; a closed breaker
    /// trips at the consecutive-failure threshold.
    pub fn on_failure(&mut self, now_us: f64) {
        match self.state {
            BreakerState::HalfOpen => self.trip(now_us),
            BreakerState::Closed { consec } => {
                let consec = consec + 1;
                if consec >= self.threshold {
                    self.trip(now_us);
                } else {
                    self.state = BreakerState::Closed { consec };
                }
            }
            BreakerState::Open { .. } => {}
        }
    }

    /// Record a clean completion: closes the breaker and resets the
    /// consecutive-failure count.
    pub fn on_success(&mut self) {
        self.state = BreakerState::Closed { consec: 0 };
    }

    /// Times the breaker tripped open over the run.
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// True while the breaker is open (before its half-open probe).
    pub fn is_open(&self) -> bool {
        matches!(self.state, BreakerState::Open { .. })
    }

    fn trip(&mut self, now_us: f64) {
        self.trips += 1;
        self.state = BreakerState::Open { until_us: now_us + self.cooldown_us };
    }
}

/// EWMA smoothing factor for the brownout deadline-risk signal.
const BROWNOUT_ALPHA: f64 = 0.2;

/// A per-device brownout controller with autoscaler-style hysteresis:
/// it smooths the observed critical deadline-risk ratio
/// (`latency / deadline` per served critical request) with an EWMA and
/// toggles brownout on above `high`, off below `low`. While on, the
/// coordinator thins best-effort elastic shards instead of shedding
/// tenants; the total browned-out simulated time is reported as
/// `brownout_us`.
#[derive(Debug, Clone)]
pub struct Brownout {
    high: f64,
    low: f64,
    ewma: f64,
    on: bool,
    since_us: f64,
    total_us: f64,
}

impl Brownout {
    /// A controller that trips above `high` and recovers below `low`.
    pub fn new(high: f64, low: f64) -> Self {
        Brownout { high, low, ewma: 0.0, on: false, since_us: 0.0, total_us: 0.0 }
    }

    /// Feed one observed critical deadline-risk ratio at simulated time
    /// `now`. Returns `Some(new_state)` when the hysteresis toggles
    /// brownout, `None` when the state is unchanged.
    pub fn observe(&mut self, ratio: f64, now_us: f64) -> Option<bool> {
        if !ratio.is_finite() {
            return None;
        }
        self.ewma = BROWNOUT_ALPHA * ratio + (1.0 - BROWNOUT_ALPHA) * self.ewma;
        if !self.on && self.ewma > self.high {
            self.on = true;
            self.since_us = now_us;
            Some(true)
        } else if self.on && self.ewma < self.low {
            self.on = false;
            self.total_us += now_us - self.since_us;
            Some(false)
        } else {
            None
        }
    }

    /// Whether brownout is currently engaged.
    pub fn engaged(&self) -> bool {
        self.on
    }

    /// Force brownout off (device went down); closes the open span at
    /// `now` and resets the risk signal.
    pub fn reset(&mut self, now_us: f64) {
        if self.on {
            self.total_us += now_us - self.since_us;
            self.on = false;
        }
        self.ewma = 0.0;
    }

    /// Total browned-out simulated time, closing any open span at `now`.
    pub fn finish(&mut self, now_us: f64) -> f64 {
        if self.on {
            self.total_us += now_us - self.since_us;
            self.since_us = now_us;
        }
        self.total_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let spec =
            FaultSpec::parse("fail:p=0.001,straggle:p=0.01*4x,corrupt:p=0.0005")
                .expect("issue example must parse");
        assert_eq!(spec.name, "cli");
        assert_eq!(spec.fail_p, 0.001);
        assert_eq!(spec.straggle_p, 0.01);
        assert_eq!(spec.straggle_factor, 4.0);
        assert_eq!(spec.corrupt_p, 0.0005);
        assert!(!spec.is_inert());
        spec.validate().expect("parsed spec must validate");
    }

    #[test]
    fn rejects_malformed_scripts() {
        for bad in [
            "fail",                      // missing separator
            "fail:0.1",                  // missing p=
            "fail:p=nope",               // bad float
            "fail:p=1.5",                // out of range
            "fail:p=-0.1",               // out of range
            "straggle:p=0.1",            // missing factor
            "straggle:p=0.1*4",          // missing x suffix
            "straggle:p=0.1*0.5x",       // factor < 1
            "explode:p=0.1",             // unknown kind
            "fail:p=0.1,fail:p=0.2",     // duplicate kind
            "fail:p=1",                  // certain failure never ends
            "corrupt:p=1.0",             // certain corruption never ends
        ] {
            let err = FaultSpec::parse(bad)
                .expect_err(&format!("`{bad}` must be rejected"));
            assert!(!err.is_empty());
        }
        // Unknown kinds name the valid set.
        let err = FaultSpec::parse("explode:p=0.1").unwrap_err();
        assert!(err.contains("fail, straggle, corrupt"), "{err}");
    }

    #[test]
    fn validate_catches_bad_recovery_knobs() {
        let mut spec = FaultSpec::none();
        spec.recovery.brownout_low = 0.9; // low >= high
        assert!(spec.validate().is_err());
        let mut spec = FaultSpec::none();
        spec.recovery.hedge_watermark = 1.5;
        assert!(spec.validate().is_err());
        let mut spec = FaultSpec::none();
        spec.recovery.breaker_threshold = 0;
        assert!(spec.validate().is_err());
        let mut spec = FaultSpec::none();
        spec.recovery.breaker_cooldown_us = 0.0;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn storms_are_valid_and_deterministic() {
        for name in FAULT_STORMS {
            let a = storm(name).expect("preset must resolve");
            let b = storm(name).expect("preset must resolve");
            assert_eq!(a, b, "storm `{name}` must be deterministic");
            a.validate().expect("preset must validate");
            assert_eq!(a.is_inert(), name == "none");
        }
        assert!(storm("category-5").is_none());
        let err = resolve_storms("none,category-5").unwrap_err();
        assert!(err.contains("full-fault-storm"), "{err}");
        assert_eq!(resolve_storms("all").unwrap().len(), FAULT_STORMS.len());
    }

    #[test]
    fn none_spec_is_default_inert_and_clean() {
        let spec = FaultSpec::default();
        assert!(spec.is_inert());
        assert_eq!(spec.name, "none");
        for id in 0..100u64 {
            assert_eq!(spec.draw(id, 0), FaultDraw::CLEAN);
        }
    }

    #[test]
    fn draws_are_pure_in_id_and_attempt() {
        let spec = storm("full-fault-storm").unwrap();
        let mut distinct = 0;
        for id in 0..200u64 {
            for attempt in 0..3u32 {
                let a = spec.draw(id, attempt);
                let b = spec.draw(id, attempt);
                assert_eq!(a, b, "draw must be pure");
                if a != FaultDraw::CLEAN {
                    distinct += 1;
                }
            }
        }
        // At these rates some draws must inject (sanity: non-vacuous).
        assert!(distinct > 0, "storm rates must actually inject faults");
        // Different attempts of the same request draw independently.
        let any_differs = (0..200u64)
            .any(|id| spec.draw(id, 0) != spec.draw(id, 1));
        assert!(any_differs, "attempts must not share a draw");
    }

    #[test]
    fn breaker_trips_and_half_open_round_trips() {
        let mut b = Breaker::new(3, 100.0);
        assert!(b.allows(0.0));
        b.on_failure(0.0);
        b.on_failure(1.0);
        assert!(b.allows(1.0), "below threshold stays closed");
        b.on_failure(2.0);
        assert_eq!(b.trips(), 1);
        assert!(b.is_open());
        assert!(!b.allows(50.0), "open before cooldown");
        assert!(b.allows(102.0), "half-open probe admitted after cooldown");
        assert!(!b.is_open());
        // Probe failure re-trips instantly.
        b.on_failure(103.0);
        assert_eq!(b.trips(), 2);
        assert!(!b.allows(150.0));
        // Probe success closes and resets the consecutive count.
        assert!(b.allows(300.0));
        b.on_success();
        b.on_failure(301.0);
        b.on_failure(302.0);
        assert!(b.allows(302.0), "success reset the consecutive count");
    }

    #[test]
    fn breaker_success_interrupts_a_streak() {
        let mut b = Breaker::new(2, 100.0);
        b.on_failure(0.0);
        b.on_success();
        b.on_failure(1.0);
        assert_eq!(b.trips(), 0, "non-consecutive failures must not trip");
        b.on_failure(2.0);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn brownout_hysteresis_and_time_accounting() {
        let mut bo = Brownout::new(0.8, 0.4);
        // Push the EWMA above the high watermark.
        let mut toggled_on_at = None;
        for i in 0..50 {
            if bo.observe(1.5, i as f64) == Some(true) {
                toggled_on_at = Some(i as f64);
                break;
            }
        }
        let on_at = toggled_on_at.expect("sustained risk must engage");
        assert!(bo.engaged());
        // Mid-band observations keep it on (hysteresis).
        assert_eq!(bo.observe(0.6, on_at + 1.0), None);
        assert!(bo.engaged());
        // Cool observations eventually disengage.
        let mut toggled_off_at = None;
        for i in 0..200 {
            let t = on_at + 2.0 + i as f64;
            if bo.observe(0.0, t) == Some(false) {
                toggled_off_at = Some(t);
                break;
            }
        }
        let off_at = toggled_off_at.expect("calm must disengage");
        assert!(!bo.engaged());
        let total = bo.finish(off_at + 100.0);
        assert_eq!(total, off_at - on_at, "span must close at disengage");
    }

    #[test]
    fn brownout_reset_closes_the_open_span() {
        let mut bo = Brownout::new(0.5, 0.1);
        for i in 0..50 {
            bo.observe(2.0, i as f64);
        }
        assert!(bo.engaged());
        bo.reset(60.0);
        assert!(!bo.engaged());
        let closed = bo.finish(100.0);
        assert!(closed > 0.0 && closed <= 60.0);
        // Fully reset: takes sustained risk to re-engage.
        assert_eq!(bo.observe(0.0, 101.0), None);
    }
}
