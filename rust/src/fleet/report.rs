//! Fleet serving reports and the canonical `BENCH_fleet.json` document
//! (ISSUE 5 tentpole).
//!
//! A [`FleetReport`] is the outcome of one (scenario, router) cell of
//! [`crate::fleet::run_fleet`]: per-device outcomes ([`DeviceOutcome`] —
//! where requests landed and how each device fared), per-tenant SLO rows
//! (the same [`TenantOutcome`] schema `BENCH_serve.json` uses), and
//! fleet-level latency/throughput/miss aggregates. A [`FleetGridReport`]
//! is a scenarios × routers comparison, serialized by
//! [`FleetGridReport::to_json`] with **no host-timing fields** — so a
//! fleet run is byte-deterministic per (seed, devices, router), the
//! contract `rust/tests/fleet_determinism.rs` pins.

use std::collections::BTreeMap;

use crate::coordinator::admission::AdmissionPolicy;
use crate::coordinator::stats::{merged_quantile, sorted_quantile};
use crate::gpu::kernel::Criticality;
use crate::runtime::json::Json;
use crate::server::online::{
    tenant_json, tenant_json_faults, tenant_json_resilience, TenantOutcome,
};

/// Identity of one fleet device (the `devices` header of
/// `BENCH_fleet.json`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceDesc {
    /// Stable instance name within the fleet (`d{i}-{preset}`).
    pub name: String,
    /// GPU preset name.
    pub platform: String,
    /// Scheduler this device runs.
    pub scheduler: String,
}

/// Outcome of one device over a fleet serving run.
#[derive(Debug, Clone)]
pub struct DeviceOutcome {
    /// The device's identity.
    pub desc: DeviceDesc,
    /// Requests the router placed here.
    pub routed: u64,
    /// Critical requests placed here (the criticality-affinity pinning
    /// invariant is checked against this).
    pub routed_critical: u64,
    /// Best-effort requests placed here.
    pub routed_normal: u64,
    /// Served completions that exceeded their tenant's deadline.
    pub deadline_misses: u64,
    /// End-to-end latency (us) of every critical request served here.
    pub critical_latencies_us: Vec<f64>,
    /// End-to-end latency (us) of every best-effort request served here.
    pub normal_latencies_us: Vec<f64>,
    /// The device's simulated span until it drained (us).
    pub span_us: f64,
    /// Simulator events this device processed.
    pub events: u64,
    /// Peak best-effort queue depth inside the device's coordinator (0
    /// when the scheduler does not expose one).
    pub max_normal_queue: usize,
    /// Requests this device received as chaos-layer requeues (drained
    /// off a dead or draining device and re-routed here; 0 without
    /// chaos).
    pub requeued_in: u64,
    /// Total simulated time this device spent down (us; 0 without
    /// chaos).
    pub downtime_us: f64,
    /// Times this device's circuit breaker tripped open (0 without
    /// fault injection).
    pub breaker_trips: u64,
    /// Total simulated time this device spent in brownout — forcing
    /// thinner elastic shards for best-effort tenants (us; 0 without
    /// fault injection).
    pub brownout_us: f64,
}

impl DeviceOutcome {
    /// Requests this device served to completion.
    pub fn served(&self) -> u64 {
        (self.critical_latencies_us.len() + self.normal_latencies_us.len())
            as u64
    }

    /// Critical-class latency quantile on this device (NaN when none).
    pub fn crit_quantile_us(&self, q: f64) -> f64 {
        sorted_quantile(&self.critical_latencies_us, q)
    }

    /// Best-effort-class latency quantile on this device (NaN when none).
    pub fn normal_quantile_us(&self, q: f64) -> f64 {
        sorted_quantile(&self.normal_latencies_us, q)
    }

    /// One device row of a fleet cell. The chaos-only keys appear only
    /// when `resilience` is set and the fault-layer keys only when
    /// `faults` is set, so zero-chaos, zero-fault documents stay
    /// byte-identical to their pre-chaos (PR 5) form.
    fn to_json_value(&self, resilience: bool, faults: bool) -> Json {
        let num = Json::Num;
        let mut m = BTreeMap::new();
        m.insert("device".into(), Json::Str(self.desc.name.clone()));
        m.insert("platform".into(), Json::Str(self.desc.platform.clone()));
        m.insert("scheduler".into(), Json::Str(self.desc.scheduler.clone()));
        m.insert("routed".into(), num(self.routed as f64));
        m.insert("routed_critical".into(), num(self.routed_critical as f64));
        m.insert("routed_normal".into(), num(self.routed_normal as f64));
        m.insert("served".into(), num(self.served() as f64));
        m.insert("deadline_misses".into(), num(self.deadline_misses as f64));
        m.insert("crit_p50_us".into(), num(self.crit_quantile_us(0.5)));
        m.insert("crit_p99_us".into(), num(self.crit_quantile_us(0.99)));
        m.insert("normal_p50_us".into(), num(self.normal_quantile_us(0.5)));
        m.insert("span_us".into(), num(self.span_us));
        m.insert("events".into(), num(self.events as f64));
        m.insert("max_normal_queue".into(),
                 num(self.max_normal_queue as f64));
        if resilience {
            m.insert("requeued_in".into(), num(self.requeued_in as f64));
            m.insert("downtime_us".into(), num(self.downtime_us));
        }
        if faults {
            m.insert("breaker_trips".into(), num(self.breaker_trips as f64));
            m.insert("brownout_us".into(), num(self.brownout_us));
        }
        Json::Obj(m)
    }
}

/// Outcome of one (scenario, router) fleet serving cell.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Scenario name.
    pub scenario: String,
    /// Router the run placed requests with.
    pub router: String,
    /// Admission policy applied fleet-wide.
    pub policy: AdmissionPolicy,
    /// Arrival seed the run actually used.
    pub seed: u64,
    /// Arrival-generation window (us).
    pub duration_us: f64,
    /// Per-device outcomes, in fleet order.
    pub devices: Vec<DeviceOutcome>,
    /// Per-tenant outcomes, in source order (fleet-wide).
    pub tenants: Vec<TenantOutcome>,
    /// Fleet simulated span: the slowest device's drain time (us).
    pub span_us: f64,
    /// Simulator events summed over devices.
    pub events: u64,
    /// Critical arrivals whose deadline was infeasible by the admission
    /// envelope (admitted regardless; see `AdmissionController`).
    pub critical_at_risk: u64,
    /// Chaos script name this cell ran under (`"none"`, `"cli"`, or a
    /// storm preset).
    pub chaos: String,
    /// Scripted chaos events in the cell's schedule.
    pub chaos_events: u64,
    /// Slowest outage recovery observed: the longest simulated time
    /// from a device kill until every request it was carrying had been
    /// served elsewhere (NaN when no outage occurred).
    pub recovery_us: f64,
    /// Standby devices the autoscaler attached during the run.
    pub attaches: u64,
    /// Pool devices the autoscaler drained and detached.
    pub detaches: u64,
    /// Whether the cell ran with a chaos script or an autoscaler. Gates
    /// the chaos-only JSON keys so zero-chaos documents stay
    /// byte-identical to their pre-chaos (PR 5) form.
    pub resilience: bool,
    /// Whether the cell ran with request-level fault injection (ISSUE
    /// 8). Gates the fault-layer JSON keys so zero-fault documents stay
    /// byte-identical to their pre-fault form.
    pub faults: bool,
    /// Fault script name this cell ran under (`"none"`, `"cli"`, or a
    /// fault-storm preset).
    pub fault_script: String,
}

impl FleetReport {
    /// Total arrivals seen.
    pub fn offered(&self) -> u64 {
        self.tenants.iter().map(|t| t.offered).sum()
    }

    /// Total arrivals admitted.
    pub fn admitted(&self) -> u64 {
        self.tenants.iter().map(|t| t.admitted).sum()
    }

    /// Total arrivals shed.
    pub fn shed(&self) -> u64 {
        self.tenants.iter().map(|t| t.shed).sum()
    }

    /// Total requests served to completion (fleet-wide).
    pub fn served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Requests placed on devices — equals [`FleetReport::admitted`] by
    /// the router-conservation invariant (every admitted request is
    /// routed to exactly one device), pinned in
    /// `rust/tests/prop_invariants.rs`.
    pub fn routed(&self) -> u64 {
        self.devices.iter().map(|d| d.routed).sum()
    }

    /// Total chaos-layer requeues over all tenants (0 without chaos).
    pub fn requeues(&self) -> u64 {
        self.tenants.iter().map(|t| t.requeues).sum()
    }

    /// Admitted requests lost to a terminal outage, fleet-wide — zero
    /// whenever at least one device stays live (pinned in
    /// `rust/tests/prop_invariants.rs`), and always
    /// `admitted == served + lost`.
    pub fn lost(&self) -> u64 {
        self.tenants.iter().map(|t| t.lost).sum()
    }

    /// Total fault-layer launch retries over all tenants (0 without
    /// fault injection).
    pub fn retries(&self) -> u64 {
        self.tenants.iter().map(|t| t.retries).sum()
    }

    /// Total hedged re-launches issued for deadline-risky critical
    /// requests (0 without fault injection).
    pub fn hedges(&self) -> u64 {
        self.tenants.iter().map(|t| t.hedges).sum()
    }

    /// Hedged requests whose hedge copy reported first (0 without
    /// fault injection). Each hedged request is counted at most once.
    pub fn hedge_wins(&self) -> u64 {
        self.tenants.iter().map(|t| t.hedge_wins).sum()
    }

    /// Admitted requests the fault layer cancelled — doomed best-effort
    /// requests past their deadline or out of retries. With faults on,
    /// `admitted == served + lost + cancelled`.
    pub fn cancelled(&self) -> u64 {
        self.tenants.iter().map(|t| t.cancelled).sum()
    }

    /// Cancelled count over critical tenants — structurally zero (the
    /// fault layer never cancels critical requests), recorded so tests
    /// and gates can assert it fleet-wide.
    pub fn critical_cancelled(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.cancelled)
    }

    /// Circuit-breaker trips summed over devices (0 without fault
    /// injection).
    pub fn breaker_trips(&self) -> u64 {
        self.devices.iter().map(|d| d.breaker_trips).sum()
    }

    /// Shed count over critical tenants — zero by the admission
    /// invariant, recorded so tests and reports can assert it fleet-wide.
    pub fn shed_critical(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.shed)
    }

    /// Deadline misses over critical tenants.
    pub fn deadline_misses_critical(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.deadline_misses)
    }

    /// Deadline misses over best-effort tenants.
    pub fn deadline_misses_normal(&self) -> u64 {
        self.class_sum(Criticality::Normal, |t| t.deadline_misses)
    }

    fn class_sum(&self, c: Criticality, f: impl Fn(&TenantOutcome) -> u64)
                 -> u64 {
        self.tenants
            .iter()
            .filter(|t| t.criticality == c)
            .map(f)
            .sum()
    }

    fn class_quantile(&self, c: Criticality, q: f64) -> f64 {
        merged_quantile(
            self.tenants
                .iter()
                .filter(|t| t.criticality == c)
                .map(|t| t.latencies_us.as_slice()),
            q,
        )
    }

    /// Fleet-wide critical-class latency quantile (NaN when none served).
    pub fn crit_quantile_us(&self, q: f64) -> f64 {
        self.class_quantile(Criticality::Critical, q)
    }

    /// Fleet-wide critical-class p99 latency (us).
    pub fn crit_p99_us(&self) -> f64 {
        self.crit_quantile_us(0.99)
    }

    /// Fleet-wide best-effort-class latency quantile.
    pub fn normal_quantile_us(&self, q: f64) -> f64 {
        self.class_quantile(Criticality::Normal, q)
    }

    /// Served requests (both classes) per second of fleet simulated span.
    pub fn throughput_rps(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        self.served() as f64 / (self.span_us / 1e6)
    }

    /// Served best-effort requests per second of fleet simulated span.
    pub fn normal_throughput_rps(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        self.class_sum(Criticality::Normal, |t| t.served) as f64
            / (self.span_us / 1e6)
    }

    /// This cell as a canonical-JSON value (one `cells[]` row of
    /// `BENCH_fleet.json`; non-finite quantiles serialize as `null`).
    pub fn to_json_value(&self) -> Json {
        let num = Json::Num;
        let mut m = BTreeMap::new();
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("router".into(), Json::Str(self.router.clone()));
        m.insert("policy".into(), Json::Str(self.policy.name().into()));
        m.insert("seed".into(), num(self.seed as f64));
        m.insert("duration_us".into(), num(self.duration_us));
        m.insert("span_us".into(), num(self.span_us));
        m.insert("events".into(), num(self.events as f64));
        m.insert("offered".into(), num(self.offered() as f64));
        m.insert("admitted".into(), num(self.admitted() as f64));
        m.insert("shed".into(), num(self.shed() as f64));
        m.insert("served".into(), num(self.served() as f64));
        m.insert("routed".into(), num(self.routed() as f64));
        m.insert("shed_critical".into(), num(self.shed_critical() as f64));
        m.insert("crit_p50_us".into(), num(self.crit_quantile_us(0.5)));
        m.insert("crit_p99_us".into(), num(self.crit_p99_us()));
        m.insert("normal_p50_us".into(), num(self.normal_quantile_us(0.5)));
        m.insert("throughput_rps".into(), num(self.throughput_rps()));
        m.insert("normal_throughput_rps".into(),
                 num(self.normal_throughput_rps()));
        m.insert("deadline_misses_critical".into(),
                 num(self.deadline_misses_critical() as f64));
        m.insert("deadline_misses_normal".into(),
                 num(self.deadline_misses_normal() as f64));
        m.insert("critical_at_risk".into(), num(self.critical_at_risk as f64));
        if self.resilience {
            m.insert("chaos".into(), Json::Str(self.chaos.clone()));
            m.insert("chaos_events".into(), num(self.chaos_events as f64));
            m.insert("requeues".into(), num(self.requeues() as f64));
            m.insert("lost".into(), num(self.lost() as f64));
            m.insert("recovery_us".into(), num(self.recovery_us));
            m.insert("attaches".into(), num(self.attaches as f64));
            m.insert("detaches".into(), num(self.detaches as f64));
        }
        if self.faults {
            m.insert("faults".into(), Json::Str(self.fault_script.clone()));
            m.insert("retries".into(), num(self.retries() as f64));
            m.insert("hedges".into(), num(self.hedges() as f64));
            m.insert("hedge_wins".into(), num(self.hedge_wins() as f64));
            m.insert("cancelled".into(), num(self.cancelled() as f64));
            m.insert("breaker_trips".into(),
                     num(self.breaker_trips() as f64));
        }
        m.insert(
            "devices".into(),
            Json::Arr(
                self.devices
                    .iter()
                    .map(|d| d.to_json_value(self.resilience, self.faults))
                    .collect(),
            ),
        );
        let trow = if self.faults {
            tenant_json_faults
        } else if self.resilience {
            tenant_json_resilience
        } else {
            tenant_json
        };
        m.insert(
            "tenants".into(),
            Json::Arr(self.tenants.iter().map(trow).collect()),
        );
        Json::Obj(m)
    }
}

/// One isolation-vs-elasticity comparison row of `BENCH_fleet.json`
/// (ISSUE 9): the fleet grid cell re-run with every device on one
/// hard-isolation split, against the same cell under the fleet's own
/// schedulers.
#[derive(Debug, Clone)]
pub struct IsolationFleetRow {
    /// The isolation scheduler of the re-run (`isolation:A/B[+spill]`).
    pub scheduler: String,
    /// Scenario of the cell.
    pub scenario: String,
    /// Router of the cell.
    pub router: String,
    /// Fleet-wide critical p99 under isolation (us).
    pub crit_p99_us: f64,
    /// Fleet-wide served throughput under isolation (req/s).
    pub throughput_rps: f64,
    /// Critical p99 of the base cell (us).
    pub base_crit_p99_us: f64,
    /// Throughput of the base cell (req/s).
    pub base_throughput_rps: f64,
}

impl IsolationFleetRow {
    /// This row as a canonical-JSON value (`isolation[]` of
    /// `BENCH_fleet.json`). Ratios > 1 mean isolation is slower
    /// (`crit_p99_vs_base`) or busier (`throughput_vs_base`) than the
    /// base schedulers.
    pub fn to_json_value(&self) -> Json {
        let num = Json::Num;
        let mut m = BTreeMap::new();
        m.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("router".into(), Json::Str(self.router.clone()));
        m.insert("crit_p99_us".into(), num(self.crit_p99_us));
        m.insert("throughput_rps".into(), num(self.throughput_rps));
        m.insert("base_crit_p99_us".into(), num(self.base_crit_p99_us));
        m.insert("base_throughput_rps".into(),
                 num(self.base_throughput_rps));
        m.insert("crit_p99_vs_base".into(),
                 num(self.crit_p99_us / self.base_crit_p99_us));
        m.insert("throughput_vs_base".into(),
                 num(self.throughput_rps / self.base_throughput_rps));
        Json::Obj(m)
    }
}

/// A scenarios × routers fleet comparison (the `BENCH_fleet.json`
/// document).
#[derive(Debug, Clone)]
pub struct FleetGridReport {
    /// Fleet devices, in fleet order.
    pub devices: Vec<DeviceDesc>,
    /// Admission policy applied in every cell.
    pub policy: String,
    /// Arrival-generation window per cell (us).
    pub duration_us: f64,
    /// Router names, in run order.
    pub routers: Vec<String>,
    /// Scenario names, in run order.
    pub scenarios: Vec<String>,
    /// Cells in deterministic grid order (scenario-major, then router) —
    /// independent of worker-thread interleaving.
    pub cells: Vec<FleetReport>,
    /// Isolation-vs-elasticity comparison rows (split-major, then
    /// scenario, then router), filled only by `--isolation` runs
    /// ([`crate::fleet::run_isolation_comparison`]). Empty rows emit no
    /// JSON key, keeping mask-free documents bitwise stable vs PR 8.
    pub isolation: Vec<IsolationFleetRow>,
}

impl FleetGridReport {
    /// The cell for (scenario, router), if it ran.
    pub fn cell(&self, scenario: &str, router: &str) -> Option<&FleetReport> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.router == router)
    }

    /// The canonical `BENCH_fleet.json` document: sorted keys, no
    /// whitespace, no host-timing fields — byte-deterministic per
    /// (seed, devices, router) and across `--threads` values (schema in
    /// EXPERIMENTS.md §Fleet). `--isolation` runs add an `isolation`
    /// comparison array (EXPERIMENTS.md §Isolation); the key is omitted
    /// otherwise.
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("fleet".into()));
        obj.insert(
            "devices".into(),
            Json::Arr(
                self.devices
                    .iter()
                    .map(|d| {
                        let mut m = BTreeMap::new();
                        m.insert("name".into(), Json::Str(d.name.clone()));
                        m.insert("platform".into(),
                                 Json::Str(d.platform.clone()));
                        m.insert("scheduler".into(),
                                 Json::Str(d.scheduler.clone()));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        obj.insert("policy".into(), Json::Str(self.policy.clone()));
        obj.insert("duration_us".into(), Json::Num(self.duration_us));
        obj.insert(
            "routers".into(),
            Json::Arr(self.routers.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(|c| c.to_json_value()).collect()),
        );
        if !self.isolation.is_empty() {
            obj.insert(
                "isolation".into(),
                Json::Arr(
                    self.isolation.iter().map(|r| r.to_json_value()).collect(),
                ),
            );
        }
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}

/// A scenarios × storms × routers resilience comparison (the
/// `BENCH_resilience.json` document, ISSUE 6).
#[derive(Debug, Clone)]
pub struct ResilienceGridReport {
    /// Fleet devices (primaries first, then the standby pool).
    pub devices: Vec<DeviceDesc>,
    /// Admission policy applied in every cell.
    pub policy: String,
    /// Arrival-generation window per cell (us).
    pub duration_us: f64,
    /// Scenario names, in run order.
    pub scenarios: Vec<String>,
    /// Storm preset names, in run order (`"none"` is the baseline).
    pub storms: Vec<String>,
    /// Router names, in run order.
    pub routers: Vec<String>,
    /// Cells in deterministic grid order (scenario-major, then storm,
    /// then router) — independent of worker-thread interleaving.
    pub cells: Vec<FleetReport>,
}

impl ResilienceGridReport {
    /// The cell for (scenario, storm, router), if it ran.
    pub fn cell(&self, scenario: &str, storm: &str, router: &str)
                -> Option<&FleetReport> {
        self.cells.iter().find(|c| {
            c.scenario == scenario && c.chaos == storm && c.router == router
        })
    }

    /// Per-cell headline numbers with each storm cell's critical p99
    /// put next to the `none` baseline of the same (scenario, router)
    /// as a degradation ratio — what `tools/bench_gate.py
    /// --resilience` and EXPERIMENTS.md read.
    fn comparisons(&self) -> Json {
        let num = Json::Num;
        let rows = self
            .cells
            .iter()
            .map(|c| {
                let base_p99 = self
                    .cell(&c.scenario, "none", &c.router)
                    .map(|b| b.crit_p99_us())
                    .unwrap_or(f64::NAN);
                let p99 = c.crit_p99_us();
                let degradation = if base_p99.is_finite() && base_p99 > 0.0
                {
                    p99 / base_p99
                } else {
                    f64::NAN
                };
                let mut m = BTreeMap::new();
                m.insert("scenario".into(), Json::Str(c.scenario.clone()));
                m.insert("storm".into(), Json::Str(c.chaos.clone()));
                m.insert("router".into(), Json::Str(c.router.clone()));
                m.insert("served".into(), num(c.served() as f64));
                m.insert("requeues".into(), num(c.requeues() as f64));
                m.insert("lost".into(), num(c.lost() as f64));
                m.insert("recovery_us".into(), num(c.recovery_us));
                m.insert("crit_p99_us".into(), num(p99));
                m.insert("crit_p99_degradation".into(), num(degradation));
                Json::Obj(m)
            })
            .collect();
        Json::Arr(rows)
    }

    /// The canonical `BENCH_resilience.json` document: sorted keys, no
    /// whitespace, no host-timing fields — byte-deterministic per seed
    /// and across `--threads` values (schema in EXPERIMENTS.md
    /// §Resilience).
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("resilience".into()));
        obj.insert(
            "devices".into(),
            Json::Arr(
                self.devices
                    .iter()
                    .map(|d| {
                        let mut m = BTreeMap::new();
                        m.insert("name".into(), Json::Str(d.name.clone()));
                        m.insert("platform".into(),
                                 Json::Str(d.platform.clone()));
                        m.insert("scheduler".into(),
                                 Json::Str(d.scheduler.clone()));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        obj.insert("policy".into(), Json::Str(self.policy.clone()));
        obj.insert("duration_us".into(), Json::Num(self.duration_us));
        obj.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "storms".into(),
            Json::Arr(self.storms.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "routers".into(),
            Json::Arr(self.routers.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert("comparisons".into(), self.comparisons());
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(|c| c.to_json_value()).collect()),
        );
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}

/// A scenarios × fault-scripts × routers comparison (the
/// `BENCH_faults.json` document, ISSUE 8).
#[derive(Debug, Clone)]
pub struct FaultsGridReport {
    /// Fleet devices (primaries first, then any standby pool).
    pub devices: Vec<DeviceDesc>,
    /// Admission policy applied in every cell.
    pub policy: String,
    /// Arrival-generation window per cell (us).
    pub duration_us: f64,
    /// Scenario names, in run order.
    pub scenarios: Vec<String>,
    /// Fault script names, in run order (`"none"` is the baseline).
    pub faults: Vec<String>,
    /// Router names, in run order.
    pub routers: Vec<String>,
    /// Cells in deterministic grid order (scenario-major, then fault
    /// script, then router) — independent of worker-thread
    /// interleaving.
    pub cells: Vec<FleetReport>,
}

impl FaultsGridReport {
    /// The cell for (scenario, fault script, router), if it ran.
    pub fn cell(&self, scenario: &str, faults: &str, router: &str)
                -> Option<&FleetReport> {
        self.cells.iter().find(|c| {
            c.scenario == scenario
                && c.fault_script == faults
                && c.router == router
        })
    }

    /// Per-cell headline numbers with each fault cell's critical p99
    /// put next to the `none` baseline of the same (scenario, router)
    /// as a degradation ratio — what `tools/bench_gate.py --faults`
    /// and EXPERIMENTS.md read.
    fn comparisons(&self) -> Json {
        let num = Json::Num;
        let rows = self
            .cells
            .iter()
            .map(|c| {
                let base_p99 = self
                    .cell(&c.scenario, "none", &c.router)
                    .map(|b| b.crit_p99_us())
                    .unwrap_or(f64::NAN);
                let p99 = c.crit_p99_us();
                let degradation = if base_p99.is_finite() && base_p99 > 0.0
                {
                    p99 / base_p99
                } else {
                    f64::NAN
                };
                let mut m = BTreeMap::new();
                m.insert("scenario".into(), Json::Str(c.scenario.clone()));
                m.insert("faults".into(), Json::Str(c.fault_script.clone()));
                m.insert("router".into(), Json::Str(c.router.clone()));
                m.insert("offered".into(), num(c.offered() as f64));
                m.insert("admitted".into(), num(c.admitted() as f64));
                m.insert("shed".into(), num(c.shed() as f64));
                m.insert("served".into(), num(c.served() as f64));
                m.insert("lost".into(), num(c.lost() as f64));
                m.insert("cancelled".into(), num(c.cancelled() as f64));
                m.insert("critical_cancelled".into(),
                         num(c.critical_cancelled() as f64));
                m.insert("retries".into(), num(c.retries() as f64));
                m.insert("hedges".into(), num(c.hedges() as f64));
                m.insert("hedge_wins".into(), num(c.hedge_wins() as f64));
                m.insert("breaker_trips".into(),
                         num(c.breaker_trips() as f64));
                m.insert("crit_p99_us".into(), num(p99));
                m.insert("crit_p99_degradation".into(), num(degradation));
                Json::Obj(m)
            })
            .collect();
        Json::Arr(rows)
    }

    /// The canonical `BENCH_faults.json` document: sorted keys, no
    /// whitespace, no host-timing fields — byte-deterministic per seed
    /// and across `--threads` values (schema in EXPERIMENTS.md
    /// §Faults).
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("faults".into()));
        obj.insert(
            "devices".into(),
            Json::Arr(
                self.devices
                    .iter()
                    .map(|d| {
                        let mut m = BTreeMap::new();
                        m.insert("name".into(), Json::Str(d.name.clone()));
                        m.insert("platform".into(),
                                 Json::Str(d.platform.clone()));
                        m.insert("scheduler".into(),
                                 Json::Str(d.scheduler.clone()));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        obj.insert("policy".into(), Json::Str(self.policy.clone()));
        obj.insert("duration_us".into(), Json::Num(self.duration_us));
        obj.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "faults".into(),
            Json::Arr(self.faults.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "routers".into(),
            Json::Arr(self.routers.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert("comparisons".into(), self.comparisons());
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(|c| c.to_json_value()).collect()),
        );
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}
