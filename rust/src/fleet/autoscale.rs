//! Reactive autoscaler for the fleet loop (ISSUE 6).
//!
//! The scaler watches an **envelope-weighted backlog** signal — the sum
//! of outstanding solo-envelope microseconds across live devices,
//! divided by the live-device count — and attaches/detaches standby
//! devices from a configured pool against watermark targets. All
//! decisions happen at scheduled evaluation ticks in *simulated* time
//! with a cooldown hysteresis, so a fleet run with an autoscaler is as
//! byte-deterministic as one without.
//!
//! The scaler itself is policy only: it answers "attach, detach, or
//! hold?" and the fleet loop in [`crate::fleet`] performs the actual
//! core rebuild / drain. Detach is graceful — the loop drains the
//! device's open requests before parking it back in the pool.

/// Configuration for the reactive autoscaler.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// Standby device pool as `GpuSpec` preset names, attach order.
    pub pool: Vec<String>,
    /// Scheduler used for attached standby devices.
    pub scheduler: String,
    /// Attach a standby when per-live-device backlog is at or above
    /// this many envelope-microseconds.
    pub high_watermark_us: f64,
    /// Detach the newest pool device when backlog is at or below this.
    pub low_watermark_us: f64,
    /// Interval between scaling evaluations, simulated microseconds.
    pub eval_period_us: f64,
    /// Minimum simulated time between two scaling *actions*
    /// (hysteresis; evaluations during cooldown always hold).
    pub cooldown_us: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            pool: Vec::new(),
            scheduler: "miriam".into(),
            high_watermark_us: 20_000.0,
            low_watermark_us: 4_000.0,
            eval_period_us: 5_000.0,
            cooldown_us: 20_000.0,
        }
    }
}

impl AutoscaleConfig {
    /// Validate watermarks and periods: `high > low >= 0`, a strictly
    /// positive finite evaluation period, a finite non-negative
    /// cooldown.
    pub fn validate(&self) -> Result<(), String> {
        if !self.high_watermark_us.is_finite()
            || !self.low_watermark_us.is_finite()
            || self.low_watermark_us < 0.0
            || self.high_watermark_us <= self.low_watermark_us
        {
            return Err(format!(
                "autoscale watermarks need high > low >= 0, got \
                 high={} low={}",
                self.high_watermark_us, self.low_watermark_us
            ));
        }
        if !self.eval_period_us.is_finite() || self.eval_period_us <= 0.0
        {
            return Err(format!(
                "autoscale eval period must be positive, got {}",
                self.eval_period_us
            ));
        }
        if !self.cooldown_us.is_finite() || self.cooldown_us < 0.0 {
            return Err(format!(
                "autoscale cooldown must be >= 0, got {}",
                self.cooldown_us
            ));
        }
        Ok(())
    }
}

/// The decision taken at one evaluation tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// No change — backlog is between the watermarks, the cooldown is
    /// active, or there is nothing to attach/detach.
    Hold,
    /// Attach the next standby device from the pool.
    Attach,
    /// Drain and detach the newest attached pool device.
    Detach,
}

/// Deterministic watermark autoscaler; see the module docs.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    next_eval_us: Option<f64>,
    last_action_us: f64,
}

impl Autoscaler {
    /// Build a scaler; the first evaluation fires one period in.
    pub fn new(cfg: AutoscaleConfig) -> Self {
        let first = cfg.eval_period_us;
        Autoscaler {
            cfg,
            next_eval_us: Some(first),
            last_action_us: f64::NEG_INFINITY,
        }
    }

    /// The configuration the scaler was built with.
    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Simulated time of the next evaluation tick, `None` when the
    /// scaler has disarmed (no work left to react to).
    pub fn next_eval_us(&self) -> Option<f64> {
        self.next_eval_us
    }

    /// Evaluate at simulated time `now_us` against the backlog signal.
    /// `backlog_per_live_us` is envelope-microseconds of outstanding
    /// work per live device; `can_attach` / `can_detach` report whether
    /// the fleet loop has a standby to add or a pool device to drain.
    pub fn evaluate(
        &mut self,
        now_us: f64,
        backlog_per_live_us: f64,
        can_attach: bool,
        can_detach: bool,
    ) -> ScaleAction {
        if now_us - self.last_action_us < self.cfg.cooldown_us {
            return ScaleAction::Hold;
        }
        let action = if backlog_per_live_us >= self.cfg.high_watermark_us
            && can_attach
        {
            ScaleAction::Attach
        } else if backlog_per_live_us <= self.cfg.low_watermark_us
            && can_detach
        {
            ScaleAction::Detach
        } else {
            ScaleAction::Hold
        };
        if action != ScaleAction::Hold {
            self.last_action_us = now_us;
        }
        action
    }

    /// Arm the next tick one period after `now_us`, or disarm when
    /// `work_remains` is false (guarantees loop termination: ticks
    /// never keep an otherwise-drained simulation alive).
    pub fn schedule_next(&mut self, now_us: f64, work_remains: bool) {
        self.next_eval_us = if work_remains {
            Some(now_us + self.cfg.eval_period_us)
        } else {
            None
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            pool: vec!["rtx2060".into()],
            high_watermark_us: 10_000.0,
            low_watermark_us: 2_000.0,
            eval_period_us: 1_000.0,
            cooldown_us: 5_000.0,
            ..AutoscaleConfig::default()
        }
    }

    #[test]
    fn validates_watermarks_and_periods() {
        assert!(cfg().validate().is_ok());
        let mut bad = cfg();
        bad.low_watermark_us = bad.high_watermark_us;
        assert!(bad.validate().is_err());
        let mut bad = cfg();
        bad.eval_period_us = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg();
        bad.cooldown_us = f64::NAN;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn attaches_above_high_and_detaches_below_low() {
        let mut s = Autoscaler::new(cfg());
        assert_eq!(s.evaluate(1_000.0, 15_000.0, true, false),
                   ScaleAction::Attach);
        // Cooldown: the very next tick holds even though backlog is
        // still high.
        assert_eq!(s.evaluate(2_000.0, 15_000.0, true, false),
                   ScaleAction::Hold);
        // After the cooldown expires, a drained backlog detaches.
        assert_eq!(s.evaluate(6_000.0, 500.0, false, true),
                   ScaleAction::Detach);
    }

    #[test]
    fn holds_between_watermarks_and_without_capacity() {
        let mut s = Autoscaler::new(cfg());
        assert_eq!(s.evaluate(1_000.0, 5_000.0, true, true),
                   ScaleAction::Hold);
        // High backlog but no standby left: hold, and the cooldown is
        // NOT consumed by a non-action.
        assert_eq!(s.evaluate(2_000.0, 15_000.0, false, true),
                   ScaleAction::Hold);
        assert_eq!(s.evaluate(3_000.0, 15_000.0, true, false),
                   ScaleAction::Attach);
    }

    #[test]
    fn schedule_next_disarms_when_work_is_done() {
        let mut s = Autoscaler::new(cfg());
        assert_eq!(s.next_eval_us(), Some(1_000.0));
        s.schedule_next(1_000.0, true);
        assert_eq!(s.next_eval_us(), Some(2_000.0));
        s.schedule_next(2_000.0, false);
        assert_eq!(s.next_eval_us(), None);
    }
}
