//! Deterministic chaos layer for the fleet subsystem (ISSUE 6).
//!
//! A [`ChaosSpec`] is a *script* of failure events pinned to simulated
//! time: device outages ([`ChaosEvent::DeviceDown`], optionally healing
//! after a fixed delay) and thermal throttles
//! ([`ChaosEvent::ThermalThrottle`], scaling the device's effective
//! `GpuSpec` rates for a window). Events come from two front doors:
//!
//! * the CLI DSL parsed by [`ChaosSpec::parse`], e.g.
//!   `down:d1@800ms+2s,throttle:d0@1s*0.6+500ms`;
//! * named **storm presets** built by [`storm`] (see [`STORMS`]) whose
//!   event times are derived from a fixed seed via the repo's own
//!   [`Rng`](crate::workloads::rng::Rng) — no host entropy, so the same
//!   (storm, devices, duration) always yields the same script.
//!
//! Every preset outage carries a heal, which is what makes the
//! `lost == 0` conservation invariant testable under every storm: with
//! at least one device live at all times, an admitted request is either
//! served or requeued, never dropped.

use crate::workloads::rng::Rng;

/// Storm preset names accepted by [`storm`] and the `fleet-sim --storm`
/// axis. `"none"` is the explicit no-chaos baseline cell.
pub const STORMS: [&str; 4] = [
    "none",
    "straggler-storm",
    "rolling-outage",
    "flash-crowd-outage",
];

/// One scripted chaos event, pinned to simulated microseconds.
#[derive(Debug, Clone, PartialEq)]
pub enum ChaosEvent {
    /// Kill a device at `at_us`. Open requests on the device are drained
    /// and re-routed; with `heal_after_us: Some(d)` the device comes
    /// back at `at_us + d`, with `None` it stays down forever (a
    /// *terminal* outage — admitted-but-unplaced requests become
    /// `lost` if the whole fleet is dark).
    DeviceDown {
        /// Simulated time of the kill, in microseconds.
        at_us: f64,
        /// Index of the device to kill (fleet order, pool included).
        device: usize,
        /// Delay until the device heals; `None` means never.
        heal_after_us: Option<f64>,
    },
    /// Scale a device's effective compute and memory rates by `factor`
    /// (in `(0, 1]`) for `duration_us` starting at `at_us`.
    ThermalThrottle {
        /// Simulated time the throttle engages, in microseconds.
        at_us: f64,
        /// Index of the throttled device.
        device: usize,
        /// Multiplier applied to `flops_per_sm_us` and
        /// `dram_bw_bytes_us`; 0.6 means the device runs at 60%.
        factor: f64,
        /// How long the throttle lasts, in microseconds (> 0).
        duration_us: f64,
    },
}

impl ChaosEvent {
    /// The device index this event targets.
    pub fn device(&self) -> usize {
        match *self {
            ChaosEvent::DeviceDown { device, .. } => device,
            ChaosEvent::ThermalThrottle { device, .. } => device,
        }
    }
}

/// A named, ordered script of chaos events.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosSpec {
    /// Label carried into reports (`"none"`, `"cli"`, or a storm name).
    pub name: String,
    /// The scripted events; firing order is resolved by the fleet loop.
    pub events: Vec<ChaosEvent>,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec::none()
    }
}

impl ChaosSpec {
    /// The empty script: zero events, name `"none"`. A fleet run under
    /// this spec is bitwise identical to a run with no chaos layer at
    /// all (pinned by `fleet_determinism.rs`).
    pub fn none() -> Self {
        ChaosSpec { name: "none".into(), events: Vec::new() }
    }

    /// True when the script has no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the CLI DSL: comma-separated items, each either
    ///
    /// * `down:<dev>@<time>[+<heal>]` — kill `<dev>` at `<time>`,
    ///   healing after `<heal>` if given;
    /// * `throttle:<dev>@<time>*<factor>+<duration>` — run `<dev>` at
    ///   `<factor>` of its rates for `<duration>`.
    ///
    /// `<dev>` is `d0`, `d1`, … or a bare index; times accept `us`,
    /// `ms` and `s` suffixes (bare numbers are microseconds). Example:
    /// `down:d1@800ms+2s,throttle:d0@1s*0.6+500ms`.
    pub fn parse(input: &str) -> Result<ChaosSpec, String> {
        let mut events = Vec::new();
        for raw in input.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            let (kind, rest) = item.split_once(':').ok_or_else(|| {
                format!(
                    "chaos item '{item}' needs a kind prefix \
                     (down: or throttle:)"
                )
            })?;
            let (dev_s, spec) = rest.split_once('@').ok_or_else(|| {
                format!("chaos item '{item}' needs '@<time>'")
            })?;
            let device = parse_device(dev_s)?;
            match kind.trim() {
                "down" => {
                    let (at_s, heal) = match spec.split_once('+') {
                        Some((a, h)) => (a, Some(parse_time(h)?)),
                        None => (spec, None),
                    };
                    events.push(ChaosEvent::DeviceDown {
                        at_us: parse_time(at_s)?,
                        device,
                        heal_after_us: heal,
                    });
                }
                "throttle" => {
                    let (at_s, tail) =
                        spec.split_once('*').ok_or_else(|| {
                            format!(
                                "throttle item '{item}' needs \
                                 '*<factor>+<duration>'"
                            )
                        })?;
                    let (factor_s, dur_s) =
                        tail.split_once('+').ok_or_else(|| {
                            format!(
                                "throttle item '{item}' needs \
                                 '+<duration>' after the factor"
                            )
                        })?;
                    let factor =
                        factor_s.trim().parse::<f64>().map_err(|_| {
                            format!(
                                "bad throttle factor '{factor_s}' in \
                                 '{item}'"
                            )
                        })?;
                    events.push(ChaosEvent::ThermalThrottle {
                        at_us: parse_time(at_s)?,
                        device,
                        factor,
                        duration_us: parse_time(dur_s)?,
                    });
                }
                other => {
                    return Err(format!(
                        "unknown chaos kind '{other}' in '{item}' \
                         (expected down or throttle)"
                    ));
                }
            }
        }
        Ok(ChaosSpec { name: "cli".into(), events })
    }

    /// Validate the script against a fleet of `devices` devices:
    /// in-range device indices, finite non-negative times, strictly
    /// positive durations, throttle factors in `(0, 1]`.
    pub fn validate(&self, devices: usize) -> Result<(), String> {
        for ev in &self.events {
            let d = ev.device();
            if d >= devices {
                return Err(format!(
                    "chaos event targets device {d} but the fleet has \
                     {devices} device(s)"
                ));
            }
            match *ev {
                ChaosEvent::DeviceDown { at_us, heal_after_us, .. } => {
                    if !at_us.is_finite() || at_us < 0.0 {
                        return Err(format!(
                            "down event has bad time {at_us}"
                        ));
                    }
                    if let Some(h) = heal_after_us {
                        if !h.is_finite() || h <= 0.0 {
                            return Err(format!(
                                "down event has bad heal delay {h}"
                            ));
                        }
                    }
                }
                ChaosEvent::ThermalThrottle {
                    at_us,
                    factor,
                    duration_us,
                    ..
                } => {
                    if !at_us.is_finite() || at_us < 0.0 {
                        return Err(format!(
                            "throttle event has bad time {at_us}"
                        ));
                    }
                    if !(factor > 0.0 && factor <= 1.0) {
                        return Err(format!(
                            "throttle factor {factor} outside (0, 1]"
                        ));
                    }
                    if !duration_us.is_finite() || duration_us <= 0.0 {
                        return Err(format!(
                            "throttle event has bad duration \
                             {duration_us}"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

fn parse_device(s: &str) -> Result<usize, String> {
    let t = s.trim();
    let digits = t.strip_prefix('d').unwrap_or(t);
    digits
        .parse::<usize>()
        .map_err(|_| format!("bad chaos device '{s}' (expected d0, d1, …)"))
}

fn parse_time(s: &str) -> Result<f64, String> {
    let t = s.trim();
    let (num, scale) = if let Some(n) = t.strip_suffix("us") {
        (n, 1.0)
    } else if let Some(n) = t.strip_suffix("ms") {
        (n, 1_000.0)
    } else if let Some(n) = t.strip_suffix('s') {
        (n, 1_000_000.0)
    } else {
        (t, 1.0)
    };
    let v = num
        .trim()
        .parse::<f64>()
        .map_err(|_| format!("bad chaos time '{s}'"))?;
    Ok(v * scale)
}

/// Build a named storm preset for a fleet of `devices` devices over a
/// `duration_us` window. Returns `None` for unknown names; callers
/// should list [`STORMS`] in their error. Event times are derived from
/// fixed per-preset seeds through [`Rng`], so the script is a pure
/// function of its arguments.
///
/// * `none` — the empty script (explicit baseline cell).
/// * `straggler-storm` — rotating thermal throttles (factors in
///   `[0.4, 0.7]`); never kills a device.
/// * `rolling-outage` — staggered kill/heal pairs, one device at a
///   time, so fleets of ≥ 2 devices always keep a live majority.
/// * `flash-crowd-outage` — device 0 dies near 30% of the window and
///   heals after ~25% of it, while device 1 (when present) is
///   throttled mid-window: an outage landing on top of peak load.
///
/// Every preset outage heals, which keeps `lost == 0` provable for
/// every storm on any fleet with ≥ 1 device.
pub fn storm(
    name: &str,
    devices: usize,
    duration_us: f64,
) -> Option<ChaosSpec> {
    if devices == 0 || !(duration_us > 0.0) {
        return None;
    }
    let events = match name {
        "none" => Vec::new(),
        "straggler-storm" => {
            let mut rng = Rng::new(0xC4A0_5001);
            let mut evs = Vec::new();
            let n = 6usize;
            let slot = duration_us / (n as f64 + 1.0);
            for w in 0..n {
                let at = slot * (w as f64 + 0.5)
                    + rng.next_f64() * slot * 0.25;
                let factor = 0.4 + 0.3 * rng.next_f64();
                let dur = slot * (0.6 + 0.3 * rng.next_f64());
                evs.push(ChaosEvent::ThermalThrottle {
                    at_us: at,
                    device: w % devices,
                    factor,
                    duration_us: dur,
                });
            }
            evs
        }
        "rolling-outage" => {
            let mut rng = Rng::new(0xC4A0_5002);
            let mut evs = Vec::new();
            // One kill/heal pair per device, strictly staggered: the
            // heal of slot k lands before the kill of slot k+1, so at
            // most one device is ever down.
            let slot = duration_us / (devices as f64 + 1.0);
            for d in 0..devices {
                let at = slot * (d as f64 + 0.5)
                    + rng.next_f64() * slot * 0.1;
                let heal = slot * (0.3 + 0.1 * rng.next_f64());
                evs.push(ChaosEvent::DeviceDown {
                    at_us: at,
                    device: d,
                    heal_after_us: Some(heal),
                });
            }
            evs
        }
        "flash-crowd-outage" => {
            let mut evs = vec![ChaosEvent::DeviceDown {
                at_us: duration_us * 0.3,
                device: 0,
                heal_after_us: Some(duration_us * 0.25),
            }];
            if devices > 1 {
                evs.push(ChaosEvent::ThermalThrottle {
                    at_us: duration_us * 0.45,
                    device: 1,
                    factor: 0.6,
                    duration_us: duration_us * 0.2,
                });
            }
            evs
        }
        _ => return None,
    };
    Some(ChaosSpec { name: name.into(), events })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let spec = ChaosSpec::parse(
            "down:d1@800ms+2s,throttle:d0@1s*0.6+500ms",
        )
        .unwrap();
        assert_eq!(spec.name, "cli");
        assert_eq!(
            spec.events,
            vec![
                ChaosEvent::DeviceDown {
                    at_us: 800_000.0,
                    device: 1,
                    heal_after_us: Some(2_000_000.0),
                },
                ChaosEvent::ThermalThrottle {
                    at_us: 1_000_000.0,
                    device: 0,
                    factor: 0.6,
                    duration_us: 500_000.0,
                },
            ]
        );
        assert!(spec.validate(2).is_ok());
    }

    #[test]
    fn parses_time_suffixes_and_bare_indices() {
        let spec =
            ChaosSpec::parse("down:1@250us, down:d0@3ms").unwrap();
        match spec.events[0] {
            ChaosEvent::DeviceDown { at_us, device, heal_after_us } => {
                assert_eq!(at_us, 250.0);
                assert_eq!(device, 1);
                assert_eq!(heal_after_us, None);
            }
            _ => panic!("expected down"),
        }
        match spec.events[1] {
            ChaosEvent::DeviceDown { at_us, device, .. } => {
                assert_eq!(at_us, 3_000.0);
                assert_eq!(device, 0);
            }
            _ => panic!("expected down"),
        }
        // Bare numbers are microseconds.
        let bare = ChaosSpec::parse("down:d0@1500").unwrap();
        match bare.events[0] {
            ChaosEvent::DeviceDown { at_us, .. } => {
                assert_eq!(at_us, 1_500.0)
            }
            _ => panic!("expected down"),
        }
    }

    #[test]
    fn rejects_malformed_items() {
        for bad in [
            "explode:d0@1ms",
            "down:d0",
            "down:dx@1ms",
            "throttle:d0@1ms",
            "throttle:d0@1ms*0.5",
            "down:d0@soon",
        ] {
            assert!(ChaosSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn validate_catches_bad_targets_and_factors() {
        let spec = ChaosSpec::parse("down:d2@1ms+1ms").unwrap();
        let err = spec.validate(2).unwrap_err();
        assert!(err.contains("device 2"), "{err}");
        let spec = ChaosSpec::parse("throttle:d0@1ms*1.5+1ms").unwrap();
        assert!(spec.validate(1).is_err());
        let spec = ChaosSpec::parse("throttle:d0@1ms*0+1ms").unwrap();
        assert!(spec.validate(1).is_err());
    }

    #[test]
    fn storms_are_valid_and_deterministic() {
        for name in STORMS {
            for devices in 1..=4 {
                let a = storm(name, devices, 200_000.0).unwrap();
                let b = storm(name, devices, 200_000.0).unwrap();
                assert_eq!(a, b, "{name}: preset not deterministic");
                assert_eq!(a.name, name);
                a.validate(devices)
                    .unwrap_or_else(|e| panic!("{name}: {e}"));
                if name != "none" {
                    assert!(!a.is_empty(), "{name}: empty script");
                }
                // Every preset outage heals — the lost == 0 invariant
                // depends on it.
                for ev in &a.events {
                    if let ChaosEvent::DeviceDown {
                        heal_after_us, ..
                    } = ev
                    {
                        assert!(heal_after_us.is_some(),
                                "{name}: terminal outage in a preset");
                    }
                }
            }
        }
        assert!(storm("category-5", 2, 200_000.0).is_none());
        assert!(storm("none", 0, 200_000.0).is_none());
    }

    #[test]
    fn none_spec_is_default_and_empty() {
        assert_eq!(ChaosSpec::default(), ChaosSpec::none());
        assert!(ChaosSpec::none().is_empty());
        assert!(storm("none", 3, 1_000.0).unwrap().is_empty());
    }
}
