//! Heterogeneous multi-GPU fleet serving (ISSUE 5 tentpole; chaos,
//! in-flight rebalancing and autoscaling: ISSUE 6; request-level fault
//! injection and the self-healing layer: ISSUE 8).
//!
//! Miriam is evaluated across two edge-GPU platforms (§8), and the
//! ROADMAP's heavy-traffic north star needs more than one device per
//! deployment: this module serves a mixed-criticality scenario across a
//! **fleet** of simulated edge GPUs — mixed [`GpuSpec`] presets, a
//! per-device scheduler choice — by multiplexing the online serving
//! machinery of [`crate::server::online`] over per-device engine +
//! coordinator instances ([`DeviceCore`]; fleet and single-device runs
//! share that code path, so a 1-device fleet reproduces `serve-sim`
//! bitwise — `rust/tests/fleet_determinism.rs`).
//!
//! The loop advances in simulated time only: arrivals come from the same
//! seeded heap the batch driver and `serve-sim` use, every arrival passes
//! through one fleet-wide [`AdmissionController`] (critical is never
//! shed), and each *admitted* request is placed on exactly one **live**
//! device by a pluggable [`RouterPolicy`] ([`router`] — `round-robin`,
//! `least-outstanding-work`, `criticality-affinity`). Reports
//! ([`report`]) carry no host timing, so `BENCH_fleet.json` and
//! `BENCH_resilience.json` are byte-deterministic per (seed, devices,
//! router, chaos) and across `--threads` values.
//!
//! # Failure / recovery lifecycle (ISSUE 6)
//!
//! A scripted [`ChaosSpec`] (CLI DSL or a [`chaos`] storm preset) kills,
//! heals and throttles devices at fixed simulated times. Each device
//! walks `Live → Down → Live` (kill/heal), `Live → Draining → Standby`
//! (autoscaler detach) or `Standby → Live` (attach); on a kill the
//! device's open requests are drained **sorted by id** and re-routed
//! through [`RouterPolicy::rebalance`] over the surviving devices (each
//! re-placement counts one `requeues` on its tenant). When the whole
//! fleet is dark, drained and newly admitted requests wait in a pending
//! list that flushes on the next heal/attach — a request is `lost` only
//! to a *terminal* outage, so `lost == 0` whenever ≥ 1 device stays
//! live, and `admitted == served + lost` always
//! (`rust/tests/prop_invariants.rs`). A reactive [`Autoscaler`]
//! ([`autoscale`]) attaches/detaches standby devices against an
//! envelope-weighted backlog signal at deterministic simulated-time
//! ticks. With a zero-event spec and no autoscaler the loop's
//! arithmetic is untouched and `run_fleet` output is **bitwise
//! identical** to its pre-chaos (PR 5) form — pinned by
//! `rust/tests/fleet_determinism.rs`.
//!
//! Admission envelopes stay derived against the *nominal* fastest
//! device: admission models the operator's capacity plan, not the
//! transient chaos state, so a storm degrades latency rather than
//! silently re-shaping the admitted load.
//!
//! # Request-level faults and self-healing (ISSUE 8)
//!
//! A seeded [`FaultSpec`] ([`faults`]; `--faults` DSL or a
//! [`FAULT_STORMS`] preset) injects per-launch faults — transient
//! submit failures, straggler slowdowns, corrupted outputs detected at
//! completion — as a pure function of `(seed, request id, attempt)`.
//! The recovery layer answers with bounded retries under deterministic
//! exponential backoff in simulated time (critical retries without
//! bound), cross-device **hedged re-launches** for critical requests
//! past a deadline-risk watermark (first *reported* completion wins,
//! the loser is cancelled where possible and otherwise completes into
//! the void), deadline-aware **cancellation** of doomed best-effort
//! requests (counted `cancelled`, never applied to critical),
//! per-device circuit [`Breaker`]s (consecutive failures trip →
//! route-around → half-open probe), and a per-device [`Brownout`]
//! controller that thins Miriam's best-effort elastic shards instead
//! of shedding when critical deadline-risk runs hot. Conservation
//! extends to `admitted == served + lost + cancelled`; with the fault
//! layer off (`FleetOpts::faults` `None` or inert) every branch of it
//! is unreachable and output is bitwise identical to a fault-free
//! build (`rust/tests/fleet_determinism.rs`).
//!
//! CLI: `miriam fleet-sim --devices xavier,tx2 --router all
//! --scenario duo-burst [--chaos "down:d1@8ms+10ms" | --storm all |
//! --faults "fail:p=0.01,straggle:p=0.02*4x" | --fault-storm all]`
//! (README has a quickstart; EXPERIMENTS.md §Fleet, §Resilience and
//! §Faults have router/chaos/fault semantics and the JSON schemas).
//!
//! [`DeviceCore`]: crate::server::online
//!
//! ```
//! use miriam::fleet::{run_fleet, FleetOpts, FleetSpec};
//! use miriam::workloads::scenario;
//!
//! let fleet = FleetSpec::parse(
//!     &["xavier".into(), "tx2".into()], &["miriam".into()]).unwrap();
//! let sc = scenario::by_name("duo-burst", 5_000.0).unwrap();
//! let report = run_fleet(&fleet, &sc, &FleetOpts::default()).unwrap();
//! // Router conservation: every admitted request landed on one device.
//! assert_eq!(report.routed(), report.admitted());
//! assert_eq!(report.shed_critical(), 0); // critical is never shed
//! ```

pub mod autoscale;
pub mod chaos;
pub mod faults;
pub mod report;
pub mod router;

pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
pub use chaos::{ChaosEvent, ChaosSpec, STORMS};
pub use faults::{
    Breaker, Brownout, FaultDraw, FaultSpec, RecoveryConfig, FAULT_STORMS,
};
pub use report::{
    DeviceDesc, DeviceOutcome, FaultsGridReport, FleetGridReport,
    FleetReport, IsolationFleetRow, ResilienceGridReport,
};
pub use router::{router_for, FleetView, RouterPolicy, ROUTERS};

use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Mutex;

use crate::coordinator::admission::{
    model_envelopes, AdmissionConfig, AdmissionController, AdmissionPolicy,
    Decision,
};
use crate::coordinator::driver::{initial_arrivals, ArrivalQueue};
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::server::online::{
    record_served, shed_arrival, tenant_outcomes, validate_admission,
    DeviceCore, TenantOutcome,
};
use crate::workloads::mdtb::Workload;
use crate::workloads::rng::Rng;
use crate::workloads::scenario::ScenarioSpec;

/// One device of a fleet: a GPU preset plus the scheduler it runs.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Stable instance name within the fleet (`d{i}-{preset}` from
    /// [`FleetSpec::parse`]; presets may repeat, instance names may not).
    pub name: String,
    /// The simulated GPU.
    pub gpu: GpuSpec,
    /// Scheduler name (any `scheduler_for` name) this device runs.
    pub scheduler: String,
}

/// A named fleet of simulated edge GPUs.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// The devices, in fleet order (device index = position here).
    pub devices: Vec<DeviceSpec>,
}

impl FleetSpec {
    /// Build a fleet from CLI-shaped lists: `devices` are GPU preset
    /// names (repeats allowed — `xavier,xavier,tx2` is a valid fleet),
    /// `schedulers` is either one name (applied to every device) or one
    /// name per device. Instance names are `d{i}-{preset}`. Errors on an
    /// unknown preset (listing the available presets), an empty fleet, or
    /// a scheduler list whose length matches neither 1 nor the device
    /// count (scheduler *names* are validated later, by `DeviceCore`).
    pub fn parse(devices: &[String], schedulers: &[String])
                 -> Result<Self, String> {
        if devices.is_empty() {
            return Err("a fleet needs at least one device".into());
        }
        if schedulers.is_empty()
            || (schedulers.len() != 1 && schedulers.len() != devices.len())
        {
            return Err(format!(
                "need one scheduler for the whole fleet or one per device \
                 (got {} for {} device(s))",
                schedulers.len(),
                devices.len()
            ));
        }
        let mut out = Vec::with_capacity(devices.len());
        for (i, d) in devices.iter().enumerate() {
            let gpu = GpuSpec::by_name(d).ok_or_else(|| {
                format!(
                    "unknown device preset '{d}' (available: {})",
                    GpuSpec::PRESET_NAMES.join(", ")
                )
            })?;
            let scheduler = if schedulers.len() == 1 {
                schedulers[0].clone()
            } else {
                schedulers[i].clone()
            };
            out.push(DeviceSpec {
                name: format!("d{i}-{}", gpu.name),
                gpu,
                scheduler,
            });
        }
        Ok(FleetSpec { devices: out })
    }

    /// Index of the fleet's fastest device: highest peak FP32 throughput
    /// ([`GpuSpec::total_flops_us`]), ties broken toward the lowest
    /// index. The spec the fleet-wide admission envelopes are derived
    /// against — note this is the *static* notion; the
    /// `criticality-affinity` pin follows the fastest **live** device
    /// ([`FleetView::fastest_live`]), which the fleet loop recomputes on
    /// every kill/heal/throttle/attach so affinity never targets a dead
    /// or detached device (ISSUE 6 satellite).
    pub fn fastest(&self) -> usize {
        let mut best = 0usize;
        let mut best_flops = f64::NEG_INFINITY;
        for (i, d) in self.devices.iter().enumerate() {
            let f = d.gpu.total_flops_us();
            if f > best_flops {
                best_flops = f;
                best = i;
            }
        }
        best
    }

    /// The devices as report headers.
    pub fn descs(&self) -> Vec<DeviceDesc> {
        self.devices
            .iter()
            .map(|d| DeviceDesc {
                name: d.name.clone(),
                platform: d.gpu.name.clone(),
                scheduler: d.scheduler.clone(),
            })
            .collect()
    }
}

/// Configuration of one fleet serving run.
#[derive(Debug, Clone)]
pub struct FleetOpts {
    /// Router to place admitted requests with (a [`ROUTERS`] name).
    pub router: String,
    /// Admission policy applied fleet-wide to best-effort arrivals.
    pub policy: AdmissionPolicy,
    /// Policy tunables (buckets, burst guard, shed backoff).
    pub admission: AdmissionConfig,
    /// Override the scenario's pinned arrival seed (`None` keeps it).
    pub seed: Option<u64>,
    /// Scripted chaos events. The default empty script leaves the loop's
    /// arithmetic untouched — output is bitwise identical to a run
    /// without the chaos layer.
    pub chaos: ChaosSpec,
    /// Reactive autoscaler with its standby pool (`None` disables).
    pub autoscale: Option<AutoscaleConfig>,
    /// Request-level fault injection + recovery policy (`None` — or an
    /// inert spec, which `run_fleet` normalizes to `None` — leaves the
    /// loop's arithmetic untouched: output is bitwise identical to a
    /// run without the fault layer).
    pub faults: Option<FaultSpec>,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            router: "round-robin".into(),
            policy: AdmissionPolicy::Open,
            admission: AdmissionConfig::default(),
            seed: None,
            chaos: ChaosSpec::none(),
            autoscale: None,
            faults: None,
        }
    }
}

/// Lifecycle state of one fleet device (primaries start `Live`,
/// standby-pool devices start `Standby`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DevState {
    Live,
    Draining,
    Down,
    Standby,
}

/// What one resolved control-timeline entry does. Ranks order same-time
/// entries: heals before throttle-ends before kills before
/// throttle-starts, so a same-instant bounce resolves to "device up".
#[derive(Debug, Clone, Copy)]
enum CtlKind {
    Heal,
    ThrottleEnd,
    Down,
    ThrottleStart { factor: f64 },
}

#[derive(Debug, Clone, Copy)]
struct Ctl {
    at_us: f64,
    rank: u8,
    device: usize,
    kind: CtlKind,
}

/// Expand a [`ChaosSpec`] into the flat, time-sorted control timeline
/// the fleet loop consumes (each down/throttle contributes its heal/end
/// as its own entry). Sort is total over (time, rank, device), so the
/// firing order is deterministic for any script.
fn control_timeline(spec: &ChaosSpec) -> Vec<Ctl> {
    let mut ctl = Vec::new();
    for ev in &spec.events {
        match *ev {
            ChaosEvent::DeviceDown { at_us, device, heal_after_us } => {
                ctl.push(Ctl {
                    at_us,
                    rank: 2,
                    device,
                    kind: CtlKind::Down,
                });
                if let Some(h) = heal_after_us {
                    ctl.push(Ctl {
                        at_us: at_us + h,
                        rank: 0,
                        device,
                        kind: CtlKind::Heal,
                    });
                }
            }
            ChaosEvent::ThermalThrottle {
                at_us,
                device,
                factor,
                duration_us,
            } => {
                ctl.push(Ctl {
                    at_us,
                    rank: 3,
                    device,
                    kind: CtlKind::ThrottleStart { factor },
                });
                ctl.push(Ctl {
                    at_us: at_us + duration_us,
                    rank: 1,
                    device,
                    kind: CtlKind::ThrottleEnd,
                });
            }
        }
    }
    ctl.sort_by(|a, b| {
        a.at_us
            .total_cmp(&b.at_us)
            .then(a.rank.cmp(&b.rank))
            .then(a.device.cmp(&b.device))
    });
    ctl
}

/// An admitted request with nowhere to go: the whole fleet was dark when
/// it needed a device. Flushed on the next heal/attach; anything still
/// here when the run ends is `lost` (terminal outage).
struct PendingReq {
    id: u64,
    arr_us: f64,
    src: usize,
    /// Whether the request had already been placed once (drained off a
    /// dead device — its flush counts as a requeue) or never placed (a
    /// flush is its first routing).
    placed: bool,
}

/// One device kill and the recovery of the requests it was carrying:
/// `recovered_at` is set the moment the last drained request is served
/// somewhere else (tracked by id — ids are fleet-unique, so a request
/// can never be counted served twice).
struct Outage {
    at_us: f64,
    open: HashSet<u64>,
    recovered_at: Option<f64>,
}

/// The fleet's mutable device-topology state, grouped so the chaos /
/// autoscale handlers and the router share one consistent picture.
struct DevCtx {
    specs: Vec<DeviceSpec>,
    cores: Vec<Option<DeviceCore>>,
    state: Vec<DevState>,
    /// Active thermal-throttle factor per device (`None` = full speed).
    throttle: Vec<Option<f64>>,
    /// `env_solo[device][source]` against the device's *effective* spec.
    env_solo: Vec<Vec<f64>>,
    /// Envelope-weighted outstanding work per device (router signal).
    outstanding: Vec<f64>,
    down_since: Vec<f64>,
    live: Vec<bool>,
    fastest_live: usize,
}

impl DevCtx {
    /// The device's GPU spec with any active throttle factor applied to
    /// its compute and memory rates.
    fn effective_gpu(&self, d: usize) -> GpuSpec {
        let mut g = self.specs[d].gpu.clone();
        if let Some(f) = self.throttle[d] {
            g.flops_per_sm_us *= f;
            g.dram_bw_bytes_us *= f;
        }
        g
    }

    fn effective_flops(&self, d: usize) -> f64 {
        let f = self.specs[d].gpu.total_flops_us();
        match self.throttle[d] {
            Some(x) => f * x,
            None => f,
        }
    }

    /// Refresh `live` and `fastest_live` from the state vector: fastest
    /// by *effective* throughput over live devices, strict `>` so ties
    /// stay on the lowest index (with no chaos this reproduces
    /// [`FleetSpec::fastest`] exactly).
    fn recompute_live(&mut self) {
        let mut fastest = 0usize;
        let mut best = f64::NEG_INFINITY;
        for d in 0..self.state.len() {
            self.live[d] = self.state[d] == DevState::Live;
            if self.live[d] {
                let f = self.effective_flops(d);
                if f > best {
                    best = f;
                    fastest = d;
                }
            }
        }
        self.fastest_live = fastest;
    }

    fn any_live(&self) -> bool {
        self.live.iter().any(|&l| l)
    }

    fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Stand a fresh core up on device `d` at simulated time `t`
    /// (heal, attach, or throttle re-clock), refreshing the device's
    /// envelope table against its effective spec and zeroing its
    /// backlog signal (the caller resubmits whatever it drained).
    fn rebuild_core(&mut self, d: usize, t: f64, wl: &Workload)
                    -> Result<(), String> {
        let gpu = self.effective_gpu(d);
        let mut core = DeviceCore::new(&gpu, wl, &self.specs[d].scheduler)?;
        core.advance_to(t);
        self.env_solo[d] = model_envelopes(wl, core.spec(), core.params())
            .iter()
            .map(|e| e.solo_us)
            .collect();
        self.outstanding[d] = 0.0;
        self.cores[d] = Some(core);
        Ok(())
    }
}

/// Fold a finished core's span/events/queue-depth into its device row.
/// Accumulating (max/sum) rather than assigning keeps multi-segment
/// devices (killed and healed) honest while reproducing the single-
/// segment (no-chaos) values bit-for-bit.
fn retire_core(core: DeviceCore, dev: &mut DeviceOutcome) {
    dev.max_normal_queue = dev.max_normal_queue.max(core.max_normal_queue());
    let (span, metrics) = core.finish();
    dev.span_us = dev.span_us.max(span);
    dev.events += metrics.events;
}

/// Place one request on a live device: route (fresh arrivals) or
/// rebalance (requeues) through the router, submit, and account. The
/// fleet loop only calls this while at least one device is live.
#[allow(clippy::too_many_arguments)]
fn place_request(
    ctx: &mut DevCtx,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [crate::server::online::TenantOutcome],
    devices: &mut [DeviceOutcome],
    src: usize,
    arr_us: f64,
    id: u64,
    requeue: bool,
) {
    let crit = wl.sources[src].criticality;
    let d = {
        let view = FleetView {
            outstanding_us: &ctx.outstanding,
            env_solo_us: &ctx.env_solo,
            live: &ctx.live,
            fastest_live: ctx.fastest_live,
        };
        if requeue {
            router.rebalance(src, crit, &view)
        } else {
            router.route(src, crit, &view)
        }
    };
    assert!(d < ctx.cores.len() && ctx.live[d],
            "router {} returned dead device {d}", router.name());
    ctx.cores[d]
        .as_mut()
        .expect("live device has a core")
        .submit(wl, src, arr_us, id);
    let dev = &mut devices[d];
    if requeue {
        dev.requeued_in += 1;
        tenants[src].requeues += 1;
    } else {
        dev.routed += 1;
        match crit {
            Criticality::Critical => dev.routed_critical += 1,
            Criticality::Normal => dev.routed_normal += 1,
        }
    }
    ctx.outstanding[d] += ctx.env_solo[d][src];
}

/// Flush the dark-fleet pending list onto whatever is live now (no-op
/// until a device is). Previously-placed requests count as requeues;
/// never-placed ones count as their first routing.
fn flush_pending(
    ctx: &mut DevCtx,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [crate::server::online::TenantOutcome],
    devices: &mut [DeviceOutcome],
    pending: &mut Vec<PendingReq>,
) {
    if pending.is_empty() || !ctx.any_live() {
        return;
    }
    for p in std::mem::take(pending) {
        place_request(ctx, router, wl, tenants, devices, p.src, p.arr_us,
                      p.id, p.placed);
    }
}

/// Re-clock device `d` at time `t` after its effective spec changed
/// (throttle start/end): drain its open requests, retire the old core,
/// stand a new one up at the new rates, and resubmit the drained
/// requests *to the same device* with their original arrival times —
/// a throttle is a slowdown, not an outage, so nothing is requeued.
fn reclock_device(
    ctx: &mut DevCtx,
    d: usize,
    t: f64,
    wl: &Workload,
    devices: &mut [DeviceOutcome],
) -> Result<(), String> {
    if ctx.cores[d].is_none() {
        return Ok(());
    }
    let mut core = ctx.cores[d].take().expect("checked above");
    let opens = core.drain_open();
    retire_core(core, &mut devices[d]);
    ctx.rebuild_core(d, t, wl)?;
    let core = ctx.cores[d].as_mut().expect("just rebuilt");
    let mut backlog = 0.0f64;
    for &(id, arr, src) in &opens {
        core.submit(wl, src, arr, id);
        backlog += ctx.env_solo[d][src];
    }
    ctx.outstanding[d] = backlog;
    Ok(())
}

/// Build the standby-pool device specs (`s{i}-{preset}`) from an
/// autoscale config, mirroring [`FleetSpec::parse`]'s unknown-preset
/// error.
fn pool_specs(cfg: &AutoscaleConfig) -> Result<Vec<DeviceSpec>, String> {
    let mut out = Vec::with_capacity(cfg.pool.len());
    for (i, p) in cfg.pool.iter().enumerate() {
        let gpu = GpuSpec::by_name(p).ok_or_else(|| {
            format!(
                "unknown standby preset '{p}' (available: {})",
                GpuSpec::PRESET_NAMES.join(", ")
            )
        })?;
        out.push(DeviceSpec {
            name: format!("s{i}-{}", gpu.name),
            gpu,
            scheduler: cfg.scheduler.clone(),
        });
    }
    Ok(out)
}

/// Sort key for the simulated-time timer queues: every timer time here
/// is finite and >= 0, where IEEE-754 bit patterns order exactly like
/// the values — so `BTreeSet<(u64, ..)>` gives a deterministic
/// earliest-first queue without an `Ord` wrapper.
fn time_bits(t: f64) -> u64 {
    debug_assert!(t.is_finite() && t >= 0.0, "timer at {t}");
    t.to_bits()
}

/// One live copy of an open request under the fault layer (a request
/// has one copy normally, two while hedged).
struct FaultCopy {
    device: usize,
    /// Submit time of this copy (the straggle stall scales off the
    /// copy's device dwell time `completion - t_sub`).
    t_sub: f64,
    corrupt: bool,
    straggle: Option<f64>,
    hedge: bool,
}

/// A straggled completion whose *report* is still stalling: the engine
/// finished (residency is free) but the result surfaces later.
struct DeferRec {
    device: usize,
    due_bits: u64,
    hedge: bool,
}

/// Per-request recovery state, alive from admission until the request
/// is served, cancelled, or lost.
struct OpenFault {
    src: usize,
    arr_us: f64,
    crit: bool,
    deadline_us: Option<f64>,
    /// Launch attempts consumed so far — the fault-draw counter
    /// ([`FaultSpec::draw`] is pure in `(id, attempt)`).
    attempt: u32,
    retries_used: u32,
    hedged: bool,
    copies: Vec<FaultCopy>,
    defers: Vec<DeferRec>,
}

/// One due entry popped off the recovery timer queues.
enum FaultTimer {
    /// Re-launch a request whose last attempt failed.
    Retry(u64),
    /// Surface a straggled completion report.
    Defer { id: u64, device: usize, due_bits: u64 },
    /// Consider a hedge copy for a critical request at deadline risk.
    Hedge(u64),
    /// Deadline-cancel a doomed best-effort request.
    Cancel(u64),
}

/// The fault layer's mutable runtime: per-request state, four
/// simulated-time timer queues, and the per-device breaker / brownout
/// machines. Exists only while `FleetOpts::faults` is armed — the
/// fault-free loop never constructs one.
struct Recovery {
    spec: FaultSpec,
    open: HashMap<u64, OpenFault>,
    /// `(time_bits, id)` — deterministic earliest-first, id-tiebroken.
    retry_q: BTreeSet<(u64, u64)>,
    hedge_q: BTreeSet<(u64, u64)>,
    cancel_q: BTreeSet<(u64, u64)>,
    /// `(time_bits, id, device)` — a request can have one deferred
    /// report per device while hedged.
    defer_q: BTreeSet<(u64, u64, usize)>,
    breakers: Vec<Breaker>,
    brownouts: Vec<Brownout>,
}

impl Recovery {
    fn new(spec: FaultSpec, devices: usize) -> Self {
        let r = &spec.recovery;
        let breakers = (0..devices)
            .map(|_| Breaker::new(r.breaker_threshold, r.breaker_cooldown_us))
            .collect();
        let brownouts = (0..devices)
            .map(|_| Brownout::new(r.brownout_high, r.brownout_low))
            .collect();
        Recovery {
            spec,
            open: HashMap::new(),
            retry_q: BTreeSet::new(),
            hedge_q: BTreeSet::new(),
            cancel_q: BTreeSet::new(),
            defer_q: BTreeSet::new(),
            breakers,
            brownouts,
        }
    }

    /// Earliest due timer over all four queues as `(time_bits, rank)`.
    /// Ranks order same-instant timers retry < defer < hedge < cancel,
    /// so a retry that lands a clean copy disarms the same-time cancel.
    fn peek(&self) -> Option<(u64, u8)> {
        let heads = [
            (self.retry_q.iter().next().map(|&(b, _)| b), 0u8),
            (self.defer_q.iter().next().map(|&(b, _, _)| b), 1),
            (self.hedge_q.iter().next().map(|&(b, _)| b), 2),
            (self.cancel_q.iter().next().map(|&(b, _)| b), 3),
        ];
        let mut best: Option<(u64, u8)> = None;
        for (bits, rank) in heads {
            if let Some(b) = bits {
                if best.map_or(true, |(bb, br)| (b, rank) < (bb, br)) {
                    best = Some((b, rank));
                }
            }
        }
        best
    }

    /// Time of the earliest due timer, if any.
    fn next_due_us(&self) -> Option<f64> {
        self.peek().map(|(b, _)| f64::from_bits(b))
    }

    /// Pop the earliest timer (the loop processes exactly one per
    /// iteration, so timer handlers observe each other's effects in a
    /// fixed order).
    fn pop_earliest(&mut self) -> Option<(f64, FaultTimer)> {
        let (bits, rank) = self.peek()?;
        let t = f64::from_bits(bits);
        let timer = match rank {
            0 => {
                let e = *self.retry_q.iter().next().expect("peeked");
                self.retry_q.remove(&e);
                FaultTimer::Retry(e.1)
            }
            1 => {
                let e = *self.defer_q.iter().next().expect("peeked");
                self.defer_q.remove(&e);
                FaultTimer::Defer { id: e.1, device: e.2, due_bits: e.0 }
            }
            2 => {
                let e = *self.hedge_q.iter().next().expect("peeked");
                self.hedge_q.remove(&e);
                FaultTimer::Hedge(e.1)
            }
            _ => {
                let e = *self.cancel_q.iter().next().expect("peeked");
                self.cancel_q.remove(&e);
                FaultTimer::Cancel(e.1)
            }
        };
        Some((t, timer))
    }
}

/// Route one placement with the circuit breakers applied: live devices
/// whose breaker is open are masked out (route-around), falling back to
/// the plain live set if every breaker is open — degraded service beats
/// none. The masked fastest is recomputed with the same strict-`>`
/// lowest-index tiebreak as [`DevCtx::recompute_live`].
fn fault_pick_device(
    ctx: &DevCtx,
    rec: &mut Recovery,
    router: &mut dyn RouterPolicy,
    src: usize,
    crit: Criticality,
    now: f64,
    requeue: bool,
) -> usize {
    let mut allowed = ctx.live.clone();
    for d in 0..allowed.len() {
        if allowed[d] && !rec.breakers[d].allows(now) {
            allowed[d] = false;
        }
    }
    if !allowed.iter().any(|&a| a) {
        allowed.copy_from_slice(&ctx.live);
    }
    let mut fastest = 0usize;
    let mut best = f64::NEG_INFINITY;
    for (d, &a) in allowed.iter().enumerate() {
        if a {
            let f = ctx.effective_flops(d);
            if f > best {
                best = f;
                fastest = d;
            }
        }
    }
    let view = FleetView {
        outstanding_us: &ctx.outstanding,
        env_solo_us: &ctx.env_solo,
        live: &allowed,
        fastest_live: fastest,
    };
    let d = if requeue {
        router.rebalance(src, crit, &view)
    } else {
        router.route(src, crit, &view)
    };
    assert!(d < ctx.cores.len() && allowed[d],
            "router {} returned unavailable device {d}", router.name());
    d
}

/// Terminal-cancel one open request: counted `cancelled` on its tenant
/// (never reached for critical — retry is unbounded and deadline-cancel
/// is best-effort-only), resolved for outage bookkeeping, and its
/// closed-loop slot freed like a served request's. The caller has
/// already cancelled / drained every live copy.
fn fault_cancel_request(
    rec: &mut Recovery,
    wl: &Workload,
    tenants: &mut [TenantOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    id: u64,
    now: f64,
) {
    let o = rec.open.remove(&id).expect("cancelling an unknown request");
    debug_assert!(!o.crit, "critical requests are never cancelled");
    tenants[o.src].cancelled += 1;
    pending.retain(|p| p.id != id);
    for og in outages.iter_mut() {
        if og.recovered_at.is_none()
            && og.open.remove(&id)
            && og.open.is_empty()
        {
            og.recovered_at = Some(now);
        }
    }
    if wl.sources[o.src].arrival.is_closed_loop() && now < wl.duration_us {
        arrivals.push(now, o.src);
    }
}

/// A request just lost its last live copy (failed launch or corrupted
/// output): schedule a retry under deterministic exponential backoff —
/// `backoff_us * 2^min(retries_used, 10)` in simulated time — or, for a
/// best-effort request out of retry budget, cancel it. Critical
/// requests retry without bound: they are never dropped by policy.
fn fault_schedule_recovery(
    rec: &mut Recovery,
    wl: &Workload,
    tenants: &mut [TenantOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    id: u64,
    now: f64,
) {
    let (crit, used) = {
        let o = &rec.open[&id];
        (o.crit, o.retries_used)
    };
    if crit || used < rec.spec.recovery.max_retries {
        let backoff =
            rec.spec.recovery.backoff_us * (1u64 << used.min(10)) as f64;
        rec.retry_q.insert((time_bits(now + backoff), id));
    } else {
        fault_cancel_request(rec, wl, tenants, arrivals, pending, outages,
                             id, now);
    }
}

/// Launch one attempt of request `id` on device `d` through the fault
/// model: a `fail` draw burns the attempt without touching the engine
/// (and schedules recovery if no other copy is live); otherwise the
/// copy is submitted carrying its drawn corrupt/straggle fate.
#[allow(clippy::too_many_arguments)]
fn fault_launch(
    ctx: &mut DevCtx,
    rec: &mut Recovery,
    wl: &Workload,
    tenants: &mut [TenantOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    d: usize,
    id: u64,
    now: f64,
    hedge: bool,
) {
    let (src, att) = {
        let o = rec.open.get_mut(&id).expect("launching unknown request");
        let att = o.attempt;
        o.attempt += 1;
        (o.src, att)
    };
    let draw = rec.spec.draw(id, att);
    if draw.fail {
        rec.breakers[d].on_failure(now);
        let alone = {
            let o = &rec.open[&id];
            o.copies.is_empty() && o.defers.is_empty()
        };
        // A failed hedge attempt is not retried (one hedge per request;
        // the primary copy is still live) — it just never launches.
        if !hedge && alone {
            fault_schedule_recovery(rec, wl, tenants, arrivals, pending,
                                    outages, id, now);
        }
        return;
    }
    let arr = {
        let o = rec.open.get_mut(&id).expect("still open");
        o.copies.push(FaultCopy {
            device: d,
            t_sub: now,
            corrupt: draw.corrupt,
            straggle: draw.straggle,
            hedge,
        });
        o.arr_us
    };
    ctx.cores[d]
        .as_mut()
        .expect("placing on a live device")
        .submit(wl, src, arr, id);
    ctx.outstanding[d] += ctx.env_solo[d][src];
}

/// Close request `id` as served by device `d` at `now`: the **first
/// reported** completion wins. Accounts latency / deadline on the
/// winning device, closes the breaker, feeds the brownout controller,
/// counts a hedge win when the winner was the hedge copy, and cancels
/// the losing copies wherever the policy still can (refusals complete
/// into the void as orphans and release residency then).
#[allow(clippy::too_many_arguments)]
fn fault_report_serve(
    ctx: &mut DevCtx,
    rec: &mut Recovery,
    wl: &Workload,
    ctrl: &mut AdmissionController,
    tenants: &mut [TenantOutcome],
    devices: &mut [DeviceOutcome],
    arrivals: &mut ArrivalQueue,
    outages: &mut [Outage],
    d: usize,
    id: u64,
    now: f64,
    was_hedge: bool,
) {
    let o = rec.open.remove(&id).expect("serving an unknown request");
    let src = o.src;
    rec.breakers[d].on_success();
    ctrl.on_served(src);
    record_served(wl, src, o.arr_us, now, tenants, arrivals);
    let lat = now - o.arr_us;
    let dev = &mut devices[d];
    match wl.sources[src].criticality {
        Criticality::Critical => dev.critical_latencies_us.push(lat),
        Criticality::Normal => dev.normal_latencies_us.push(lat),
    }
    if wl.sources[src].deadline_us.is_some_and(|dl| lat > dl) {
        dev.deadline_misses += 1;
    }
    if was_hedge {
        tenants[src].hedge_wins += 1;
    }
    // Brownout: the winning device observed this critical request's
    // deadline-risk ratio; its hysteresis decides whether to thin the
    // device's best-effort shards (critical geometry is never touched —
    // the coordinator guarantees that).
    if o.crit && rec.spec.recovery.brownout {
        if let Some(dl) = o.deadline_us {
            if let Some(on) = rec.brownouts[d].observe(lat / dl, now) {
                if let Some(core) = ctx.cores[d].as_mut() {
                    core.set_brownout(on);
                }
            }
        }
    }
    for c in &o.copies {
        if c.device == d {
            continue;
        }
        if let Some(core) = ctx.cores[c.device].as_mut() {
            if core.cancel(id).is_some() {
                ctx.outstanding[c.device] = (ctx.outstanding[c.device]
                    - ctx.env_solo[c.device][src])
                    .max(0.0);
            }
        }
    }
    for og in outages.iter_mut() {
        if og.recovered_at.is_none()
            && og.open.remove(&id)
            && og.open.is_empty()
        {
            og.recovered_at = Some(now);
        }
    }
}

/// Process one engine-level completion of request `id` on device `d`
/// under the fault layer: orphans (cancelled / already-won copies) just
/// release their routing signal; corrupted copies fail and may schedule
/// recovery; straggled copies defer their report; clean copies serve.
#[allow(clippy::too_many_arguments)]
fn fault_handle_completion(
    ctx: &mut DevCtx,
    rec: &mut Recovery,
    wl: &Workload,
    ctrl: &mut AdmissionController,
    tenants: &mut [TenantOutcome],
    devices: &mut [DeviceOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    d: usize,
    id: u64,
    src: usize,
    now: f64,
) {
    // The work left the engine either way: release the routing signal.
    ctx.outstanding[d] =
        (ctx.outstanding[d] - ctx.env_solo[d][src]).max(0.0);
    let copy = {
        let Some(o) = rec.open.get_mut(&id) else {
            return;
        };
        let Some(pos) = o.copies.iter().position(|c| c.device == d) else {
            return;
        };
        o.copies.remove(pos)
    };
    if copy.corrupt {
        // Detected at completion: the output is garbage. Corruptions
        // count toward the device's breaker like launch failures.
        rec.breakers[d].on_failure(now);
        let alone = {
            let o = &rec.open[&id];
            o.copies.is_empty() && o.defers.is_empty()
        };
        if alone {
            fault_schedule_recovery(rec, wl, tenants, arrivals, pending,
                                    outages, id, now);
        }
        return;
    }
    if let Some(factor) = copy.straggle {
        // Straggler: the kernels ran at nominal speed — residency is
        // free as of now — but the completion *report* stalls by
        // (factor - 1)x the copy's device dwell time.
        let due = now + (now - copy.t_sub) * (factor - 1.0);
        let due_bits = time_bits(due);
        let o = rec.open.get_mut(&id).expect("still open");
        o.defers.push(DeferRec { device: d, due_bits, hedge: copy.hedge });
        rec.defer_q.insert((due_bits, id, d));
        return;
    }
    fault_report_serve(ctx, rec, wl, ctrl, tenants, devices, arrivals,
                       outages, d, id, now, copy.hedge);
}

/// Admit one request into the fault layer: open its recovery state, arm
/// its hedge (critical) or deadline-cancel (best-effort) timer, and
/// place its first copy — or park it if the whole fleet is dark.
#[allow(clippy::too_many_arguments)]
fn fault_admit(
    ctx: &mut DevCtx,
    rec: &mut Recovery,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [TenantOutcome],
    devices: &mut [DeviceOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    src: usize,
    t: f64,
    id: u64,
) {
    let s = &wl.sources[src];
    let crit = matches!(s.criticality, Criticality::Critical);
    rec.open.insert(id, OpenFault {
        src,
        arr_us: t,
        crit,
        deadline_us: s.deadline_us,
        attempt: 0,
        retries_used: 0,
        hedged: false,
        copies: Vec::new(),
        defers: Vec::new(),
    });
    if let Some(dl) = s.deadline_us {
        if crit && rec.spec.recovery.hedge {
            let at = t + rec.spec.recovery.hedge_watermark * dl;
            rec.hedge_q.insert((time_bits(at), id));
        }
        if !crit && rec.spec.recovery.cancel {
            rec.cancel_q.insert((time_bits(t + dl), id));
        }
    }
    if ctx.any_live() {
        let d = fault_pick_device(ctx, rec, router, src, s.criticality, t,
                                  false);
        let dev = &mut devices[d];
        dev.routed += 1;
        match s.criticality {
            Criticality::Critical => dev.routed_critical += 1,
            Criticality::Normal => dev.routed_normal += 1,
        }
        fault_launch(ctx, rec, wl, tenants, arrivals, pending, outages, d,
                     id, t, false);
    } else {
        pending.push(PendingReq { id, arr_us: t, src, placed: false });
    }
}

/// Re-place a previously-placed request (drained off a dead device or
/// parked): rebalance-routed, counted as a requeue, launched as a fresh
/// attempt.
#[allow(clippy::too_many_arguments)]
fn fault_requeue(
    ctx: &mut DevCtx,
    rec: &mut Recovery,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [TenantOutcome],
    devices: &mut [DeviceOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    id: u64,
    now: f64,
) {
    let (src, crit) = {
        let o = &rec.open[&id];
        (o.src, o.crit)
    };
    let class = if crit { Criticality::Critical } else { Criticality::Normal };
    let d = fault_pick_device(ctx, rec, router, src, class, now, true);
    devices[d].requeued_in += 1;
    tenants[src].requeues += 1;
    fault_launch(ctx, rec, wl, tenants, arrivals, pending, outages, d, id,
                 now, false);
}

/// The fault-layer counterpart of [`flush_pending`]: relaunch every
/// parked request through the fault model once a device is live again.
#[allow(clippy::too_many_arguments)]
fn fault_flush_pending(
    ctx: &mut DevCtx,
    rec: &mut Recovery,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [TenantOutcome],
    devices: &mut [DeviceOutcome],
    arrivals: &mut ArrivalQueue,
    pending: &mut Vec<PendingReq>,
    outages: &mut [Outage],
    t: f64,
) {
    if pending.is_empty() || !ctx.any_live() {
        return;
    }
    for p in std::mem::take(pending) {
        if !rec.open.contains_key(&p.id) {
            continue; // cancelled while parked
        }
        if p.placed {
            fault_requeue(ctx, rec, router, wl, tenants, devices, arrivals,
                          pending, outages, p.id, t);
        } else {
            let class = wl.sources[p.src].criticality;
            let d = fault_pick_device(ctx, rec, router, p.src, class, t,
                                      false);
            let dev = &mut devices[d];
            dev.routed += 1;
            match class {
                Criticality::Critical => dev.routed_critical += 1,
                Criticality::Normal => dev.routed_normal += 1,
            }
            fault_launch(ctx, rec, wl, tenants, arrivals, pending, outages,
                         d, p.id, t, false);
        }
    }
}

/// Serve one scenario across the fleet until every device drains.
/// Deterministic for a given (scenario, seed, devices, router, policy,
/// chaos, autoscale, faults): the loop advances in simulated time only,
/// ties (arrival vs event vs control vs fault timer, device vs device)
/// break the same way every run, and no host timing enters the report.
pub fn run_fleet(fleet: &FleetSpec, sc: &ScenarioSpec, opts: &FleetOpts)
                 -> Result<FleetReport, String> {
    if fleet.devices.is_empty() {
        return Err("a fleet needs at least one device".into());
    }
    validate_admission(&opts.admission)?;
    let pool: Vec<DeviceSpec> = match &opts.autoscale {
        Some(a) => {
            a.validate()?;
            pool_specs(a)?
        }
        None => Vec::new(),
    };
    let pool_start = fleet.devices.len();
    let total = pool_start + pool.len();
    opts.chaos.validate(total)?;
    let mut router = router_for(&opts.router, total).ok_or_else(|| {
        format!(
            "unknown router {} (available: {})",
            opts.router,
            ROUTERS.join(", ")
        )
    })?;
    if let Some(f) = &opts.faults {
        f.validate()?;
    }
    // An inert spec is normalized away entirely: the fault layer is
    // not just dormant but absent, so zero-fault runs are bitwise
    // identical to pre-fault builds.
    let fault_spec = opts.faults.clone().filter(|f| !f.is_inert());
    let resilience = !opts.chaos.is_empty() || opts.autoscale.is_some()
        || fault_spec.is_some();

    let mut wl = sc.build();
    if let Some(seed) = opts.seed {
        wl.seed = seed;
    }
    let mut specs = fleet.devices.clone();
    specs.extend(pool.iter().cloned());
    let mut cores: Vec<Option<DeviceCore>> = Vec::with_capacity(total);
    let mut env_solo: Vec<Vec<f64>> = Vec::with_capacity(total);
    for d in &fleet.devices {
        let core = DeviceCore::new(&d.gpu, &wl, &d.scheduler)?;
        env_solo.push(
            model_envelopes(&wl, core.spec(), core.params())
                .iter()
                .map(|e| e.solo_us)
                .collect(),
        );
        cores.push(Some(core));
    }
    for d in &pool {
        // Validate the standby scheduler now so an attach cannot fail
        // mid-run; the throwaway core never joins the fleet and the
        // real envelope table is computed at attach time.
        DeviceCore::new(&d.gpu, &wl, &d.scheduler)?;
        env_solo.push(vec![0.0; wl.sources.len()]);
        cores.push(None);
    }

    // One fleet-wide admission controller. Its envelopes are derived
    // against the *nominal fastest* device (best-placement estimates,
    // unaffected by transient chaos — see the module docs); in a
    // 1-device fleet that is the device itself, which keeps the
    // serve-sim differential contract exact.
    let fastest = fleet.fastest();
    let mut ctrl = AdmissionController::new(
        opts.policy,
        opts.admission.clone(),
        &wl,
        cores[fastest].as_ref().expect("primaries start live").spec(),
        cores[fastest].as_ref().expect("primaries start live").params(),
    );

    let mut state = vec![DevState::Live; pool_start];
    state.extend(vec![DevState::Standby; pool.len()]);
    let mut ctx = DevCtx {
        specs,
        cores,
        state,
        throttle: vec![None; total],
        env_solo,
        outstanding: vec![0.0f64; total],
        down_since: vec![0.0f64; total],
        live: vec![false; total],
        fastest_live: 0,
    };
    ctx.recompute_live();

    let ctl = control_timeline(&opts.chaos);
    let mut ctl_i = 0usize;
    let mut scaler = opts.autoscale.clone().map(Autoscaler::new);
    let mut pending: Vec<PendingReq> = Vec::new();
    let mut outages: Vec<Outage> = Vec::new();
    let mut attaches = 0u64;
    let mut detaches = 0u64;

    let mut rng = Rng::new(wl.seed);
    let mut arrivals = initial_arrivals(&wl, &mut rng);
    let mut tenants = tenant_outcomes(sc, &wl);
    let mut devices: Vec<DeviceOutcome> = ctx
        .specs
        .iter()
        .map(|d| DeviceOutcome {
            desc: DeviceDesc {
                name: d.name.clone(),
                platform: d.gpu.name.clone(),
                scheduler: d.scheduler.clone(),
            },
            routed: 0,
            routed_critical: 0,
            routed_normal: 0,
            deadline_misses: 0,
            critical_latencies_us: Vec::new(),
            normal_latencies_us: Vec::new(),
            span_us: 0.0,
            events: 0,
            max_normal_queue: 0,
            requeued_in: 0,
            downtime_us: 0.0,
            breaker_trips: 0,
            brownout_us: 0.0,
        })
        .collect();
    let mut next_id: u64 = 1;
    let mut rec = fault_spec.map(|spec| Recovery::new(spec, total));

    loop {
        let t_arr = arrivals.peek().map(|(t, _)| t);
        // Earliest device event; ties break toward the lowest index
        // (strict `<`), so the step order is deterministic.
        let mut t_ev: Option<(f64, usize)> = None;
        for (d, core) in ctx.cores.iter_mut().enumerate() {
            if let Some(core) = core {
                if let Some(t) = core.next_event_time() {
                    if t_ev.map_or(true, |(tb, _)| t < tb) {
                        t_ev = Some((t, d));
                    }
                }
            }
        }
        let t_chaos = ctl.get(ctl_i).map(|c| c.at_us);
        let t_tick = scaler.as_ref().and_then(|s| s.next_eval_us());
        let t_ctl = match (t_chaos, t_tick) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let t_flt = rec.as_ref().and_then(|r| r.next_due_us());
        // Control (chaos / autoscale tick) preempts arrivals, events
        // and fault timers at the same instant: a device killed at t
        // never sees t's arrivals, and control still fires after the
        // queues drain (a terminal heal must flush the pending list).
        let ctl_due = match t_ctl {
            Some(tc) => {
                t_arr.map_or(true, |ta| tc <= ta)
                    && t_ev.map_or(true, |(te, _)| tc <= te)
                    && t_flt.map_or(true, |tf| tc <= tf)
            }
            None => false,
        };
        if ctl_due {
            let t = t_ctl.expect("ctl_due implies a control time");
            for core in ctx.cores.iter_mut().flatten() {
                core.advance_to(t);
            }
            let fire_chaos = match (t_chaos, t_tick) {
                (Some(a), Some(b)) => a <= b,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if fire_chaos {
                let c = ctl[ctl_i];
                ctl_i += 1;
                match c.kind {
                    CtlKind::Down => {
                        let d = c.device;
                        if matches!(ctx.state[d],
                                    DevState::Live | DevState::Draining)
                        {
                            let mut core = ctx.cores[d]
                                .take()
                                .expect("live device has a core");
                            let opens = core.drain_open();
                            retire_core(core, &mut devices[d]);
                            ctx.state[d] = DevState::Down;
                            ctx.down_since[d] = t;
                            ctx.outstanding[d] = 0.0;
                            ctx.recompute_live();
                            if let Some(r) = rec.as_mut() {
                                // The device's brownout span ends with
                                // it; the breaker keeps its state for
                                // the heal (a flaky device stays
                                // suspect).
                                r.brownouts[d].reset(t);
                                let mut o = Outage {
                                    at_us: t,
                                    open: opens
                                        .iter()
                                        .filter(|&&(id, _, _)| {
                                            r.open.contains_key(&id)
                                        })
                                        .map(|&(id, _, _)| id)
                                        .collect(),
                                    recovered_at: None,
                                };
                                if o.open.is_empty() {
                                    o.recovered_at = Some(t);
                                }
                                outages.push(o);
                                for (id, arr, src) in opens {
                                    // Drop this device's copy record;
                                    // replace only a request with no
                                    // surviving copy or pending report
                                    // (served/cancelled ids died as
                                    // orphans and need nothing).
                                    let replace =
                                        match r.open.get_mut(&id) {
                                            Some(of) => {
                                                of.copies.retain(|c| {
                                                    c.device != d
                                                });
                                                of.copies.is_empty()
                                                    && of.defers.is_empty()
                                            }
                                            None => false,
                                        };
                                    if !replace {
                                        continue;
                                    }
                                    if ctx.any_live() {
                                        fault_requeue(
                                            &mut ctx, r, router.as_mut(),
                                            &wl, &mut tenants,
                                            &mut devices, &mut arrivals,
                                            &mut pending, &mut outages,
                                            id, t,
                                        );
                                    } else {
                                        pending.push(PendingReq {
                                            id,
                                            arr_us: arr,
                                            src,
                                            placed: true,
                                        });
                                    }
                                }
                            } else {
                                let mut o = Outage {
                                    at_us: t,
                                    open: opens
                                        .iter()
                                        .map(|&(id, _, _)| id)
                                        .collect(),
                                    recovered_at: None,
                                };
                                if o.open.is_empty() {
                                    o.recovered_at = Some(t);
                                }
                                outages.push(o);
                                if ctx.any_live() {
                                    for (id, arr, src) in opens {
                                        place_request(
                                            &mut ctx, router.as_mut(),
                                            &wl, &mut tenants,
                                            &mut devices, src, arr, id,
                                            true,
                                        );
                                    }
                                } else {
                                    for (id, arr, src) in opens {
                                        pending.push(PendingReq {
                                            id,
                                            arr_us: arr,
                                            src,
                                            placed: true,
                                        });
                                    }
                                }
                            }
                        }
                    }
                    CtlKind::Heal => {
                        let d = c.device;
                        if ctx.state[d] == DevState::Down {
                            devices[d].downtime_us += t - ctx.down_since[d];
                            ctx.rebuild_core(d, t, &wl)?;
                            ctx.state[d] = DevState::Live;
                            ctx.recompute_live();
                            if let Some(r) = rec.as_mut() {
                                fault_flush_pending(
                                    &mut ctx, r, router.as_mut(), &wl,
                                    &mut tenants, &mut devices,
                                    &mut arrivals, &mut pending,
                                    &mut outages, t,
                                );
                            } else {
                                flush_pending(&mut ctx, router.as_mut(),
                                              &wl, &mut tenants,
                                              &mut devices, &mut pending);
                            }
                        }
                    }
                    CtlKind::ThrottleStart { factor } => {
                        let d = c.device;
                        ctx.throttle[d] = Some(factor);
                        reclock_device(&mut ctx, d, t, &wl, &mut devices)?;
                        ctx.recompute_live();
                    }
                    CtlKind::ThrottleEnd => {
                        let d = c.device;
                        ctx.throttle[d] = None;
                        reclock_device(&mut ctx, d, t, &wl, &mut devices)?;
                        ctx.recompute_live();
                    }
                }
            } else {
                // Autoscale evaluation tick.
                let live_count = ctx.live_count();
                let backlog: f64 = ctx
                    .outstanding
                    .iter()
                    .zip(&ctx.live)
                    .filter(|&(_, &l)| l)
                    .map(|(o, _)| o)
                    .sum();
                let per_live = if live_count > 0 {
                    backlog / live_count as f64
                } else {
                    f64::INFINITY
                };
                let attach_target = (pool_start..total)
                    .find(|&d| ctx.state[d] == DevState::Standby);
                let detach_target = (pool_start..total)
                    .rev()
                    .find(|&d| ctx.state[d] == DevState::Live);
                let can_detach = detach_target.is_some() && live_count > 1;
                let s = scaler.as_mut().expect("tick implies a scaler");
                match s.evaluate(t, per_live, attach_target.is_some(),
                                 can_detach)
                {
                    ScaleAction::Attach => {
                        let d = attach_target.expect("evaluate checked");
                        ctx.rebuild_core(d, t, &wl)?;
                        ctx.state[d] = DevState::Live;
                        attaches += 1;
                        ctx.recompute_live();
                        if let Some(r) = rec.as_mut() {
                            fault_flush_pending(
                                &mut ctx, r, router.as_mut(), &wl,
                                &mut tenants, &mut devices, &mut arrivals,
                                &mut pending, &mut outages, t,
                            );
                        } else {
                            flush_pending(&mut ctx, router.as_mut(), &wl,
                                          &mut tenants, &mut devices,
                                          &mut pending);
                        }
                    }
                    ScaleAction::Detach => {
                        let d = detach_target.expect("evaluate checked");
                        let open = ctx.cores[d]
                            .as_ref()
                            .map_or(0, |c| c.open_count());
                        if open == 0 {
                            if let Some(core) = ctx.cores[d].take() {
                                retire_core(core, &mut devices[d]);
                            }
                            ctx.state[d] = DevState::Standby;
                            ctx.outstanding[d] = 0.0;
                        } else {
                            // Graceful: stop routing here, park it once
                            // its open requests drain (see step branch).
                            ctx.state[d] = DevState::Draining;
                        }
                        detaches += 1;
                        ctx.recompute_live();
                    }
                    ScaleAction::Hold => {}
                }
                let work_remains = !arrivals.is_empty()
                    || !pending.is_empty()
                    || ctx.cores.iter().flatten().any(|c| c.open_count() > 0)
                    || rec.as_ref().map_or(false, |r| !r.open.is_empty());
                s.schedule_next(t, work_remains);
            }
            continue;
        }
        // Fault timers preempt arrivals and events at the same instant
        // (control already preempted them above): exactly one timer is
        // processed per iteration, so handlers observe each other's
        // effects in the fixed (time, kind, id) order.
        let flt_due = match t_flt {
            Some(tf) => {
                t_arr.map_or(true, |ta| tf <= ta)
                    && t_ev.map_or(true, |(te, _)| tf <= te)
            }
            None => false,
        };
        if flt_due {
            let tf = t_flt.expect("flt_due implies a timer");
            for core in ctx.cores.iter_mut().flatten() {
                core.advance_to(tf);
            }
            let r = rec.as_mut().expect("a timer implies the fault layer");
            let (_, timer) =
                r.pop_earliest().expect("flt_due implies a timer");
            match timer {
                FaultTimer::Retry(id) => {
                    // Stale once the request closed or regrew a copy
                    // (it never does between failure and retry, but
                    // stay defensive — skipping is always safe).
                    let state = r.open.get(&id).map(|o| {
                        (o.copies.is_empty() && o.defers.is_empty(),
                         o.src, o.arr_us, o.crit)
                    });
                    if let Some((idle, src, arr, crit)) = state {
                        if idle && ctx.any_live() {
                            r.open
                                .get_mut(&id)
                                .expect("checked open")
                                .retries_used += 1;
                            tenants[src].retries += 1;
                            let class = if crit {
                                Criticality::Critical
                            } else {
                                Criticality::Normal
                            };
                            let d = fault_pick_device(
                                &ctx, r, router.as_mut(), src, class, tf,
                                false,
                            );
                            fault_launch(&mut ctx, r, &wl, &mut tenants,
                                         &mut arrivals, &mut pending,
                                         &mut outages, d, id, tf, false);
                        } else if idle {
                            // Whole fleet dark: park it; the next
                            // heal/attach flush relaunches it (or the
                            // run ends and it counts lost).
                            pending.push(PendingReq {
                                id,
                                arr_us: arr,
                                src,
                                placed: true,
                            });
                        }
                    }
                }
                FaultTimer::Defer { id, device, due_bits } => {
                    let hit = r.open.get_mut(&id).and_then(|o| {
                        o.defers
                            .iter()
                            .position(|dr| {
                                dr.device == device
                                    && dr.due_bits == due_bits
                            })
                            .map(|pos| o.defers.remove(pos))
                    });
                    if let Some(dr) = hit {
                        fault_report_serve(
                            &mut ctx, r, &wl, &mut ctrl, &mut tenants,
                            &mut devices, &mut arrivals, &mut outages,
                            device, id, tf, dr.hedge,
                        );
                    }
                }
                FaultTimer::Hedge(id) => {
                    // Hedge only a still-open, not-yet-hedged request
                    // with a live or deferred copy (a copy-less request
                    // is already in the retry path). One hedge per
                    // request, ever.
                    let plan = match r.open.get(&id) {
                        Some(o)
                            if !o.hedged
                                && (!o.copies.is_empty()
                                    || !o.defers.is_empty()) =>
                        {
                            let mut ex: Vec<usize> = o
                                .copies
                                .iter()
                                .map(|c| c.device)
                                .collect();
                            ex.extend(o.defers.iter().map(|d| d.device));
                            Some((o.src, ex))
                        }
                        _ => None,
                    };
                    if let Some((src, exclude)) = plan {
                        // Fastest live breaker-allowed device not
                        // already carrying this request; a 1-device
                        // fleet has nowhere to hedge.
                        let mut target: Option<usize> = None;
                        let mut best = f64::NEG_INFINITY;
                        for d in 0..ctx.live.len() {
                            if ctx.live[d]
                                && !exclude.contains(&d)
                                && r.breakers[d].allows(tf)
                            {
                                let f = ctx.effective_flops(d);
                                if f > best {
                                    best = f;
                                    target = Some(d);
                                }
                            }
                        }
                        if let Some(d) = target {
                            r.open
                                .get_mut(&id)
                                .expect("checked open")
                                .hedged = true;
                            tenants[src].hedges += 1;
                            fault_launch(&mut ctx, r, &wl, &mut tenants,
                                         &mut arrivals, &mut pending,
                                         &mut outages, d, id, tf, true);
                        }
                    }
                }
                FaultTimer::Cancel(id) => {
                    // Deadline passed for a best-effort request: cancel
                    // wherever the policy still can. Dispatched work
                    // cannot be recalled — if any copy refuses, the
                    // request runs on and is served late instead.
                    let plan = match r.open.get(&id) {
                        Some(o) if o.defers.is_empty() => Some((
                            o.src,
                            o.copies
                                .iter()
                                .map(|c| c.device)
                                .collect::<Vec<_>>(),
                        )),
                        _ => None,
                    };
                    if let Some((src, copy_devs)) = plan {
                        let mut all = true;
                        let mut gone: Vec<usize> = Vec::new();
                        for d in copy_devs {
                            let ok = ctx.cores[d]
                                .as_mut()
                                .map_or(false,
                                        |c| c.cancel(id).is_some());
                            if ok {
                                gone.push(d);
                            } else {
                                all = false;
                            }
                        }
                        for &d in &gone {
                            ctx.outstanding[d] = (ctx.outstanding[d]
                                - ctx.env_solo[d][src])
                                .max(0.0);
                        }
                        r.open
                            .get_mut(&id)
                            .expect("still open")
                            .copies
                            .retain(|c| !gone.contains(&c.device));
                        if all {
                            fault_cancel_request(
                                r, &wl, &mut tenants, &mut arrivals,
                                &mut pending, &mut outages, id, tf,
                            );
                        }
                    }
                }
            }
            continue;
        }
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |(t, _)| ta <= t) => {
                // ta precedes every device's next event, so advancing the
                // whole fleet cannot skip one; devices therefore observe
                // arrivals on a common clock.
                for core in ctx.cores.iter_mut().flatten() {
                    core.advance_to(ta);
                }
                while let Some((t, src)) = arrivals.peek() {
                    if t > ta {
                        break;
                    }
                    arrivals.pop();
                    tenants[src].offered += 1;
                    match ctrl.decide(src, t) {
                        Decision::Admitted => {
                            tenants[src].admitted += 1;
                            let id = next_id;
                            next_id += 1;
                            if let Some(r) = rec.as_mut() {
                                fault_admit(
                                    &mut ctx, r, router.as_mut(), &wl,
                                    &mut tenants, &mut devices,
                                    &mut arrivals, &mut pending,
                                    &mut outages, src, t, id,
                                );
                            } else if ctx.any_live() {
                                place_request(
                                    &mut ctx, router.as_mut(), &wl,
                                    &mut tenants, &mut devices, src, t,
                                    id, false,
                                );
                            } else {
                                pending.push(PendingReq {
                                    id,
                                    arr_us: t,
                                    src,
                                    placed: false,
                                });
                            }
                        }
                        Decision::Shed(_) => {
                            shed_arrival(&wl, src, t, &opts.admission,
                                         &mut tenants, &mut arrivals);
                        }
                    }
                }
                for core in ctx.cores.iter_mut().flatten() {
                    core.sample_queue_depth();
                }
            }
            (_, Some((_, d))) => {
                let mut core =
                    ctx.cores[d].take().expect("stepping a missing core");
                if rec.is_some() {
                    // Completions are collected first and routed through
                    // the fault layer after the core is back in place —
                    // recovery may need every device (hedge-loser
                    // cancels, breaker routing on retries).
                    let mut comps: Vec<(u64, usize, f64, f64)> =
                        Vec::new();
                    core.step(|id, src, arr, now| {
                        comps.push((id, src, arr, now));
                    });
                    ctx.cores[d] = Some(core);
                    let r = rec.as_mut().expect("checked above");
                    for (id, src, _arr, now) in comps {
                        fault_handle_completion(
                            &mut ctx, r, &wl, &mut ctrl, &mut tenants,
                            &mut devices, &mut arrivals, &mut pending,
                            &mut outages, d, id, src, now,
                        );
                    }
                    if ctx.state[d] == DevState::Draining
                        && ctx.cores[d]
                            .as_ref()
                            .map_or(true, |c| c.open_count() == 0)
                    {
                        if let Some(core) = ctx.cores[d].take() {
                            retire_core(core, &mut devices[d]);
                        }
                        ctx.state[d] = DevState::Standby;
                        ctx.outstanding[d] = 0.0;
                        ctx.recompute_live();
                    }
                    continue;
                }
                {
                    let dev = &mut devices[d];
                    let out_d = &mut ctx.outstanding[d];
                    let env_d = &ctx.env_solo[d];
                    core.step(|id, src, arr, now| {
                        ctrl.on_served(src);
                        record_served(&wl, src, arr, now, &mut tenants,
                                      &mut arrivals);
                        let lat = now - arr;
                        match wl.sources[src].criticality {
                            Criticality::Critical => {
                                dev.critical_latencies_us.push(lat);
                            }
                            Criticality::Normal => {
                                dev.normal_latencies_us.push(lat);
                            }
                        }
                        if wl.sources[src]
                            .deadline_us
                            .is_some_and(|dl| lat > dl)
                        {
                            dev.deadline_misses += 1;
                        }
                        *out_d = (*out_d - env_d[src]).max(0.0);
                        // Outage recovery bookkeeping: remove/is_empty
                        // only — no set iteration, so no HashSet order
                        // dependence.
                        for o in outages.iter_mut() {
                            if o.recovered_at.is_none()
                                && o.open.remove(&id)
                                && o.open.is_empty()
                            {
                                o.recovered_at = Some(now);
                            }
                        }
                    });
                }
                if ctx.state[d] == DevState::Draining
                    && core.open_count() == 0
                {
                    retire_core(core, &mut devices[d]);
                    ctx.state[d] = DevState::Standby;
                    ctx.outstanding[d] = 0.0;
                    ctx.recompute_live();
                } else {
                    ctx.cores[d] = Some(core);
                }
            }
            // (Some, None) with a failed guard cannot occur: the guard is
            // vacuously true when no device has a next event.
            _ => unreachable!("fleet loop: impossible arrival/event state"),
        }
    }

    // Whatever is still pending was admitted into a fleet that never
    // came back: lost to a terminal outage.
    for p in &pending {
        tenants[p.src].lost += 1;
    }
    if let Some(r) = &rec {
        // A fault-layer request still open but not parked was stranded
        // mid-recovery by a terminal outage (every live copy, defer, or
        // timer would have kept the loop running): count it lost so
        // `admitted == served + lost + cancelled` stays exact.
        let parked: HashSet<u64> = pending.iter().map(|p| p.id).collect();
        for (id, o) in &r.open {
            if !parked.contains(id) {
                tenants[o.src].lost += 1;
            }
        }
    }
    for (core, dev) in ctx.cores.iter_mut().zip(&mut devices) {
        if let Some(core) = core.take() {
            retire_core(core, dev);
        }
    }
    let mut span_us = 0.0f64;
    let mut events = 0u64;
    for dev in &devices {
        span_us = span_us.max(dev.span_us);
        events += dev.events;
    }
    for (d, dev) in devices.iter_mut().enumerate() {
        if ctx.state[d] == DevState::Down {
            dev.downtime_us += (span_us - ctx.down_since[d]).max(0.0);
        }
    }
    if let Some(r) = rec.as_mut() {
        for (d, dev) in devices.iter_mut().enumerate() {
            dev.breaker_trips = r.breakers[d].trips();
            dev.brownout_us = r.brownouts[d].finish(span_us);
        }
    }
    let recovery_us = outages
        .iter()
        .filter_map(|o| o.recovered_at.map(|r| r - o.at_us))
        .fold(f64::NAN, f64::max);
    Ok(FleetReport {
        scenario: sc.name.clone(),
        router: opts.router.clone(),
        policy: opts.policy,
        seed: wl.seed,
        duration_us: wl.duration_us,
        devices,
        tenants,
        span_us,
        events,
        critical_at_risk: ctrl.critical_at_risk(),
        chaos: opts.chaos.name.clone(),
        chaos_events: opts.chaos.events.len() as u64,
        recovery_us,
        attaches,
        detaches,
        resilience,
        faults: rec.is_some(),
        fault_script: rec
            .as_ref()
            .map_or_else(|| "none".to_string(), |r| r.spec.name.clone()),
    })
}

/// Run the scenarios × routers grid (scenario-major order) across a
/// scoped worker pool and assemble the [`FleetGridReport`]. Cells are
/// independent deterministic simulations landing in per-cell slots, so
/// the report — and its `BENCH_fleet.json` — is **byte-identical for any
/// `threads` value**. `base` provides the policy, seed override and
/// admission tunables; its `router` field is ignored in favor of the
/// `routers` list.
pub fn run_fleet_grid(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    routers: &[String],
    base: &FleetOpts,
    threads: usize,
) -> Result<FleetGridReport, String> {
    if scenarios.is_empty() {
        return Err("fleet grid needs at least one scenario".into());
    }
    if routers.is_empty() {
        return Err("fleet grid needs at least one router".into());
    }
    // Validate the whole grid up front so workers cannot hit a config
    // error mid-pool.
    validate_admission(&base.admission)?;
    for r in routers {
        if router_for(r, fleet.devices.len().max(1)).is_none() {
            return Err(format!(
                "unknown router {r} (available: {})",
                ROUTERS.join(", ")
            ));
        }
    }
    let cells: Vec<(usize, usize)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(si, _)| (0..routers.len()).map(move |ri| (si, ri)))
        .collect();
    let n = cells.len();
    let slots: Vec<Mutex<Option<Result<FleetReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    // Same pool skeleton as `miriam sweep`: per-cell slots keep results
    // position-stable for any thread count.
    crate::coordinator::sweep::run_indexed(n, threads, |i| {
        let (si, ri) = cells[i];
        let opts = FleetOpts { router: routers[ri].clone(), ..base.clone() };
        *slots[i].lock().unwrap() =
            Some(run_fleet(fleet, &scenarios[si], &opts));
    });
    let cells = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FleetGridReport {
        devices: fleet.descs(),
        policy: base.policy.name().to_string(),
        duration_us: scenarios[0].duration_us,
        routers: routers.to_vec(),
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        cells,
        isolation: Vec::new(),
    })
}

/// Re-run `base_grid`'s scenarios × routers cells with every device on
/// each hard-isolation split in `splits` (names like `isolation:70/30`,
/// pre-validated by the CLI against each device's SM count) and return
/// the isolation-vs-elasticity comparison rows for `BENCH_fleet.json`
/// (ISSUE 9). Split-major, then the base grid's cell order, each split
/// re-using the grid runner — so the rows inherit its byte-determinism
/// across `--threads` values.
pub fn run_isolation_comparison(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    routers: &[String],
    base: &FleetOpts,
    splits: &[String],
    base_grid: &FleetGridReport,
    threads: usize,
) -> Result<Vec<IsolationFleetRow>, String> {
    let mut rows = Vec::new();
    for split in splits {
        let mut iso_fleet = fleet.clone();
        for d in &mut iso_fleet.devices {
            d.scheduler = split.clone();
        }
        let grid =
            run_fleet_grid(&iso_fleet, scenarios, routers, base, threads)?;
        for cell in &grid.cells {
            let Some(b) = base_grid.cell(&cell.scenario, &cell.router) else {
                return Err(format!(
                    "isolation comparison: base grid has no cell \
                     {}/{}", cell.scenario, cell.router));
            };
            rows.push(IsolationFleetRow {
                scheduler: split.clone(),
                scenario: cell.scenario.clone(),
                router: cell.router.clone(),
                crit_p99_us: cell.crit_p99_us(),
                throughput_rps: cell.throughput_rps(),
                base_crit_p99_us: b.crit_p99_us(),
                base_throughput_rps: b.throughput_rps(),
            });
        }
    }
    Ok(rows)
}

/// Run the scenarios × storms × routers resilience grid (scenario-major,
/// then storm, then router) across a scoped worker pool and assemble the
/// [`ResilienceGridReport`] (`BENCH_resilience.json`). Storm scripts are
/// generated per scenario window, so every cell of one storm column runs
/// the same named weather scaled to its scenario. Byte-identical for any
/// `threads` value, like [`run_fleet_grid`].
pub fn run_resilience_grid(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    storms: &[String],
    routers: &[String],
    base: &FleetOpts,
    threads: usize,
) -> Result<ResilienceGridReport, String> {
    if scenarios.is_empty() {
        return Err("resilience grid needs at least one scenario".into());
    }
    if storms.is_empty() {
        return Err("resilience grid needs at least one storm".into());
    }
    if routers.is_empty() {
        return Err("resilience grid needs at least one router".into());
    }
    validate_admission(&base.admission)?;
    for r in routers {
        if router_for(r, fleet.devices.len().max(1)).is_none() {
            return Err(format!(
                "unknown router {r} (available: {})",
                ROUTERS.join(", ")
            ));
        }
    }
    for s in storms {
        if chaos::storm(s, fleet.devices.len(), scenarios[0].duration_us)
            .is_none()
        {
            return Err(format!(
                "unknown storm '{s}' (available: {})",
                STORMS.join(", ")
            ));
        }
    }
    let mut devices = fleet.descs();
    if let Some(a) = &base.autoscale {
        a.validate()?;
        devices.extend(pool_specs(a)?.iter().map(|d| DeviceDesc {
            name: d.name.clone(),
            platform: d.gpu.name.clone(),
            scheduler: d.scheduler.clone(),
        }));
    }
    let cells: Vec<(usize, usize, usize)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(si, _)| {
            (0..storms.len()).flat_map(move |ti| {
                (0..routers.len()).map(move |ri| (si, ti, ri))
            })
        })
        .collect();
    let n = cells.len();
    let slots: Vec<Mutex<Option<Result<FleetReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    crate::coordinator::sweep::run_indexed(n, threads, |i| {
        let (si, ti, ri) = cells[i];
        let sc = &scenarios[si];
        let opts = FleetOpts {
            router: routers[ri].clone(),
            chaos: chaos::storm(&storms[ti], fleet.devices.len(),
                                sc.duration_us)
                .expect("storms validated above"),
            ..base.clone()
        };
        *slots[i].lock().unwrap() = Some(run_fleet(fleet, sc, &opts));
    });
    let cells = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(ResilienceGridReport {
        devices,
        policy: base.policy.name().to_string(),
        duration_us: scenarios[0].duration_us,
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        storms: storms.to_vec(),
        routers: routers.to_vec(),
        cells,
    })
}

/// Run the scenarios × fault-specs × routers grid (scenario-major, then
/// fault spec, then router) across a scoped worker pool and assemble
/// the [`FaultsGridReport`] (`BENCH_faults.json`). `specs` come from
/// [`faults::resolve_storms`] (presets) or [`FaultSpec::parse`] (the
/// `--faults` DSL); an inert spec — the `"none"` baseline cell — runs
/// with the fault layer absent, so that column doubles as the calm
/// reference the hedging-effectiveness comparisons divide by. Fault
/// draws are pure in `(seed, id, attempt)` and every cell lands in its
/// own slot, so the report is **byte-identical for any `threads`
/// value**, like [`run_fleet_grid`].
pub fn run_faults_grid(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    specs: &[FaultSpec],
    routers: &[String],
    base: &FleetOpts,
    threads: usize,
) -> Result<FaultsGridReport, String> {
    if scenarios.is_empty() {
        return Err("faults grid needs at least one scenario".into());
    }
    if specs.is_empty() {
        return Err("faults grid needs at least one fault spec".into());
    }
    if routers.is_empty() {
        return Err("faults grid needs at least one router".into());
    }
    validate_admission(&base.admission)?;
    for r in routers {
        if router_for(r, fleet.devices.len().max(1)).is_none() {
            return Err(format!(
                "unknown router {r} (available: {})",
                ROUTERS.join(", ")
            ));
        }
    }
    for s in specs {
        s.validate()?;
    }
    let mut devices = fleet.descs();
    if let Some(a) = &base.autoscale {
        a.validate()?;
        devices.extend(pool_specs(a)?.iter().map(|d| DeviceDesc {
            name: d.name.clone(),
            platform: d.gpu.name.clone(),
            scheduler: d.scheduler.clone(),
        }));
    }
    let cells: Vec<(usize, usize, usize)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(si, _)| {
            (0..specs.len()).flat_map(move |fi| {
                (0..routers.len()).map(move |ri| (si, fi, ri))
            })
        })
        .collect();
    let n = cells.len();
    let slots: Vec<Mutex<Option<Result<FleetReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    crate::coordinator::sweep::run_indexed(n, threads, |i| {
        let (si, fi, ri) = cells[i];
        let opts = FleetOpts {
            router: routers[ri].clone(),
            faults: Some(specs[fi].clone()),
            ..base.clone()
        };
        *slots[i].lock().unwrap() =
            Some(run_fleet(fleet, &scenarios[si], &opts));
    });
    let cells = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FaultsGridReport {
        devices,
        policy: base.policy.name().to_string(),
        duration_us: scenarios[0].duration_us,
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        faults: specs.iter().map(|s| s.name.clone()).collect(),
        routers: routers.to_vec(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::scenario;

    const DUR_US: f64 = 20_000.0;

    fn duo() -> ScenarioSpec {
        scenario::by_name("duo-burst", DUR_US).unwrap()
    }

    fn hetero() -> FleetSpec {
        FleetSpec::parse(
            &["rtx2060".into(), "xavier".into(), "tx2".into()],
            &["miriam".into()],
        )
        .unwrap()
    }

    #[test]
    fn parse_builds_named_devices_and_broadcasts_scheduler() {
        let f = hetero();
        assert_eq!(f.devices.len(), 3);
        assert_eq!(f.devices[0].name, "d0-rtx2060");
        assert_eq!(f.devices[2].name, "d2-tx2");
        assert!(f.devices.iter().all(|d| d.scheduler == "miriam"));
        // Per-device schedulers and repeated presets.
        let f = FleetSpec::parse(
            &["xavier".into(), "xavier".into()],
            &["miriam".into(), "sequential".into()],
        )
        .unwrap();
        assert_eq!(f.devices[0].name, "d0-xavier");
        assert_eq!(f.devices[1].name, "d1-xavier");
        assert_eq!(f.devices[1].scheduler, "sequential");
    }

    #[test]
    fn parse_rejects_unknown_presets_listing_the_vocabulary() {
        let err = FleetSpec::parse(&["h100".into()], &["miriam".into()])
            .unwrap_err();
        assert!(err.contains("h100"), "{err}");
        for name in GpuSpec::PRESET_NAMES {
            assert!(err.contains(name),
                    "error does not list preset {name}: {err}");
        }
        assert!(FleetSpec::parse(&[], &["miriam".into()]).is_err());
        assert!(FleetSpec::parse(
            &["tx2".into(), "tx2".into(), "tx2".into()],
            &["miriam".into(), "ib".into()],
        )
        .is_err());
    }

    #[test]
    fn fastest_is_highest_total_flops_lowest_index_on_ties() {
        assert_eq!(hetero().fastest(), 0); // rtx2060 leads
        let f = FleetSpec::parse(
            &["tx2".into(), "rtx2060".into()],
            &["miriam".into()],
        )
        .unwrap();
        assert_eq!(f.fastest(), 1);
        let twins = FleetSpec::parse(
            &["xavier".into(), "xavier".into()],
            &["miriam".into()],
        )
        .unwrap();
        assert_eq!(twins.fastest(), 0);
    }

    #[test]
    fn fleet_accounting_balances_for_every_router() {
        for r in ROUTERS {
            let opts = FleetOpts { router: r.into(), ..FleetOpts::default() };
            let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{r}");
            assert_eq!(rep.routed(), rep.admitted(), "{r}");
            assert_eq!(rep.shed_critical(), 0, "{r}");
            assert_eq!(rep.requeues(), 0, "{r}: requeues without chaos");
            assert_eq!(rep.lost(), 0, "{r}: lost without chaos");
            assert!(!rep.resilience, "{r}: resilience without chaos");
            assert!(rep.served() > 0, "{r}: nothing served");
            assert!(rep.events > 0, "{r}");
            assert!(rep.span_us > 0.0, "{r}");
            let dev_served: u64 =
                rep.devices.iter().map(|d| d.served()).sum();
            assert_eq!(dev_served, rep.served(), "{r}");
            for d in &rep.devices {
                assert_eq!(d.routed, d.routed_critical + d.routed_normal,
                           "{r}/{}", d.desc.name);
                assert!(d.served() <= d.routed, "{r}/{}", d.desc.name);
            }
        }
    }

    #[test]
    fn round_robin_spreads_load_across_devices() {
        let rep = run_fleet(&hetero(), &duo(), &FleetOpts::default())
            .unwrap();
        assert!(rep.devices.iter().all(|d| d.routed > 0),
                "round-robin left a device idle");
    }

    #[test]
    fn rejects_bad_options() {
        let bad_router =
            FleetOpts { router: "random".into(), ..FleetOpts::default() };
        let err = run_fleet(&hetero(), &duo(), &bad_router).unwrap_err();
        for name in ROUTERS {
            assert!(err.contains(name), "{err}");
        }
        let bad_sched = FleetSpec::parse(
            &["tx2".into()], &["fifo".into()]).unwrap();
        assert!(run_fleet(&bad_sched, &duo(), &FleetOpts::default())
            .is_err());
        let bad_backoff = FleetOpts {
            admission: AdmissionConfig {
                shed_backoff_us: 0.0,
                ..AdmissionConfig::default()
            },
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_backoff).is_err());
        assert!(run_fleet_grid(&hetero(), &[], &["round-robin".into()],
                               &FleetOpts::default(), 1)
            .is_err());
        assert!(run_fleet_grid(&hetero(), &[duo()], &[],
                               &FleetOpts::default(), 1)
            .is_err());
        assert!(run_fleet_grid(&hetero(), &[duo()], &["random".into()],
                               &FleetOpts::default(), 1)
            .is_err());
        // Chaos targeting a device the fleet does not have.
        let bad_chaos = FleetOpts {
            chaos: ChaosSpec::parse("down:d7@1ms+1ms").unwrap(),
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_chaos).is_err());
        // Bad autoscale watermarks and an unknown standby preset.
        let bad_scale = FleetOpts {
            autoscale: Some(AutoscaleConfig {
                pool: vec!["rtx2060".into()],
                high_watermark_us: 1.0,
                low_watermark_us: 2.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_scale).is_err());
        let bad_pool = FleetOpts {
            autoscale: Some(AutoscaleConfig {
                pool: vec!["h100".into()],
                ..AutoscaleConfig::default()
            }),
            ..FleetOpts::default()
        };
        let err = run_fleet(&hetero(), &duo(), &bad_pool).unwrap_err();
        for name in GpuSpec::PRESET_NAMES {
            assert!(err.contains(name), "{err}");
        }
    }

    #[test]
    fn grid_report_shape_and_json_parse() {
        use crate::runtime::json::{parse, Json};
        let routers: Vec<String> =
            ROUTERS.iter().map(|r| r.to_string()).collect();
        let grid = run_fleet_grid(&hetero(), &[duo()], &routers,
                                  &FleetOpts::default(), 2)
            .unwrap();
        assert_eq!(grid.cells.len(), 3);
        assert!(grid.cell("duo-burst", "criticality-affinity").is_some());
        let j = grid.to_json();
        let doc = parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("fleet"));
        assert_eq!(doc.get("cells").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
        assert_eq!(doc.get("devices").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
        // Without --isolation the comparison key must be absent (bitwise
        // identity with the PR 8 document).
        assert!(doc.get("isolation").is_none());
    }

    #[test]
    fn isolation_comparison_rows_and_json_key() {
        use crate::runtime::json::{parse, Json};
        let routers = vec!["round-robin".to_string()];
        let opts = FleetOpts::default();
        let mut grid =
            run_fleet_grid(&hetero(), &[duo()], &routers, &opts, 2).unwrap();
        let splits = vec![
            "isolation:70/30".to_string(),
            "isolation:70/30+spill".to_string(),
        ];
        let rows = run_isolation_comparison(
            &hetero(), &[duo()], &routers, &opts, &splits, &grid, 2)
            .unwrap();
        assert_eq!(rows.len(), 2, "one row per split per cell");
        assert_eq!(rows[0].scheduler, "isolation:70/30");
        assert_eq!(rows[1].scheduler, "isolation:70/30+spill");
        for r in &rows {
            assert_eq!(r.scenario, "duo-burst");
            assert!(r.throughput_rps > 0.0, "{}: nothing served",
                    r.scheduler);
            assert!(r.base_throughput_rps > 0.0);
        }
        grid.isolation = rows;
        let doc = parse(&grid.to_json()).expect("valid JSON");
        let arr = doc.get("isolation").and_then(Json::as_arr)
            .expect("isolation key present");
        assert_eq!(arr.len(), 2);
        assert!(arr[0].get("crit_p99_vs_base").is_some());
        assert!(arr[0].get("throughput_vs_base").is_some());
    }

    #[test]
    fn seed_override_changes_a_stochastic_run() {
        let a = run_fleet(&hetero(), &duo(),
                          &FleetOpts { seed: Some(11),
                                       ..FleetOpts::default() })
            .unwrap();
        let b = run_fleet(&hetero(), &duo(),
                          &FleetOpts { seed: Some(12),
                                       ..FleetOpts::default() })
            .unwrap();
        assert_eq!(a.seed, 11);
        assert_eq!(b.seed, 12);
        assert_ne!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string());
    }

    #[test]
    fn kill_and_heal_conserves_requests_and_requeues() {
        // Kill the fastest device mid-run and heal it: nothing may be
        // lost (a survivor stays live throughout) and the drained
        // requests must show up as requeues.
        let chaos = ChaosSpec::parse("down:d0@5ms+8ms").unwrap();
        for r in ROUTERS {
            let opts = FleetOpts {
                router: r.into(),
                chaos: chaos.clone(),
                ..FleetOpts::default()
            };
            let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert!(rep.resilience, "{r}");
            assert_eq!(rep.chaos, "cli", "{r}");
            assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{r}");
            assert_eq!(rep.admitted(), rep.served() + rep.lost(), "{r}");
            assert_eq!(rep.lost(), 0, "{r}: lost with a live survivor");
            assert_eq!(rep.shed_critical(), 0, "{r}");
            assert_eq!(rep.routed(), rep.admitted(), "{r}");
            let requeued_in: u64 =
                rep.devices.iter().map(|d| d.requeued_in).sum();
            assert_eq!(requeued_in, rep.requeues(),
                       "{r}: device/tenant requeue ledgers disagree");
            assert!(rep.devices[0].downtime_us > 0.0,
                    "{r}: killed device shows no downtime");
            assert!(rep.recovery_us.is_finite(),
                    "{r}: no recovery recorded");
        }
    }

    #[test]
    fn terminal_outage_loses_what_it_must_and_no_more() {
        // Kill every device forever at 5ms: requests admitted before
        // the blackout are either served or lost, and the ledgers
        // balance exactly.
        let chaos =
            ChaosSpec::parse("down:d0@5ms,down:d1@5ms,down:d2@5ms")
                .unwrap();
        let opts =
            FleetOpts { chaos, ..FleetOpts::default() };
        let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
        assert_eq!(rep.offered(), rep.admitted() + rep.shed());
        assert_eq!(rep.admitted(), rep.served() + rep.lost());
        assert!(rep.lost() > 0, "a permanent blackout lost nothing?");
        assert!(rep.devices.iter().all(|d| d.downtime_us > 0.0));
    }

    #[test]
    fn autoscaler_attaches_under_pressure_and_stays_deterministic() {
        // A slow single primary under five-storm load with a tight
        // high watermark: the scaler must pull in the standby.
        let fleet =
            FleetSpec::parse(&["tx2".into()], &["miriam".into()]).unwrap();
        let sc = scenario::by_name("five-storm", DUR_US).unwrap();
        let opts = FleetOpts {
            autoscale: Some(AutoscaleConfig {
                pool: vec!["rtx2060".into()],
                high_watermark_us: 500.0,
                low_watermark_us: 1.0,
                eval_period_us: 1_000.0,
                cooldown_us: 2_000.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetOpts::default()
        };
        let a = run_fleet(&fleet, &sc, &opts).unwrap();
        assert!(a.resilience);
        assert!(a.attaches >= 1, "scaler never attached the standby");
        assert_eq!(a.devices.len(), 2, "pool device missing from report");
        assert_eq!(a.devices[1].desc.name, "s0-rtx2060");
        assert!(a.devices[1].routed > 0,
                "attached standby never received work");
        assert_eq!(a.admitted(), a.served() + a.lost());
        assert_eq!(a.lost(), 0);
        let b = run_fleet(&fleet, &sc, &opts).unwrap();
        assert_eq!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string(),
                   "autoscaled runs diverged across repeats");
    }

    #[test]
    fn resilience_grid_shape_errors_and_json() {
        use crate::runtime::json::{parse, Json};
        let routers: Vec<String> =
            ROUTERS.iter().map(|r| r.to_string()).collect();
        let storms: Vec<String> =
            STORMS.iter().map(|s| s.to_string()).collect();
        let grid = run_resilience_grid(&hetero(), &[duo()], &storms,
                                       &routers, &FleetOpts::default(), 2)
            .unwrap();
        assert_eq!(grid.cells.len(), STORMS.len() * ROUTERS.len());
        assert!(grid
            .cell("duo-burst", "rolling-outage", "round-robin")
            .is_some());
        let j = grid.to_json();
        let doc = parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str),
                   Some("resilience"));
        assert_eq!(
            doc.get("comparisons").and_then(Json::as_arr).map(|a| a.len()),
            Some(grid.cells.len())
        );
        // Unknown storm: error lists the vocabulary.
        let err = run_resilience_grid(&hetero(), &[duo()],
                                      &["category-5".into()], &routers,
                                      &FleetOpts::default(), 1)
            .unwrap_err();
        for name in STORMS {
            assert!(err.contains(name), "{err}");
        }
    }

    #[test]
    fn inert_fault_spec_matches_no_faults_bitwise() {
        // The zero-fault identity contract: handing run_fleet an inert
        // spec must produce the byte-identical document a fault-free
        // run produces (the spec is normalized away, no fault keys
        // appear, no code path diverges).
        let base = run_fleet(&hetero(), &duo(), &FleetOpts::default())
            .unwrap();
        let opts = FleetOpts {
            faults: Some(FaultSpec::none()),
            ..FleetOpts::default()
        };
        let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
        assert!(!rep.faults, "inert spec left the fault layer armed");
        assert_eq!(base.to_json_value().to_canonical_string(),
                   rep.to_json_value().to_canonical_string(),
                   "an inert fault spec changed the run");
    }

    #[test]
    fn fault_storms_conserve_and_never_cancel_critical() {
        for name in FAULT_STORMS {
            let spec = faults::storm(name).unwrap();
            let armed = !spec.is_inert();
            let opts =
                FleetOpts { faults: Some(spec), ..FleetOpts::default() };
            let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert_eq!(rep.faults, armed, "{name}");
            assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{name}");
            assert_eq!(
                rep.admitted(),
                rep.served() + rep.lost() + rep.cancelled(),
                "{name}: extended conservation broke"
            );
            assert_eq!(rep.lost(), 0, "{name}: lost with every device live");
            assert_eq!(rep.critical_cancelled(), 0,
                       "{name}: a critical request was cancelled");
            assert_eq!(rep.shed_critical(), 0, "{name}");
            assert_eq!(rep.routed(), rep.admitted(), "{name}");
            assert!(rep.hedge_wins() <= rep.hedges(), "{name}");
            if armed {
                assert_eq!(rep.fault_script, name, "{name}");
                assert!(rep.resilience, "{name}");
            }
            let again = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert_eq!(rep.to_json_value().to_canonical_string(),
                       again.to_json_value().to_canonical_string(),
                       "{name}: fault runs diverged across repeats");
        }
    }

    #[test]
    fn heavy_launch_failures_cancel_normals_never_critical() {
        // fail:p=0.9 exhausts the best-effort retry budget often
        // (0.9^4 per request) and trips every breaker, while critical
        // requests retry without bound and all eventually land.
        let spec = FaultSpec::parse("fail:p=0.9").unwrap();
        let opts = FleetOpts { faults: Some(spec), ..FleetOpts::default() };
        let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
        assert!(rep.retries() > 0, "no retries at p=0.9");
        assert!(rep.cancelled() > 0,
                "no best-effort request ran out of retries at p=0.9");
        assert_eq!(rep.critical_cancelled(), 0);
        assert_eq!(rep.lost(), 0);
        assert_eq!(rep.admitted(),
                   rep.served() + rep.lost() + rep.cancelled());
        assert!(rep.breaker_trips() > 0, "no breaker tripped at p=0.9");
        let dev_trips: u64 =
            rep.devices.iter().map(|d| d.breaker_trips).sum();
        assert_eq!(dev_trips, rep.breaker_trips());
    }

    #[test]
    fn stragglers_trigger_hedges_for_deadline_risky_criticals() {
        // Near-certain 64x stalls with an aggressive hedge watermark:
        // critical requests must hedge onto a second device, and the
        // brownout governor must engage somewhere under deadline-risk
        // this extreme.
        let mut spec = FaultSpec::parse("straggle:p=0.9*64x").unwrap();
        spec.recovery.hedge_watermark = 0.05;
        let opts = FleetOpts { faults: Some(spec), ..FleetOpts::default() };
        let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
        assert!(rep.hedges() > 0,
                "no hedge fired under a 64x straggler storm");
        assert!(rep.hedge_wins() <= rep.hedges());
        assert_eq!(rep.critical_cancelled(), 0);
        assert_eq!(rep.admitted(),
                   rep.served() + rep.lost() + rep.cancelled());
        assert!(rep.devices.iter().any(|d| d.brownout_us > 0.0),
                "brownout never engaged under a 64x straggler storm");
    }

    #[test]
    fn rejects_bad_fault_specs_and_mixed_chaos() {
        // run_fleet re-validates the spec (CLI parsing is not the only
        // way in).
        let mut bad = FaultSpec::parse("fail:p=0.5").unwrap();
        bad.recovery.brownout_high = 0.1; // below brownout_low
        let opts = FleetOpts { faults: Some(bad), ..FleetOpts::default() };
        assert!(run_fleet(&hetero(), &duo(), &opts).is_err());
        // Faults compose with chaos: a kill under an active fault layer
        // still conserves and requeues the drained requests.
        let opts = FleetOpts {
            faults: Some(faults::storm("flaky-launches").unwrap()),
            chaos: ChaosSpec::parse("down:d0@5ms+8ms").unwrap(),
            ..FleetOpts::default()
        };
        let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
        assert_eq!(rep.offered(), rep.admitted() + rep.shed());
        assert_eq!(rep.admitted(),
                   rep.served() + rep.lost() + rep.cancelled());
        assert_eq!(rep.lost(), 0, "lost with a live survivor");
        assert_eq!(rep.critical_cancelled(), 0);
    }

    #[test]
    fn faults_grid_shape_errors_and_json() {
        use crate::runtime::json::{parse, Json};
        let routers: Vec<String> =
            ROUTERS.iter().map(|r| r.to_string()).collect();
        let specs = vec![
            FaultSpec::none(),
            faults::storm("flaky-launches").unwrap(),
        ];
        let grid = run_faults_grid(&hetero(), &[duo()], &specs, &routers,
                                   &FleetOpts::default(), 2)
            .unwrap();
        assert_eq!(grid.cells.len(), specs.len() * ROUTERS.len());
        assert!(grid
            .cell("duo-burst", "flaky-launches", "round-robin")
            .is_some());
        assert!(grid.cell("duo-burst", "none", "round-robin").is_some());
        let j = grid.to_json();
        let doc = parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("faults"));
        assert_eq!(
            doc.get("comparisons").and_then(Json::as_arr).map(|a| a.len()),
            Some(grid.cells.len())
        );
        assert_eq!(
            doc.get("faults").and_then(Json::as_arr).map(|a| a.len()),
            Some(2)
        );
        // Shape errors fail fast.
        assert!(run_faults_grid(&hetero(), &[duo()], &[], &routers,
                                &FleetOpts::default(), 1)
            .is_err());
        assert!(run_faults_grid(&hetero(), &[], &specs, &routers,
                                &FleetOpts::default(), 1)
            .is_err());
        assert!(run_faults_grid(&hetero(), &[duo()], &specs, &[],
                                &FleetOpts::default(), 1)
            .is_err());
    }
}
