//! Heterogeneous multi-GPU fleet serving (ISSUE 5 tentpole).
//!
//! Miriam is evaluated across two edge-GPU platforms (§8), and the
//! ROADMAP's heavy-traffic north star needs more than one device per
//! deployment: this module serves a mixed-criticality scenario across a
//! **fleet** of simulated edge GPUs — mixed [`GpuSpec`] presets, a
//! per-device scheduler choice — by multiplexing the online serving
//! machinery of [`crate::server::online`] over per-device engine +
//! coordinator instances ([`DeviceCore`]; fleet and single-device runs
//! share that code path, so a 1-device fleet reproduces `serve-sim`
//! bitwise — `rust/tests/fleet_determinism.rs`).
//!
//! The loop advances in simulated time only: arrivals come from the same
//! seeded heap the batch driver and `serve-sim` use, every arrival passes
//! through one fleet-wide [`AdmissionController`] (critical is never
//! shed), and each *admitted* request is placed on exactly one device by
//! a pluggable [`RouterPolicy`] ([`router`] — `round-robin`,
//! `least-outstanding-work`, `criticality-affinity`). Reports
//! ([`report`]) carry no host timing, so `BENCH_fleet.json` is
//! byte-deterministic per (seed, devices, router) and across
//! `--threads` values.
//!
//! CLI: `miriam fleet-sim --devices xavier,tx2 --router all
//! --scenario duo-burst` (README has a quickstart; EXPERIMENTS.md §Fleet
//! has router semantics and the JSON schema).
//!
//! [`DeviceCore`]: crate::server::online
//!
//! ```
//! use miriam::fleet::{run_fleet, FleetOpts, FleetSpec};
//! use miriam::workloads::scenario;
//!
//! let fleet = FleetSpec::parse(
//!     &["xavier".into(), "tx2".into()], &["miriam".into()]).unwrap();
//! let sc = scenario::by_name("duo-burst", 5_000.0).unwrap();
//! let report = run_fleet(&fleet, &sc, &FleetOpts::default()).unwrap();
//! // Router conservation: every admitted request landed on one device.
//! assert_eq!(report.routed(), report.admitted());
//! assert_eq!(report.shed_critical(), 0); // critical is never shed
//! ```

pub mod report;
pub mod router;

pub use report::{DeviceDesc, DeviceOutcome, FleetGridReport, FleetReport};
pub use router::{router_for, FleetView, RouterPolicy, ROUTERS};

use std::cmp::Reverse;
use std::sync::Mutex;

use crate::coordinator::admission::{
    model_envelopes, AdmissionConfig, AdmissionController, AdmissionPolicy,
    Decision,
};
use crate::coordinator::driver::{initial_arrivals, TimeKey};
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::server::online::{
    record_served, shed_arrival, tenant_outcomes, validate_admission,
    DeviceCore,
};
use crate::workloads::rng::Rng;
use crate::workloads::scenario::ScenarioSpec;

/// One device of a fleet: a GPU preset plus the scheduler it runs.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Stable instance name within the fleet (`d{i}-{preset}` from
    /// [`FleetSpec::parse`]; presets may repeat, instance names may not).
    pub name: String,
    /// The simulated GPU.
    pub gpu: GpuSpec,
    /// Scheduler name (any `scheduler_for` name) this device runs.
    pub scheduler: String,
}

/// A named fleet of simulated edge GPUs.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// The devices, in fleet order (device index = position here).
    pub devices: Vec<DeviceSpec>,
}

impl FleetSpec {
    /// Build a fleet from CLI-shaped lists: `devices` are GPU preset
    /// names (repeats allowed — `xavier,xavier,tx2` is a valid fleet),
    /// `schedulers` is either one name (applied to every device) or one
    /// name per device. Instance names are `d{i}-{preset}`. Errors on an
    /// unknown preset (listing the available presets), an empty fleet, or
    /// a scheduler list whose length matches neither 1 nor the device
    /// count (scheduler *names* are validated later, by `DeviceCore`).
    pub fn parse(devices: &[String], schedulers: &[String])
                 -> Result<Self, String> {
        if devices.is_empty() {
            return Err("a fleet needs at least one device".into());
        }
        if schedulers.is_empty()
            || (schedulers.len() != 1 && schedulers.len() != devices.len())
        {
            return Err(format!(
                "need one scheduler for the whole fleet or one per device \
                 (got {} for {} device(s))",
                schedulers.len(),
                devices.len()
            ));
        }
        let mut out = Vec::with_capacity(devices.len());
        for (i, d) in devices.iter().enumerate() {
            let gpu = GpuSpec::by_name(d).ok_or_else(|| {
                format!(
                    "unknown device preset '{d}' (available: {})",
                    GpuSpec::PRESET_NAMES.join(", ")
                )
            })?;
            let scheduler = if schedulers.len() == 1 {
                schedulers[0].clone()
            } else {
                schedulers[i].clone()
            };
            out.push(DeviceSpec {
                name: format!("d{i}-{}", gpu.name),
                gpu,
                scheduler,
            });
        }
        Ok(FleetSpec { devices: out })
    }

    /// Index of the fleet's fastest device: highest peak FP32 throughput
    /// ([`GpuSpec::total_flops_us`]), ties broken toward the lowest
    /// index. The `criticality-affinity` pin target and the spec the
    /// fleet-wide admission envelopes are derived against.
    pub fn fastest(&self) -> usize {
        let mut best = 0usize;
        let mut best_flops = f64::NEG_INFINITY;
        for (i, d) in self.devices.iter().enumerate() {
            let f = d.gpu.total_flops_us();
            if f > best_flops {
                best_flops = f;
                best = i;
            }
        }
        best
    }

    /// The devices as report headers.
    pub fn descs(&self) -> Vec<DeviceDesc> {
        self.devices
            .iter()
            .map(|d| DeviceDesc {
                name: d.name.clone(),
                platform: d.gpu.name.clone(),
                scheduler: d.scheduler.clone(),
            })
            .collect()
    }
}

/// Configuration of one fleet serving run.
#[derive(Debug, Clone)]
pub struct FleetOpts {
    /// Router to place admitted requests with (a [`ROUTERS`] name).
    pub router: String,
    /// Admission policy applied fleet-wide to best-effort arrivals.
    pub policy: AdmissionPolicy,
    /// Policy tunables (buckets, burst guard, shed backoff).
    pub admission: AdmissionConfig,
    /// Override the scenario's pinned arrival seed (`None` keeps it).
    pub seed: Option<u64>,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            router: "round-robin".into(),
            policy: AdmissionPolicy::Open,
            admission: AdmissionConfig::default(),
            seed: None,
        }
    }
}

/// Serve one scenario across the fleet until every device drains.
/// Deterministic for a given (scenario, seed, devices, router, policy):
/// the loop advances in simulated time only, ties (arrival vs event,
/// device vs device) break the same way every run, and no host timing
/// enters the report.
pub fn run_fleet(fleet: &FleetSpec, sc: &ScenarioSpec, opts: &FleetOpts)
                 -> Result<FleetReport, String> {
    if fleet.devices.is_empty() {
        return Err("a fleet needs at least one device".into());
    }
    validate_admission(&opts.admission)?;
    let n = fleet.devices.len();
    let mut router = router_for(&opts.router, n).ok_or_else(|| {
        format!(
            "unknown router {} (available: {})",
            opts.router,
            ROUTERS.join(", ")
        )
    })?;

    let mut wl = sc.build();
    if let Some(seed) = opts.seed {
        wl.seed = seed;
    }
    let mut cores = Vec::with_capacity(n);
    for d in &fleet.devices {
        cores.push(DeviceCore::new(&d.gpu, &wl, &d.scheduler)?);
    }

    // One fleet-wide admission controller. Its envelopes are derived
    // against the *fastest* device (best-placement estimates); in a
    // 1-device fleet that is the device itself, which keeps the
    // serve-sim differential contract exact.
    let fastest = fleet.fastest();
    let mut ctrl = AdmissionController::new(
        opts.policy,
        opts.admission.clone(),
        &wl,
        cores[fastest].spec(),
        cores[fastest].params(),
    );
    // Per-device × per-source solo envelopes: the router's cost model.
    let env_solo: Vec<Vec<f64>> = cores
        .iter()
        .map(|c| {
            model_envelopes(&wl, c.spec(), c.params())
                .iter()
                .map(|e| e.solo_us)
                .collect()
        })
        .collect();

    let mut rng = Rng::new(wl.seed);
    let mut arrivals = initial_arrivals(&wl, &mut rng);
    let mut tenants = tenant_outcomes(sc, &wl);
    let mut devices: Vec<DeviceOutcome> = fleet
        .descs()
        .into_iter()
        .map(|desc| DeviceOutcome {
            desc,
            routed: 0,
            routed_critical: 0,
            routed_normal: 0,
            deadline_misses: 0,
            critical_latencies_us: Vec::new(),
            normal_latencies_us: Vec::new(),
            span_us: 0.0,
            events: 0,
            max_normal_queue: 0,
        })
        .collect();
    // Envelope-weighted outstanding work per device (router signal).
    let mut outstanding = vec![0.0f64; n];
    let mut next_id: u64 = 1;

    loop {
        let t_arr = arrivals.peek().map(|Reverse((TimeKey(t), _))| *t);
        // Earliest device event; ties break toward the lowest index
        // (strict `<`), so the step order is deterministic.
        let mut t_ev: Option<(f64, usize)> = None;
        for (d, core) in cores.iter_mut().enumerate() {
            if let Some(t) = core.next_event_time() {
                if t_ev.map_or(true, |(tb, _)| t < tb) {
                    t_ev = Some((t, d));
                }
            }
        }
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |(t, _)| ta <= t) => {
                // ta precedes every device's next event, so advancing the
                // whole fleet cannot skip one; devices therefore observe
                // arrivals on a common clock.
                for core in &mut cores {
                    core.advance_to(ta);
                }
                while let Some(Reverse((TimeKey(t), src))) =
                    arrivals.peek().copied()
                {
                    if t > ta {
                        break;
                    }
                    arrivals.pop();
                    tenants[src].offered += 1;
                    match ctrl.decide(src, t) {
                        Decision::Admitted => {
                            let crit = wl.sources[src].criticality;
                            let d = router.route(
                                src,
                                crit,
                                &FleetView {
                                    outstanding_us: &outstanding,
                                    env_solo_us: &env_solo,
                                    fastest,
                                },
                            );
                            assert!(d < n,
                                    "router {} returned device {d} of {n}",
                                    router.name());
                            cores[d].submit(&wl, src, t, next_id);
                            next_id += 1;
                            tenants[src].admitted += 1;
                            let dev = &mut devices[d];
                            dev.routed += 1;
                            match crit {
                                Criticality::Critical => {
                                    dev.routed_critical += 1;
                                }
                                Criticality::Normal => {
                                    dev.routed_normal += 1;
                                }
                            }
                            outstanding[d] += env_solo[d][src];
                        }
                        Decision::Shed(_) => {
                            shed_arrival(&wl, src, t, &opts.admission,
                                         &mut tenants, &mut arrivals);
                        }
                    }
                }
                for core in &mut cores {
                    core.sample_queue_depth();
                }
            }
            (_, Some((_, d))) => {
                let dev = &mut devices[d];
                let out_d = &mut outstanding[d];
                let env_d = &env_solo[d];
                cores[d].step(|src, arr, now| {
                    ctrl.on_served(src);
                    record_served(&wl, src, arr, now, &mut tenants,
                                  &mut arrivals);
                    let lat = now - arr;
                    match wl.sources[src].criticality {
                        Criticality::Critical => {
                            dev.critical_latencies_us.push(lat);
                        }
                        Criticality::Normal => {
                            dev.normal_latencies_us.push(lat);
                        }
                    }
                    if wl.sources[src].deadline_us.is_some_and(|dl| lat > dl)
                    {
                        dev.deadline_misses += 1;
                    }
                    *out_d = (*out_d - env_d[src]).max(0.0);
                });
            }
            // (Some, None) with a failed guard cannot occur: the guard is
            // vacuously true when no device has a next event.
            _ => unreachable!("fleet loop: impossible arrival/event state"),
        }
    }

    let mut span_us = 0.0f64;
    let mut events = 0u64;
    for (core, dev) in cores.into_iter().zip(&mut devices) {
        dev.max_normal_queue = core.max_normal_queue();
        let (span, metrics) = core.finish();
        dev.span_us = span;
        dev.events = metrics.events;
        span_us = span_us.max(span);
        events += metrics.events;
    }
    Ok(FleetReport {
        scenario: sc.name.clone(),
        router: opts.router.clone(),
        policy: opts.policy,
        seed: wl.seed,
        duration_us: wl.duration_us,
        devices,
        tenants,
        span_us,
        events,
        critical_at_risk: ctrl.critical_at_risk(),
    })
}

/// Run the scenarios × routers grid (scenario-major order) across a
/// scoped worker pool and assemble the [`FleetGridReport`]. Cells are
/// independent deterministic simulations landing in per-cell slots, so
/// the report — and its `BENCH_fleet.json` — is **byte-identical for any
/// `threads` value**. `base` provides the policy, seed override and
/// admission tunables; its `router` field is ignored in favor of the
/// `routers` list.
pub fn run_fleet_grid(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    routers: &[String],
    base: &FleetOpts,
    threads: usize,
) -> Result<FleetGridReport, String> {
    if scenarios.is_empty() {
        return Err("fleet grid needs at least one scenario".into());
    }
    if routers.is_empty() {
        return Err("fleet grid needs at least one router".into());
    }
    // Validate the whole grid up front so workers cannot hit a config
    // error mid-pool.
    validate_admission(&base.admission)?;
    for r in routers {
        if router_for(r, fleet.devices.len().max(1)).is_none() {
            return Err(format!(
                "unknown router {r} (available: {})",
                ROUTERS.join(", ")
            ));
        }
    }
    let cells: Vec<(usize, usize)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(si, _)| (0..routers.len()).map(move |ri| (si, ri)))
        .collect();
    let n = cells.len();
    let slots: Vec<Mutex<Option<Result<FleetReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    // Same pool skeleton as `miriam sweep`: per-cell slots keep results
    // position-stable for any thread count.
    crate::coordinator::sweep::run_indexed(n, threads, |i| {
        let (si, ri) = cells[i];
        let opts = FleetOpts { router: routers[ri].clone(), ..base.clone() };
        *slots[i].lock().unwrap() =
            Some(run_fleet(fleet, &scenarios[si], &opts));
    });
    let cells = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FleetGridReport {
        devices: fleet.descs(),
        policy: base.policy.name().to_string(),
        duration_us: scenarios[0].duration_us,
        routers: routers.to_vec(),
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::scenario;

    const DUR_US: f64 = 20_000.0;

    fn duo() -> ScenarioSpec {
        scenario::by_name("duo-burst", DUR_US).unwrap()
    }

    fn hetero() -> FleetSpec {
        FleetSpec::parse(
            &["rtx2060".into(), "xavier".into(), "tx2".into()],
            &["miriam".into()],
        )
        .unwrap()
    }

    #[test]
    fn parse_builds_named_devices_and_broadcasts_scheduler() {
        let f = hetero();
        assert_eq!(f.devices.len(), 3);
        assert_eq!(f.devices[0].name, "d0-rtx2060");
        assert_eq!(f.devices[2].name, "d2-tx2");
        assert!(f.devices.iter().all(|d| d.scheduler == "miriam"));
        // Per-device schedulers and repeated presets.
        let f = FleetSpec::parse(
            &["xavier".into(), "xavier".into()],
            &["miriam".into(), "sequential".into()],
        )
        .unwrap();
        assert_eq!(f.devices[0].name, "d0-xavier");
        assert_eq!(f.devices[1].name, "d1-xavier");
        assert_eq!(f.devices[1].scheduler, "sequential");
    }

    #[test]
    fn parse_rejects_unknown_presets_listing_the_vocabulary() {
        let err = FleetSpec::parse(&["h100".into()], &["miriam".into()])
            .unwrap_err();
        assert!(err.contains("h100"), "{err}");
        for name in GpuSpec::PRESET_NAMES {
            assert!(err.contains(name),
                    "error does not list preset {name}: {err}");
        }
        assert!(FleetSpec::parse(&[], &["miriam".into()]).is_err());
        assert!(FleetSpec::parse(
            &["tx2".into(), "tx2".into(), "tx2".into()],
            &["miriam".into(), "ib".into()],
        )
        .is_err());
    }

    #[test]
    fn fastest_is_highest_total_flops_lowest_index_on_ties() {
        assert_eq!(hetero().fastest(), 0); // rtx2060 leads
        let f = FleetSpec::parse(
            &["tx2".into(), "rtx2060".into()],
            &["miriam".into()],
        )
        .unwrap();
        assert_eq!(f.fastest(), 1);
        let twins = FleetSpec::parse(
            &["xavier".into(), "xavier".into()],
            &["miriam".into()],
        )
        .unwrap();
        assert_eq!(twins.fastest(), 0);
    }

    #[test]
    fn fleet_accounting_balances_for_every_router() {
        for r in ROUTERS {
            let opts = FleetOpts { router: r.into(), ..FleetOpts::default() };
            let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{r}");
            assert_eq!(rep.routed(), rep.admitted(), "{r}");
            assert_eq!(rep.shed_critical(), 0, "{r}");
            assert!(rep.served() > 0, "{r}: nothing served");
            assert!(rep.events > 0, "{r}");
            assert!(rep.span_us > 0.0, "{r}");
            let dev_served: u64 =
                rep.devices.iter().map(|d| d.served()).sum();
            assert_eq!(dev_served, rep.served(), "{r}");
            for d in &rep.devices {
                assert_eq!(d.routed, d.routed_critical + d.routed_normal,
                           "{r}/{}", d.desc.name);
                assert!(d.served() <= d.routed, "{r}/{}", d.desc.name);
            }
        }
    }

    #[test]
    fn round_robin_spreads_load_across_devices() {
        let rep = run_fleet(&hetero(), &duo(), &FleetOpts::default())
            .unwrap();
        assert!(rep.devices.iter().all(|d| d.routed > 0),
                "round-robin left a device idle");
    }

    #[test]
    fn rejects_bad_options() {
        let bad_router =
            FleetOpts { router: "random".into(), ..FleetOpts::default() };
        let err = run_fleet(&hetero(), &duo(), &bad_router).unwrap_err();
        for name in ROUTERS {
            assert!(err.contains(name), "{err}");
        }
        let bad_sched = FleetSpec::parse(
            &["tx2".into()], &["fifo".into()]).unwrap();
        assert!(run_fleet(&bad_sched, &duo(), &FleetOpts::default())
            .is_err());
        let bad_backoff = FleetOpts {
            admission: AdmissionConfig {
                shed_backoff_us: 0.0,
                ..AdmissionConfig::default()
            },
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_backoff).is_err());
        assert!(run_fleet_grid(&hetero(), &[], &["round-robin".into()],
                               &FleetOpts::default(), 1)
            .is_err());
        assert!(run_fleet_grid(&hetero(), &[duo()], &[],
                               &FleetOpts::default(), 1)
            .is_err());
        assert!(run_fleet_grid(&hetero(), &[duo()], &["random".into()],
                               &FleetOpts::default(), 1)
            .is_err());
    }

    #[test]
    fn grid_report_shape_and_json_parse() {
        use crate::runtime::json::{parse, Json};
        let routers: Vec<String> =
            ROUTERS.iter().map(|r| r.to_string()).collect();
        let grid = run_fleet_grid(&hetero(), &[duo()], &routers,
                                  &FleetOpts::default(), 2)
            .unwrap();
        assert_eq!(grid.cells.len(), 3);
        assert!(grid.cell("duo-burst", "criticality-affinity").is_some());
        let j = grid.to_json();
        let doc = parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("fleet"));
        assert_eq!(doc.get("cells").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
        assert_eq!(doc.get("devices").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
    }

    #[test]
    fn seed_override_changes_a_stochastic_run() {
        let a = run_fleet(&hetero(), &duo(),
                          &FleetOpts { seed: Some(11),
                                       ..FleetOpts::default() })
            .unwrap();
        let b = run_fleet(&hetero(), &duo(),
                          &FleetOpts { seed: Some(12),
                                       ..FleetOpts::default() })
            .unwrap();
        assert_eq!(a.seed, 11);
        assert_eq!(b.seed, 12);
        assert_ne!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string());
    }
}
