//! Heterogeneous multi-GPU fleet serving (ISSUE 5 tentpole; chaos,
//! in-flight rebalancing and autoscaling: ISSUE 6).
//!
//! Miriam is evaluated across two edge-GPU platforms (§8), and the
//! ROADMAP's heavy-traffic north star needs more than one device per
//! deployment: this module serves a mixed-criticality scenario across a
//! **fleet** of simulated edge GPUs — mixed [`GpuSpec`] presets, a
//! per-device scheduler choice — by multiplexing the online serving
//! machinery of [`crate::server::online`] over per-device engine +
//! coordinator instances ([`DeviceCore`]; fleet and single-device runs
//! share that code path, so a 1-device fleet reproduces `serve-sim`
//! bitwise — `rust/tests/fleet_determinism.rs`).
//!
//! The loop advances in simulated time only: arrivals come from the same
//! seeded heap the batch driver and `serve-sim` use, every arrival passes
//! through one fleet-wide [`AdmissionController`] (critical is never
//! shed), and each *admitted* request is placed on exactly one **live**
//! device by a pluggable [`RouterPolicy`] ([`router`] — `round-robin`,
//! `least-outstanding-work`, `criticality-affinity`). Reports
//! ([`report`]) carry no host timing, so `BENCH_fleet.json` and
//! `BENCH_resilience.json` are byte-deterministic per (seed, devices,
//! router, chaos) and across `--threads` values.
//!
//! # Failure / recovery lifecycle (ISSUE 6)
//!
//! A scripted [`ChaosSpec`] (CLI DSL or a [`chaos`] storm preset) kills,
//! heals and throttles devices at fixed simulated times. Each device
//! walks `Live → Down → Live` (kill/heal), `Live → Draining → Standby`
//! (autoscaler detach) or `Standby → Live` (attach); on a kill the
//! device's open requests are drained **sorted by id** and re-routed
//! through [`RouterPolicy::rebalance`] over the surviving devices (each
//! re-placement counts one `requeues` on its tenant). When the whole
//! fleet is dark, drained and newly admitted requests wait in a pending
//! list that flushes on the next heal/attach — a request is `lost` only
//! to a *terminal* outage, so `lost == 0` whenever ≥ 1 device stays
//! live, and `admitted == served + lost` always
//! (`rust/tests/prop_invariants.rs`). A reactive [`Autoscaler`]
//! ([`autoscale`]) attaches/detaches standby devices against an
//! envelope-weighted backlog signal at deterministic simulated-time
//! ticks. With a zero-event spec and no autoscaler the loop's
//! arithmetic is untouched and `run_fleet` output is **bitwise
//! identical** to its pre-chaos (PR 5) form — pinned by
//! `rust/tests/fleet_determinism.rs`.
//!
//! Admission envelopes stay derived against the *nominal* fastest
//! device: admission models the operator's capacity plan, not the
//! transient chaos state, so a storm degrades latency rather than
//! silently re-shaping the admitted load.
//!
//! CLI: `miriam fleet-sim --devices xavier,tx2 --router all
//! --scenario duo-burst [--chaos "down:d1@8ms+10ms" | --storm all]`
//! (README has a quickstart; EXPERIMENTS.md §Fleet and §Resilience have
//! router/chaos semantics and the JSON schemas).
//!
//! [`DeviceCore`]: crate::server::online
//!
//! ```
//! use miriam::fleet::{run_fleet, FleetOpts, FleetSpec};
//! use miriam::workloads::scenario;
//!
//! let fleet = FleetSpec::parse(
//!     &["xavier".into(), "tx2".into()], &["miriam".into()]).unwrap();
//! let sc = scenario::by_name("duo-burst", 5_000.0).unwrap();
//! let report = run_fleet(&fleet, &sc, &FleetOpts::default()).unwrap();
//! // Router conservation: every admitted request landed on one device.
//! assert_eq!(report.routed(), report.admitted());
//! assert_eq!(report.shed_critical(), 0); // critical is never shed
//! ```

pub mod autoscale;
pub mod chaos;
pub mod report;
pub mod router;

pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
pub use chaos::{ChaosEvent, ChaosSpec, STORMS};
pub use report::{
    DeviceDesc, DeviceOutcome, FleetGridReport, FleetReport,
    ResilienceGridReport,
};
pub use router::{router_for, FleetView, RouterPolicy, ROUTERS};

use std::collections::HashSet;
use std::sync::Mutex;

use crate::coordinator::admission::{
    model_envelopes, AdmissionConfig, AdmissionController, AdmissionPolicy,
    Decision,
};
use crate::coordinator::driver::initial_arrivals;
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::server::online::{
    record_served, shed_arrival, tenant_outcomes, validate_admission,
    DeviceCore,
};
use crate::workloads::mdtb::Workload;
use crate::workloads::rng::Rng;
use crate::workloads::scenario::ScenarioSpec;

/// One device of a fleet: a GPU preset plus the scheduler it runs.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Stable instance name within the fleet (`d{i}-{preset}` from
    /// [`FleetSpec::parse`]; presets may repeat, instance names may not).
    pub name: String,
    /// The simulated GPU.
    pub gpu: GpuSpec,
    /// Scheduler name (any `scheduler_for` name) this device runs.
    pub scheduler: String,
}

/// A named fleet of simulated edge GPUs.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// The devices, in fleet order (device index = position here).
    pub devices: Vec<DeviceSpec>,
}

impl FleetSpec {
    /// Build a fleet from CLI-shaped lists: `devices` are GPU preset
    /// names (repeats allowed — `xavier,xavier,tx2` is a valid fleet),
    /// `schedulers` is either one name (applied to every device) or one
    /// name per device. Instance names are `d{i}-{preset}`. Errors on an
    /// unknown preset (listing the available presets), an empty fleet, or
    /// a scheduler list whose length matches neither 1 nor the device
    /// count (scheduler *names* are validated later, by `DeviceCore`).
    pub fn parse(devices: &[String], schedulers: &[String])
                 -> Result<Self, String> {
        if devices.is_empty() {
            return Err("a fleet needs at least one device".into());
        }
        if schedulers.is_empty()
            || (schedulers.len() != 1 && schedulers.len() != devices.len())
        {
            return Err(format!(
                "need one scheduler for the whole fleet or one per device \
                 (got {} for {} device(s))",
                schedulers.len(),
                devices.len()
            ));
        }
        let mut out = Vec::with_capacity(devices.len());
        for (i, d) in devices.iter().enumerate() {
            let gpu = GpuSpec::by_name(d).ok_or_else(|| {
                format!(
                    "unknown device preset '{d}' (available: {})",
                    GpuSpec::PRESET_NAMES.join(", ")
                )
            })?;
            let scheduler = if schedulers.len() == 1 {
                schedulers[0].clone()
            } else {
                schedulers[i].clone()
            };
            out.push(DeviceSpec {
                name: format!("d{i}-{}", gpu.name),
                gpu,
                scheduler,
            });
        }
        Ok(FleetSpec { devices: out })
    }

    /// Index of the fleet's fastest device: highest peak FP32 throughput
    /// ([`GpuSpec::total_flops_us`]), ties broken toward the lowest
    /// index. The spec the fleet-wide admission envelopes are derived
    /// against — note this is the *static* notion; the
    /// `criticality-affinity` pin follows the fastest **live** device
    /// ([`FleetView::fastest_live`]), which the fleet loop recomputes on
    /// every kill/heal/throttle/attach so affinity never targets a dead
    /// or detached device (ISSUE 6 satellite).
    pub fn fastest(&self) -> usize {
        let mut best = 0usize;
        let mut best_flops = f64::NEG_INFINITY;
        for (i, d) in self.devices.iter().enumerate() {
            let f = d.gpu.total_flops_us();
            if f > best_flops {
                best_flops = f;
                best = i;
            }
        }
        best
    }

    /// The devices as report headers.
    pub fn descs(&self) -> Vec<DeviceDesc> {
        self.devices
            .iter()
            .map(|d| DeviceDesc {
                name: d.name.clone(),
                platform: d.gpu.name.clone(),
                scheduler: d.scheduler.clone(),
            })
            .collect()
    }
}

/// Configuration of one fleet serving run.
#[derive(Debug, Clone)]
pub struct FleetOpts {
    /// Router to place admitted requests with (a [`ROUTERS`] name).
    pub router: String,
    /// Admission policy applied fleet-wide to best-effort arrivals.
    pub policy: AdmissionPolicy,
    /// Policy tunables (buckets, burst guard, shed backoff).
    pub admission: AdmissionConfig,
    /// Override the scenario's pinned arrival seed (`None` keeps it).
    pub seed: Option<u64>,
    /// Scripted chaos events. The default empty script leaves the loop's
    /// arithmetic untouched — output is bitwise identical to a run
    /// without the chaos layer.
    pub chaos: ChaosSpec,
    /// Reactive autoscaler with its standby pool (`None` disables).
    pub autoscale: Option<AutoscaleConfig>,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            router: "round-robin".into(),
            policy: AdmissionPolicy::Open,
            admission: AdmissionConfig::default(),
            seed: None,
            chaos: ChaosSpec::none(),
            autoscale: None,
        }
    }
}

/// Lifecycle state of one fleet device (primaries start `Live`,
/// standby-pool devices start `Standby`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DevState {
    Live,
    Draining,
    Down,
    Standby,
}

/// What one resolved control-timeline entry does. Ranks order same-time
/// entries: heals before throttle-ends before kills before
/// throttle-starts, so a same-instant bounce resolves to "device up".
#[derive(Debug, Clone, Copy)]
enum CtlKind {
    Heal,
    ThrottleEnd,
    Down,
    ThrottleStart { factor: f64 },
}

#[derive(Debug, Clone, Copy)]
struct Ctl {
    at_us: f64,
    rank: u8,
    device: usize,
    kind: CtlKind,
}

/// Expand a [`ChaosSpec`] into the flat, time-sorted control timeline
/// the fleet loop consumes (each down/throttle contributes its heal/end
/// as its own entry). Sort is total over (time, rank, device), so the
/// firing order is deterministic for any script.
fn control_timeline(spec: &ChaosSpec) -> Vec<Ctl> {
    let mut ctl = Vec::new();
    for ev in &spec.events {
        match *ev {
            ChaosEvent::DeviceDown { at_us, device, heal_after_us } => {
                ctl.push(Ctl {
                    at_us,
                    rank: 2,
                    device,
                    kind: CtlKind::Down,
                });
                if let Some(h) = heal_after_us {
                    ctl.push(Ctl {
                        at_us: at_us + h,
                        rank: 0,
                        device,
                        kind: CtlKind::Heal,
                    });
                }
            }
            ChaosEvent::ThermalThrottle {
                at_us,
                device,
                factor,
                duration_us,
            } => {
                ctl.push(Ctl {
                    at_us,
                    rank: 3,
                    device,
                    kind: CtlKind::ThrottleStart { factor },
                });
                ctl.push(Ctl {
                    at_us: at_us + duration_us,
                    rank: 1,
                    device,
                    kind: CtlKind::ThrottleEnd,
                });
            }
        }
    }
    ctl.sort_by(|a, b| {
        a.at_us
            .total_cmp(&b.at_us)
            .then(a.rank.cmp(&b.rank))
            .then(a.device.cmp(&b.device))
    });
    ctl
}

/// An admitted request with nowhere to go: the whole fleet was dark when
/// it needed a device. Flushed on the next heal/attach; anything still
/// here when the run ends is `lost` (terminal outage).
struct PendingReq {
    id: u64,
    arr_us: f64,
    src: usize,
    /// Whether the request had already been placed once (drained off a
    /// dead device — its flush counts as a requeue) or never placed (a
    /// flush is its first routing).
    placed: bool,
}

/// One device kill and the recovery of the requests it was carrying:
/// `recovered_at` is set the moment the last drained request is served
/// somewhere else (tracked by id — ids are fleet-unique, so a request
/// can never be counted served twice).
struct Outage {
    at_us: f64,
    open: HashSet<u64>,
    recovered_at: Option<f64>,
}

/// The fleet's mutable device-topology state, grouped so the chaos /
/// autoscale handlers and the router share one consistent picture.
struct DevCtx {
    specs: Vec<DeviceSpec>,
    cores: Vec<Option<DeviceCore>>,
    state: Vec<DevState>,
    /// Active thermal-throttle factor per device (`None` = full speed).
    throttle: Vec<Option<f64>>,
    /// `env_solo[device][source]` against the device's *effective* spec.
    env_solo: Vec<Vec<f64>>,
    /// Envelope-weighted outstanding work per device (router signal).
    outstanding: Vec<f64>,
    down_since: Vec<f64>,
    live: Vec<bool>,
    fastest_live: usize,
}

impl DevCtx {
    /// The device's GPU spec with any active throttle factor applied to
    /// its compute and memory rates.
    fn effective_gpu(&self, d: usize) -> GpuSpec {
        let mut g = self.specs[d].gpu.clone();
        if let Some(f) = self.throttle[d] {
            g.flops_per_sm_us *= f;
            g.dram_bw_bytes_us *= f;
        }
        g
    }

    fn effective_flops(&self, d: usize) -> f64 {
        let f = self.specs[d].gpu.total_flops_us();
        match self.throttle[d] {
            Some(x) => f * x,
            None => f,
        }
    }

    /// Refresh `live` and `fastest_live` from the state vector: fastest
    /// by *effective* throughput over live devices, strict `>` so ties
    /// stay on the lowest index (with no chaos this reproduces
    /// [`FleetSpec::fastest`] exactly).
    fn recompute_live(&mut self) {
        let mut fastest = 0usize;
        let mut best = f64::NEG_INFINITY;
        for d in 0..self.state.len() {
            self.live[d] = self.state[d] == DevState::Live;
            if self.live[d] {
                let f = self.effective_flops(d);
                if f > best {
                    best = f;
                    fastest = d;
                }
            }
        }
        self.fastest_live = fastest;
    }

    fn any_live(&self) -> bool {
        self.live.iter().any(|&l| l)
    }

    fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Stand a fresh core up on device `d` at simulated time `t`
    /// (heal, attach, or throttle re-clock), refreshing the device's
    /// envelope table against its effective spec and zeroing its
    /// backlog signal (the caller resubmits whatever it drained).
    fn rebuild_core(&mut self, d: usize, t: f64, wl: &Workload)
                    -> Result<(), String> {
        let gpu = self.effective_gpu(d);
        let mut core = DeviceCore::new(&gpu, wl, &self.specs[d].scheduler)?;
        core.advance_to(t);
        self.env_solo[d] = model_envelopes(wl, core.spec(), core.params())
            .iter()
            .map(|e| e.solo_us)
            .collect();
        self.outstanding[d] = 0.0;
        self.cores[d] = Some(core);
        Ok(())
    }
}

/// Fold a finished core's span/events/queue-depth into its device row.
/// Accumulating (max/sum) rather than assigning keeps multi-segment
/// devices (killed and healed) honest while reproducing the single-
/// segment (no-chaos) values bit-for-bit.
fn retire_core(core: DeviceCore, dev: &mut DeviceOutcome) {
    dev.max_normal_queue = dev.max_normal_queue.max(core.max_normal_queue());
    let (span, metrics) = core.finish();
    dev.span_us = dev.span_us.max(span);
    dev.events += metrics.events;
}

/// Place one request on a live device: route (fresh arrivals) or
/// rebalance (requeues) through the router, submit, and account. The
/// fleet loop only calls this while at least one device is live.
#[allow(clippy::too_many_arguments)]
fn place_request(
    ctx: &mut DevCtx,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [crate::server::online::TenantOutcome],
    devices: &mut [DeviceOutcome],
    src: usize,
    arr_us: f64,
    id: u64,
    requeue: bool,
) {
    let crit = wl.sources[src].criticality;
    let d = {
        let view = FleetView {
            outstanding_us: &ctx.outstanding,
            env_solo_us: &ctx.env_solo,
            live: &ctx.live,
            fastest_live: ctx.fastest_live,
        };
        if requeue {
            router.rebalance(src, crit, &view)
        } else {
            router.route(src, crit, &view)
        }
    };
    assert!(d < ctx.cores.len() && ctx.live[d],
            "router {} returned dead device {d}", router.name());
    ctx.cores[d]
        .as_mut()
        .expect("live device has a core")
        .submit(wl, src, arr_us, id);
    let dev = &mut devices[d];
    if requeue {
        dev.requeued_in += 1;
        tenants[src].requeues += 1;
    } else {
        dev.routed += 1;
        match crit {
            Criticality::Critical => dev.routed_critical += 1,
            Criticality::Normal => dev.routed_normal += 1,
        }
    }
    ctx.outstanding[d] += ctx.env_solo[d][src];
}

/// Flush the dark-fleet pending list onto whatever is live now (no-op
/// until a device is). Previously-placed requests count as requeues;
/// never-placed ones count as their first routing.
fn flush_pending(
    ctx: &mut DevCtx,
    router: &mut dyn RouterPolicy,
    wl: &Workload,
    tenants: &mut [crate::server::online::TenantOutcome],
    devices: &mut [DeviceOutcome],
    pending: &mut Vec<PendingReq>,
) {
    if pending.is_empty() || !ctx.any_live() {
        return;
    }
    for p in std::mem::take(pending) {
        place_request(ctx, router, wl, tenants, devices, p.src, p.arr_us,
                      p.id, p.placed);
    }
}

/// Re-clock device `d` at time `t` after its effective spec changed
/// (throttle start/end): drain its open requests, retire the old core,
/// stand a new one up at the new rates, and resubmit the drained
/// requests *to the same device* with their original arrival times —
/// a throttle is a slowdown, not an outage, so nothing is requeued.
fn reclock_device(
    ctx: &mut DevCtx,
    d: usize,
    t: f64,
    wl: &Workload,
    devices: &mut [DeviceOutcome],
) -> Result<(), String> {
    if ctx.cores[d].is_none() {
        return Ok(());
    }
    let mut core = ctx.cores[d].take().expect("checked above");
    let opens = core.drain_open();
    retire_core(core, &mut devices[d]);
    ctx.rebuild_core(d, t, wl)?;
    let core = ctx.cores[d].as_mut().expect("just rebuilt");
    let mut backlog = 0.0f64;
    for &(id, arr, src) in &opens {
        core.submit(wl, src, arr, id);
        backlog += ctx.env_solo[d][src];
    }
    ctx.outstanding[d] = backlog;
    Ok(())
}

/// Build the standby-pool device specs (`s{i}-{preset}`) from an
/// autoscale config, mirroring [`FleetSpec::parse`]'s unknown-preset
/// error.
fn pool_specs(cfg: &AutoscaleConfig) -> Result<Vec<DeviceSpec>, String> {
    let mut out = Vec::with_capacity(cfg.pool.len());
    for (i, p) in cfg.pool.iter().enumerate() {
        let gpu = GpuSpec::by_name(p).ok_or_else(|| {
            format!(
                "unknown standby preset '{p}' (available: {})",
                GpuSpec::PRESET_NAMES.join(", ")
            )
        })?;
        out.push(DeviceSpec {
            name: format!("s{i}-{}", gpu.name),
            gpu,
            scheduler: cfg.scheduler.clone(),
        });
    }
    Ok(out)
}

/// Serve one scenario across the fleet until every device drains.
/// Deterministic for a given (scenario, seed, devices, router, policy,
/// chaos, autoscale): the loop advances in simulated time only, ties
/// (arrival vs event vs control, device vs device) break the same way
/// every run, and no host timing enters the report.
pub fn run_fleet(fleet: &FleetSpec, sc: &ScenarioSpec, opts: &FleetOpts)
                 -> Result<FleetReport, String> {
    if fleet.devices.is_empty() {
        return Err("a fleet needs at least one device".into());
    }
    validate_admission(&opts.admission)?;
    let pool: Vec<DeviceSpec> = match &opts.autoscale {
        Some(a) => {
            a.validate()?;
            pool_specs(a)?
        }
        None => Vec::new(),
    };
    let pool_start = fleet.devices.len();
    let total = pool_start + pool.len();
    opts.chaos.validate(total)?;
    let mut router = router_for(&opts.router, total).ok_or_else(|| {
        format!(
            "unknown router {} (available: {})",
            opts.router,
            ROUTERS.join(", ")
        )
    })?;
    let resilience = !opts.chaos.is_empty() || opts.autoscale.is_some();

    let mut wl = sc.build();
    if let Some(seed) = opts.seed {
        wl.seed = seed;
    }
    let mut specs = fleet.devices.clone();
    specs.extend(pool.iter().cloned());
    let mut cores: Vec<Option<DeviceCore>> = Vec::with_capacity(total);
    let mut env_solo: Vec<Vec<f64>> = Vec::with_capacity(total);
    for d in &fleet.devices {
        let core = DeviceCore::new(&d.gpu, &wl, &d.scheduler)?;
        env_solo.push(
            model_envelopes(&wl, core.spec(), core.params())
                .iter()
                .map(|e| e.solo_us)
                .collect(),
        );
        cores.push(Some(core));
    }
    for d in &pool {
        // Validate the standby scheduler now so an attach cannot fail
        // mid-run; the throwaway core never joins the fleet and the
        // real envelope table is computed at attach time.
        DeviceCore::new(&d.gpu, &wl, &d.scheduler)?;
        env_solo.push(vec![0.0; wl.sources.len()]);
        cores.push(None);
    }

    // One fleet-wide admission controller. Its envelopes are derived
    // against the *nominal fastest* device (best-placement estimates,
    // unaffected by transient chaos — see the module docs); in a
    // 1-device fleet that is the device itself, which keeps the
    // serve-sim differential contract exact.
    let fastest = fleet.fastest();
    let mut ctrl = AdmissionController::new(
        opts.policy,
        opts.admission.clone(),
        &wl,
        cores[fastest].as_ref().expect("primaries start live").spec(),
        cores[fastest].as_ref().expect("primaries start live").params(),
    );

    let mut state = vec![DevState::Live; pool_start];
    state.extend(vec![DevState::Standby; pool.len()]);
    let mut ctx = DevCtx {
        specs,
        cores,
        state,
        throttle: vec![None; total],
        env_solo,
        outstanding: vec![0.0f64; total],
        down_since: vec![0.0f64; total],
        live: vec![false; total],
        fastest_live: 0,
    };
    ctx.recompute_live();

    let ctl = control_timeline(&opts.chaos);
    let mut ctl_i = 0usize;
    let mut scaler = opts.autoscale.clone().map(Autoscaler::new);
    let mut pending: Vec<PendingReq> = Vec::new();
    let mut outages: Vec<Outage> = Vec::new();
    let mut attaches = 0u64;
    let mut detaches = 0u64;

    let mut rng = Rng::new(wl.seed);
    let mut arrivals = initial_arrivals(&wl, &mut rng);
    let mut tenants = tenant_outcomes(sc, &wl);
    let mut devices: Vec<DeviceOutcome> = ctx
        .specs
        .iter()
        .map(|d| DeviceOutcome {
            desc: DeviceDesc {
                name: d.name.clone(),
                platform: d.gpu.name.clone(),
                scheduler: d.scheduler.clone(),
            },
            routed: 0,
            routed_critical: 0,
            routed_normal: 0,
            deadline_misses: 0,
            critical_latencies_us: Vec::new(),
            normal_latencies_us: Vec::new(),
            span_us: 0.0,
            events: 0,
            max_normal_queue: 0,
            requeued_in: 0,
            downtime_us: 0.0,
        })
        .collect();
    let mut next_id: u64 = 1;

    loop {
        let t_arr = arrivals.peek().map(|(t, _)| t);
        // Earliest device event; ties break toward the lowest index
        // (strict `<`), so the step order is deterministic.
        let mut t_ev: Option<(f64, usize)> = None;
        for (d, core) in ctx.cores.iter_mut().enumerate() {
            if let Some(core) = core {
                if let Some(t) = core.next_event_time() {
                    if t_ev.map_or(true, |(tb, _)| t < tb) {
                        t_ev = Some((t, d));
                    }
                }
            }
        }
        let t_chaos = ctl.get(ctl_i).map(|c| c.at_us);
        let t_tick = scaler.as_ref().and_then(|s| s.next_eval_us());
        let t_ctl = match (t_chaos, t_tick) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        // Control (chaos / autoscale tick) preempts arrivals and events
        // at the same instant: a device killed at t never sees t's
        // arrivals, and control still fires after the queues drain (a
        // terminal heal must flush the pending list).
        let ctl_due = match t_ctl {
            Some(tc) => {
                t_arr.map_or(true, |ta| tc <= ta)
                    && t_ev.map_or(true, |(te, _)| tc <= te)
            }
            None => false,
        };
        if ctl_due {
            let t = t_ctl.expect("ctl_due implies a control time");
            for core in ctx.cores.iter_mut().flatten() {
                core.advance_to(t);
            }
            let fire_chaos = match (t_chaos, t_tick) {
                (Some(a), Some(b)) => a <= b,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if fire_chaos {
                let c = ctl[ctl_i];
                ctl_i += 1;
                match c.kind {
                    CtlKind::Down => {
                        let d = c.device;
                        if matches!(ctx.state[d],
                                    DevState::Live | DevState::Draining)
                        {
                            let mut core = ctx.cores[d]
                                .take()
                                .expect("live device has a core");
                            let opens = core.drain_open();
                            retire_core(core, &mut devices[d]);
                            ctx.state[d] = DevState::Down;
                            ctx.down_since[d] = t;
                            ctx.outstanding[d] = 0.0;
                            ctx.recompute_live();
                            let mut o = Outage {
                                at_us: t,
                                open: opens
                                    .iter()
                                    .map(|&(id, _, _)| id)
                                    .collect(),
                                recovered_at: None,
                            };
                            if o.open.is_empty() {
                                o.recovered_at = Some(t);
                            }
                            outages.push(o);
                            if ctx.any_live() {
                                for (id, arr, src) in opens {
                                    place_request(
                                        &mut ctx, router.as_mut(), &wl,
                                        &mut tenants, &mut devices, src,
                                        arr, id, true,
                                    );
                                }
                            } else {
                                for (id, arr, src) in opens {
                                    pending.push(PendingReq {
                                        id,
                                        arr_us: arr,
                                        src,
                                        placed: true,
                                    });
                                }
                            }
                        }
                    }
                    CtlKind::Heal => {
                        let d = c.device;
                        if ctx.state[d] == DevState::Down {
                            devices[d].downtime_us += t - ctx.down_since[d];
                            ctx.rebuild_core(d, t, &wl)?;
                            ctx.state[d] = DevState::Live;
                            ctx.recompute_live();
                            flush_pending(&mut ctx, router.as_mut(), &wl,
                                          &mut tenants, &mut devices,
                                          &mut pending);
                        }
                    }
                    CtlKind::ThrottleStart { factor } => {
                        let d = c.device;
                        ctx.throttle[d] = Some(factor);
                        reclock_device(&mut ctx, d, t, &wl, &mut devices)?;
                        ctx.recompute_live();
                    }
                    CtlKind::ThrottleEnd => {
                        let d = c.device;
                        ctx.throttle[d] = None;
                        reclock_device(&mut ctx, d, t, &wl, &mut devices)?;
                        ctx.recompute_live();
                    }
                }
            } else {
                // Autoscale evaluation tick.
                let live_count = ctx.live_count();
                let backlog: f64 = ctx
                    .outstanding
                    .iter()
                    .zip(&ctx.live)
                    .filter(|&(_, &l)| l)
                    .map(|(o, _)| o)
                    .sum();
                let per_live = if live_count > 0 {
                    backlog / live_count as f64
                } else {
                    f64::INFINITY
                };
                let attach_target = (pool_start..total)
                    .find(|&d| ctx.state[d] == DevState::Standby);
                let detach_target = (pool_start..total)
                    .rev()
                    .find(|&d| ctx.state[d] == DevState::Live);
                let can_detach = detach_target.is_some() && live_count > 1;
                let s = scaler.as_mut().expect("tick implies a scaler");
                match s.evaluate(t, per_live, attach_target.is_some(),
                                 can_detach)
                {
                    ScaleAction::Attach => {
                        let d = attach_target.expect("evaluate checked");
                        ctx.rebuild_core(d, t, &wl)?;
                        ctx.state[d] = DevState::Live;
                        attaches += 1;
                        ctx.recompute_live();
                        flush_pending(&mut ctx, router.as_mut(), &wl,
                                      &mut tenants, &mut devices,
                                      &mut pending);
                    }
                    ScaleAction::Detach => {
                        let d = detach_target.expect("evaluate checked");
                        let open = ctx.cores[d]
                            .as_ref()
                            .map_or(0, |c| c.open_count());
                        if open == 0 {
                            if let Some(core) = ctx.cores[d].take() {
                                retire_core(core, &mut devices[d]);
                            }
                            ctx.state[d] = DevState::Standby;
                            ctx.outstanding[d] = 0.0;
                        } else {
                            // Graceful: stop routing here, park it once
                            // its open requests drain (see step branch).
                            ctx.state[d] = DevState::Draining;
                        }
                        detaches += 1;
                        ctx.recompute_live();
                    }
                    ScaleAction::Hold => {}
                }
                let work_remains = !arrivals.is_empty()
                    || !pending.is_empty()
                    || ctx.cores.iter().flatten().any(|c| c.open_count() > 0);
                s.schedule_next(t, work_remains);
            }
            continue;
        }
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |(t, _)| ta <= t) => {
                // ta precedes every device's next event, so advancing the
                // whole fleet cannot skip one; devices therefore observe
                // arrivals on a common clock.
                for core in ctx.cores.iter_mut().flatten() {
                    core.advance_to(ta);
                }
                while let Some((t, src)) = arrivals.peek() {
                    if t > ta {
                        break;
                    }
                    arrivals.pop();
                    tenants[src].offered += 1;
                    match ctrl.decide(src, t) {
                        Decision::Admitted => {
                            tenants[src].admitted += 1;
                            let id = next_id;
                            next_id += 1;
                            if ctx.any_live() {
                                place_request(
                                    &mut ctx, router.as_mut(), &wl,
                                    &mut tenants, &mut devices, src, t,
                                    id, false,
                                );
                            } else {
                                pending.push(PendingReq {
                                    id,
                                    arr_us: t,
                                    src,
                                    placed: false,
                                });
                            }
                        }
                        Decision::Shed(_) => {
                            shed_arrival(&wl, src, t, &opts.admission,
                                         &mut tenants, &mut arrivals);
                        }
                    }
                }
                for core in ctx.cores.iter_mut().flatten() {
                    core.sample_queue_depth();
                }
            }
            (_, Some((_, d))) => {
                let mut core =
                    ctx.cores[d].take().expect("stepping a missing core");
                {
                    let dev = &mut devices[d];
                    let out_d = &mut ctx.outstanding[d];
                    let env_d = &ctx.env_solo[d];
                    core.step(|id, src, arr, now| {
                        ctrl.on_served(src);
                        record_served(&wl, src, arr, now, &mut tenants,
                                      &mut arrivals);
                        let lat = now - arr;
                        match wl.sources[src].criticality {
                            Criticality::Critical => {
                                dev.critical_latencies_us.push(lat);
                            }
                            Criticality::Normal => {
                                dev.normal_latencies_us.push(lat);
                            }
                        }
                        if wl.sources[src]
                            .deadline_us
                            .is_some_and(|dl| lat > dl)
                        {
                            dev.deadline_misses += 1;
                        }
                        *out_d = (*out_d - env_d[src]).max(0.0);
                        // Outage recovery bookkeeping: remove/is_empty
                        // only — no set iteration, so no HashSet order
                        // dependence.
                        for o in outages.iter_mut() {
                            if o.recovered_at.is_none()
                                && o.open.remove(&id)
                                && o.open.is_empty()
                            {
                                o.recovered_at = Some(now);
                            }
                        }
                    });
                }
                if ctx.state[d] == DevState::Draining
                    && core.open_count() == 0
                {
                    retire_core(core, &mut devices[d]);
                    ctx.state[d] = DevState::Standby;
                    ctx.outstanding[d] = 0.0;
                    ctx.recompute_live();
                } else {
                    ctx.cores[d] = Some(core);
                }
            }
            // (Some, None) with a failed guard cannot occur: the guard is
            // vacuously true when no device has a next event.
            _ => unreachable!("fleet loop: impossible arrival/event state"),
        }
    }

    // Whatever is still pending was admitted into a fleet that never
    // came back: lost to a terminal outage.
    for p in &pending {
        tenants[p.src].lost += 1;
    }
    for (core, dev) in ctx.cores.iter_mut().zip(&mut devices) {
        if let Some(core) = core.take() {
            retire_core(core, dev);
        }
    }
    let mut span_us = 0.0f64;
    let mut events = 0u64;
    for dev in &devices {
        span_us = span_us.max(dev.span_us);
        events += dev.events;
    }
    for (d, dev) in devices.iter_mut().enumerate() {
        if ctx.state[d] == DevState::Down {
            dev.downtime_us += (span_us - ctx.down_since[d]).max(0.0);
        }
    }
    let recovery_us = outages
        .iter()
        .filter_map(|o| o.recovered_at.map(|r| r - o.at_us))
        .fold(f64::NAN, f64::max);
    Ok(FleetReport {
        scenario: sc.name.clone(),
        router: opts.router.clone(),
        policy: opts.policy,
        seed: wl.seed,
        duration_us: wl.duration_us,
        devices,
        tenants,
        span_us,
        events,
        critical_at_risk: ctrl.critical_at_risk(),
        chaos: opts.chaos.name.clone(),
        chaos_events: opts.chaos.events.len() as u64,
        recovery_us,
        attaches,
        detaches,
        resilience,
    })
}

/// Run the scenarios × routers grid (scenario-major order) across a
/// scoped worker pool and assemble the [`FleetGridReport`]. Cells are
/// independent deterministic simulations landing in per-cell slots, so
/// the report — and its `BENCH_fleet.json` — is **byte-identical for any
/// `threads` value**. `base` provides the policy, seed override and
/// admission tunables; its `router` field is ignored in favor of the
/// `routers` list.
pub fn run_fleet_grid(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    routers: &[String],
    base: &FleetOpts,
    threads: usize,
) -> Result<FleetGridReport, String> {
    if scenarios.is_empty() {
        return Err("fleet grid needs at least one scenario".into());
    }
    if routers.is_empty() {
        return Err("fleet grid needs at least one router".into());
    }
    // Validate the whole grid up front so workers cannot hit a config
    // error mid-pool.
    validate_admission(&base.admission)?;
    for r in routers {
        if router_for(r, fleet.devices.len().max(1)).is_none() {
            return Err(format!(
                "unknown router {r} (available: {})",
                ROUTERS.join(", ")
            ));
        }
    }
    let cells: Vec<(usize, usize)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(si, _)| (0..routers.len()).map(move |ri| (si, ri)))
        .collect();
    let n = cells.len();
    let slots: Vec<Mutex<Option<Result<FleetReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    // Same pool skeleton as `miriam sweep`: per-cell slots keep results
    // position-stable for any thread count.
    crate::coordinator::sweep::run_indexed(n, threads, |i| {
        let (si, ri) = cells[i];
        let opts = FleetOpts { router: routers[ri].clone(), ..base.clone() };
        *slots[i].lock().unwrap() =
            Some(run_fleet(fleet, &scenarios[si], &opts));
    });
    let cells = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FleetGridReport {
        devices: fleet.descs(),
        policy: base.policy.name().to_string(),
        duration_us: scenarios[0].duration_us,
        routers: routers.to_vec(),
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        cells,
    })
}

/// Run the scenarios × storms × routers resilience grid (scenario-major,
/// then storm, then router) across a scoped worker pool and assemble the
/// [`ResilienceGridReport`] (`BENCH_resilience.json`). Storm scripts are
/// generated per scenario window, so every cell of one storm column runs
/// the same named weather scaled to its scenario. Byte-identical for any
/// `threads` value, like [`run_fleet_grid`].
pub fn run_resilience_grid(
    fleet: &FleetSpec,
    scenarios: &[ScenarioSpec],
    storms: &[String],
    routers: &[String],
    base: &FleetOpts,
    threads: usize,
) -> Result<ResilienceGridReport, String> {
    if scenarios.is_empty() {
        return Err("resilience grid needs at least one scenario".into());
    }
    if storms.is_empty() {
        return Err("resilience grid needs at least one storm".into());
    }
    if routers.is_empty() {
        return Err("resilience grid needs at least one router".into());
    }
    validate_admission(&base.admission)?;
    for r in routers {
        if router_for(r, fleet.devices.len().max(1)).is_none() {
            return Err(format!(
                "unknown router {r} (available: {})",
                ROUTERS.join(", ")
            ));
        }
    }
    for s in storms {
        if chaos::storm(s, fleet.devices.len(), scenarios[0].duration_us)
            .is_none()
        {
            return Err(format!(
                "unknown storm '{s}' (available: {})",
                STORMS.join(", ")
            ));
        }
    }
    let mut devices = fleet.descs();
    if let Some(a) = &base.autoscale {
        a.validate()?;
        devices.extend(pool_specs(a)?.iter().map(|d| DeviceDesc {
            name: d.name.clone(),
            platform: d.gpu.name.clone(),
            scheduler: d.scheduler.clone(),
        }));
    }
    let cells: Vec<(usize, usize, usize)> = scenarios
        .iter()
        .enumerate()
        .flat_map(|(si, _)| {
            (0..storms.len()).flat_map(move |ti| {
                (0..routers.len()).map(move |ri| (si, ti, ri))
            })
        })
        .collect();
    let n = cells.len();
    let slots: Vec<Mutex<Option<Result<FleetReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    crate::coordinator::sweep::run_indexed(n, threads, |i| {
        let (si, ti, ri) = cells[i];
        let sc = &scenarios[si];
        let opts = FleetOpts {
            router: routers[ri].clone(),
            chaos: chaos::storm(&storms[ti], fleet.devices.len(),
                                sc.duration_us)
                .expect("storms validated above"),
            ..base.clone()
        };
        *slots[i].lock().unwrap() = Some(run_fleet(fleet, sc, &opts));
    });
    let cells = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(ResilienceGridReport {
        devices,
        policy: base.policy.name().to_string(),
        duration_us: scenarios[0].duration_us,
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        storms: storms.to_vec(),
        routers: routers.to_vec(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::scenario;

    const DUR_US: f64 = 20_000.0;

    fn duo() -> ScenarioSpec {
        scenario::by_name("duo-burst", DUR_US).unwrap()
    }

    fn hetero() -> FleetSpec {
        FleetSpec::parse(
            &["rtx2060".into(), "xavier".into(), "tx2".into()],
            &["miriam".into()],
        )
        .unwrap()
    }

    #[test]
    fn parse_builds_named_devices_and_broadcasts_scheduler() {
        let f = hetero();
        assert_eq!(f.devices.len(), 3);
        assert_eq!(f.devices[0].name, "d0-rtx2060");
        assert_eq!(f.devices[2].name, "d2-tx2");
        assert!(f.devices.iter().all(|d| d.scheduler == "miriam"));
        // Per-device schedulers and repeated presets.
        let f = FleetSpec::parse(
            &["xavier".into(), "xavier".into()],
            &["miriam".into(), "sequential".into()],
        )
        .unwrap();
        assert_eq!(f.devices[0].name, "d0-xavier");
        assert_eq!(f.devices[1].name, "d1-xavier");
        assert_eq!(f.devices[1].scheduler, "sequential");
    }

    #[test]
    fn parse_rejects_unknown_presets_listing_the_vocabulary() {
        let err = FleetSpec::parse(&["h100".into()], &["miriam".into()])
            .unwrap_err();
        assert!(err.contains("h100"), "{err}");
        for name in GpuSpec::PRESET_NAMES {
            assert!(err.contains(name),
                    "error does not list preset {name}: {err}");
        }
        assert!(FleetSpec::parse(&[], &["miriam".into()]).is_err());
        assert!(FleetSpec::parse(
            &["tx2".into(), "tx2".into(), "tx2".into()],
            &["miriam".into(), "ib".into()],
        )
        .is_err());
    }

    #[test]
    fn fastest_is_highest_total_flops_lowest_index_on_ties() {
        assert_eq!(hetero().fastest(), 0); // rtx2060 leads
        let f = FleetSpec::parse(
            &["tx2".into(), "rtx2060".into()],
            &["miriam".into()],
        )
        .unwrap();
        assert_eq!(f.fastest(), 1);
        let twins = FleetSpec::parse(
            &["xavier".into(), "xavier".into()],
            &["miriam".into()],
        )
        .unwrap();
        assert_eq!(twins.fastest(), 0);
    }

    #[test]
    fn fleet_accounting_balances_for_every_router() {
        for r in ROUTERS {
            let opts = FleetOpts { router: r.into(), ..FleetOpts::default() };
            let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{r}");
            assert_eq!(rep.routed(), rep.admitted(), "{r}");
            assert_eq!(rep.shed_critical(), 0, "{r}");
            assert_eq!(rep.requeues(), 0, "{r}: requeues without chaos");
            assert_eq!(rep.lost(), 0, "{r}: lost without chaos");
            assert!(!rep.resilience, "{r}: resilience without chaos");
            assert!(rep.served() > 0, "{r}: nothing served");
            assert!(rep.events > 0, "{r}");
            assert!(rep.span_us > 0.0, "{r}");
            let dev_served: u64 =
                rep.devices.iter().map(|d| d.served()).sum();
            assert_eq!(dev_served, rep.served(), "{r}");
            for d in &rep.devices {
                assert_eq!(d.routed, d.routed_critical + d.routed_normal,
                           "{r}/{}", d.desc.name);
                assert!(d.served() <= d.routed, "{r}/{}", d.desc.name);
            }
        }
    }

    #[test]
    fn round_robin_spreads_load_across_devices() {
        let rep = run_fleet(&hetero(), &duo(), &FleetOpts::default())
            .unwrap();
        assert!(rep.devices.iter().all(|d| d.routed > 0),
                "round-robin left a device idle");
    }

    #[test]
    fn rejects_bad_options() {
        let bad_router =
            FleetOpts { router: "random".into(), ..FleetOpts::default() };
        let err = run_fleet(&hetero(), &duo(), &bad_router).unwrap_err();
        for name in ROUTERS {
            assert!(err.contains(name), "{err}");
        }
        let bad_sched = FleetSpec::parse(
            &["tx2".into()], &["fifo".into()]).unwrap();
        assert!(run_fleet(&bad_sched, &duo(), &FleetOpts::default())
            .is_err());
        let bad_backoff = FleetOpts {
            admission: AdmissionConfig {
                shed_backoff_us: 0.0,
                ..AdmissionConfig::default()
            },
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_backoff).is_err());
        assert!(run_fleet_grid(&hetero(), &[], &["round-robin".into()],
                               &FleetOpts::default(), 1)
            .is_err());
        assert!(run_fleet_grid(&hetero(), &[duo()], &[],
                               &FleetOpts::default(), 1)
            .is_err());
        assert!(run_fleet_grid(&hetero(), &[duo()], &["random".into()],
                               &FleetOpts::default(), 1)
            .is_err());
        // Chaos targeting a device the fleet does not have.
        let bad_chaos = FleetOpts {
            chaos: ChaosSpec::parse("down:d7@1ms+1ms").unwrap(),
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_chaos).is_err());
        // Bad autoscale watermarks and an unknown standby preset.
        let bad_scale = FleetOpts {
            autoscale: Some(AutoscaleConfig {
                pool: vec!["rtx2060".into()],
                high_watermark_us: 1.0,
                low_watermark_us: 2.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetOpts::default()
        };
        assert!(run_fleet(&hetero(), &duo(), &bad_scale).is_err());
        let bad_pool = FleetOpts {
            autoscale: Some(AutoscaleConfig {
                pool: vec!["h100".into()],
                ..AutoscaleConfig::default()
            }),
            ..FleetOpts::default()
        };
        let err = run_fleet(&hetero(), &duo(), &bad_pool).unwrap_err();
        for name in GpuSpec::PRESET_NAMES {
            assert!(err.contains(name), "{err}");
        }
    }

    #[test]
    fn grid_report_shape_and_json_parse() {
        use crate::runtime::json::{parse, Json};
        let routers: Vec<String> =
            ROUTERS.iter().map(|r| r.to_string()).collect();
        let grid = run_fleet_grid(&hetero(), &[duo()], &routers,
                                  &FleetOpts::default(), 2)
            .unwrap();
        assert_eq!(grid.cells.len(), 3);
        assert!(grid.cell("duo-burst", "criticality-affinity").is_some());
        let j = grid.to_json();
        let doc = parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("fleet"));
        assert_eq!(doc.get("cells").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
        assert_eq!(doc.get("devices").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
    }

    #[test]
    fn seed_override_changes_a_stochastic_run() {
        let a = run_fleet(&hetero(), &duo(),
                          &FleetOpts { seed: Some(11),
                                       ..FleetOpts::default() })
            .unwrap();
        let b = run_fleet(&hetero(), &duo(),
                          &FleetOpts { seed: Some(12),
                                       ..FleetOpts::default() })
            .unwrap();
        assert_eq!(a.seed, 11);
        assert_eq!(b.seed, 12);
        assert_ne!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string());
    }

    #[test]
    fn kill_and_heal_conserves_requests_and_requeues() {
        // Kill the fastest device mid-run and heal it: nothing may be
        // lost (a survivor stays live throughout) and the drained
        // requests must show up as requeues.
        let chaos = ChaosSpec::parse("down:d0@5ms+8ms").unwrap();
        for r in ROUTERS {
            let opts = FleetOpts {
                router: r.into(),
                chaos: chaos.clone(),
                ..FleetOpts::default()
            };
            let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
            assert!(rep.resilience, "{r}");
            assert_eq!(rep.chaos, "cli", "{r}");
            assert_eq!(rep.offered(), rep.admitted() + rep.shed(), "{r}");
            assert_eq!(rep.admitted(), rep.served() + rep.lost(), "{r}");
            assert_eq!(rep.lost(), 0, "{r}: lost with a live survivor");
            assert_eq!(rep.shed_critical(), 0, "{r}");
            assert_eq!(rep.routed(), rep.admitted(), "{r}");
            let requeued_in: u64 =
                rep.devices.iter().map(|d| d.requeued_in).sum();
            assert_eq!(requeued_in, rep.requeues(),
                       "{r}: device/tenant requeue ledgers disagree");
            assert!(rep.devices[0].downtime_us > 0.0,
                    "{r}: killed device shows no downtime");
            assert!(rep.recovery_us.is_finite(),
                    "{r}: no recovery recorded");
        }
    }

    #[test]
    fn terminal_outage_loses_what_it_must_and_no_more() {
        // Kill every device forever at 5ms: requests admitted before
        // the blackout are either served or lost, and the ledgers
        // balance exactly.
        let chaos =
            ChaosSpec::parse("down:d0@5ms,down:d1@5ms,down:d2@5ms")
                .unwrap();
        let opts =
            FleetOpts { chaos, ..FleetOpts::default() };
        let rep = run_fleet(&hetero(), &duo(), &opts).unwrap();
        assert_eq!(rep.offered(), rep.admitted() + rep.shed());
        assert_eq!(rep.admitted(), rep.served() + rep.lost());
        assert!(rep.lost() > 0, "a permanent blackout lost nothing?");
        assert!(rep.devices.iter().all(|d| d.downtime_us > 0.0));
    }

    #[test]
    fn autoscaler_attaches_under_pressure_and_stays_deterministic() {
        // A slow single primary under five-storm load with a tight
        // high watermark: the scaler must pull in the standby.
        let fleet =
            FleetSpec::parse(&["tx2".into()], &["miriam".into()]).unwrap();
        let sc = scenario::by_name("five-storm", DUR_US).unwrap();
        let opts = FleetOpts {
            autoscale: Some(AutoscaleConfig {
                pool: vec!["rtx2060".into()],
                high_watermark_us: 500.0,
                low_watermark_us: 1.0,
                eval_period_us: 1_000.0,
                cooldown_us: 2_000.0,
                ..AutoscaleConfig::default()
            }),
            ..FleetOpts::default()
        };
        let a = run_fleet(&fleet, &sc, &opts).unwrap();
        assert!(a.resilience);
        assert!(a.attaches >= 1, "scaler never attached the standby");
        assert_eq!(a.devices.len(), 2, "pool device missing from report");
        assert_eq!(a.devices[1].desc.name, "s0-rtx2060");
        assert!(a.devices[1].routed > 0,
                "attached standby never received work");
        assert_eq!(a.admitted(), a.served() + a.lost());
        assert_eq!(a.lost(), 0);
        let b = run_fleet(&fleet, &sc, &opts).unwrap();
        assert_eq!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string(),
                   "autoscaled runs diverged across repeats");
    }

    #[test]
    fn resilience_grid_shape_errors_and_json() {
        use crate::runtime::json::{parse, Json};
        let routers: Vec<String> =
            ROUTERS.iter().map(|r| r.to_string()).collect();
        let storms: Vec<String> =
            STORMS.iter().map(|s| s.to_string()).collect();
        let grid = run_resilience_grid(&hetero(), &[duo()], &storms,
                                       &routers, &FleetOpts::default(), 2)
            .unwrap();
        assert_eq!(grid.cells.len(), STORMS.len() * ROUTERS.len());
        assert!(grid
            .cell("duo-burst", "rolling-outage", "round-robin")
            .is_some());
        let j = grid.to_json();
        let doc = parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str),
                   Some("resilience"));
        assert_eq!(
            doc.get("comparisons").and_then(Json::as_arr).map(|a| a.len()),
            Some(grid.cells.len())
        );
        // Unknown storm: error lists the vocabulary.
        let err = run_resilience_grid(&hetero(), &[duo()],
                                      &["category-5".into()], &routers,
                                      &FleetOpts::default(), 1)
            .unwrap_err();
        for name in STORMS {
            assert!(err.contains(name), "{err}");
        }
    }
}
