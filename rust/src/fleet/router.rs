//! Pluggable request-to-device routing policies (ISSUE 5 tentpole;
//! chaos-aware since ISSUE 6).
//!
//! Per-device scheduling decides *when and how* a request's kernels run;
//! routing decides *where* — the placement dimension that EdgeServing and
//! the edge-GPU performance-isolation literature show is as decisive as
//! scheduling for deadline compliance on heterogeneous fleets. A
//! [`RouterPolicy`] sees one admitted request at a time plus a
//! [`FleetView`] of the devices and returns the device index; the fleet
//! loop (`crate::fleet::run_fleet`) does the rest.
//!
//! Three policies ship (names in [`ROUTERS`]):
//!
//! * `round-robin` — class-blind rotation over the **live** devices; the
//!   placement baseline every comparison is made against.
//! * `least-outstanding-work` — pick the live device whose
//!   envelope-weighted backlog *after* placing this request would be
//!   smallest. Backlogs are weighted by each device's own
//!   [`ModelEnvelope::solo_us`] for the request's model
//!   (`crate::coordinator::admission::model_envelopes`), so a slow
//!   device accrues more microseconds per routed request than a fast
//!   one — device speed is priced in, not just queue length.
//! * `criticality-affinity` — critical tenants are pinned to the fastest
//!   **live** device ([`FleetView::fastest_live`], recomputed by the
//!   fleet loop on every kill/heal/throttle); best-effort requests fill
//!   the remaining live devices round-robin (everything shares the one
//!   device when only one is live). The placement analog of Miriam's
//!   dedicated critical stream — and when the fastest device dies, the
//!   pin follows the fastest *survivor* and snaps back on heal.
//!
//! With every device live the policies are arithmetically identical to
//! their pre-chaos (PR 5) forms — fleet runs under a zero-event
//! [`ChaosSpec`](crate::fleet::chaos::ChaosSpec) are pinned bitwise by
//! `rust/tests/fleet_determinism.rs`. Every policy is pure arithmetic
//! over the view (no RNG, no host state), so fleet runs stay
//! byte-deterministic per seed; ties break toward the lowest device
//! index. `rust/tests/prop_invariants.rs` pins routed-exactly-once
//! conservation and the criticality-affinity pinning invariant.
//!
//! [`ModelEnvelope::solo_us`]: crate::coordinator::admission::ModelEnvelope

use crate::gpu::kernel::Criticality;

/// Router names, in presentation order (baseline first) — the default
/// `miriam fleet-sim --router all` / `benches/fleet_serving.rs`
/// comparison set.
pub const ROUTERS: [&str; 3] =
    ["round-robin", "least-outstanding-work", "criticality-affinity"];

/// What a router is allowed to see when placing one request: per-device
/// envelope-weighted backlogs, the per-device × per-source envelope
/// table, which devices are currently live, and which live device is
/// fastest right now.
#[derive(Debug)]
pub struct FleetView<'a> {
    /// Envelope-weighted outstanding work per device (us of solo service
    /// time routed there and not yet served).
    pub outstanding_us: &'a [f64],
    /// `env_solo_us[device][source]`: the solo latency envelope of
    /// `source`'s model on `device`.
    pub env_solo_us: &'a [Vec<f64>],
    /// `live[device]`: whether the device can accept requests right now
    /// (not down, not draining, not parked in the standby pool).
    pub live: &'a [bool],
    /// Index of the fastest **live** device (criticality-affinity
    /// target), recomputed by the fleet loop on every topology change.
    pub fastest_live: usize,
}

/// A request-to-device placement policy. Implementations must return a
/// **live** index `< view.live.len()` and be deterministic functions of
/// their own state plus the view. The fleet loop only calls a router
/// while at least one device is live.
pub trait RouterPolicy {
    /// Stable router name (CLI / report key).
    fn name(&self) -> &'static str;

    /// Place one admitted request from `source` (class `criticality`).
    fn route(&mut self, source: usize, criticality: Criticality,
             view: &FleetView<'_>) -> usize;

    /// Re-place a request drained from a dead device (ISSUE 6). The
    /// default routes through the normal live-device path, which is the
    /// right answer for every shipped policy — criticality-affinity
    /// re-pins critical work to the fastest survivor for free because
    /// `route` reads [`FleetView::fastest_live`]. Override to treat
    /// requeues differently from fresh arrivals.
    fn rebalance(&mut self, source: usize, criticality: Criticality,
                 view: &FleetView<'_>) -> usize {
        self.route(source, criticality, view)
    }
}

/// Class-blind rotation over the live devices.
struct RoundRobin {
    devices: usize,
    next: usize,
}

impl RouterPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _source: usize, _criticality: Criticality,
             view: &FleetView<'_>) -> usize {
        // Advance the rotor until it lands on a live device. With every
        // device live this is the pre-chaos single step, so zero-event
        // runs stay bitwise identical to PR 5.
        for _ in 0..self.devices {
            let d = self.next;
            self.next = (self.next + 1) % self.devices;
            if view.live[d] {
                return d;
            }
        }
        view.fastest_live
    }
}

/// Argmin over live devices of (current backlog + this request's own
/// envelope there) — smallest *resulting* backlog, so device speed
/// matters.
struct LeastOutstandingWork;

impl RouterPolicy for LeastOutstandingWork {
    fn name(&self) -> &'static str {
        "least-outstanding-work"
    }

    fn route(&mut self, source: usize, _criticality: Criticality,
             view: &FleetView<'_>) -> usize {
        let mut best = view.fastest_live;
        let mut best_us = f64::INFINITY;
        for (d, out) in view.outstanding_us.iter().enumerate() {
            if !view.live[d] {
                continue;
            }
            let resulting = out + view.env_solo_us[d][source];
            // Strict `<`: ties stay on the lowest index (determinism).
            if resulting < best_us {
                best_us = resulting;
                best = d;
            }
        }
        best
    }
}

/// Critical requests pinned to the fastest live device; best-effort
/// requests round-robin over the remaining live devices.
struct CriticalityAffinity {
    next_normal: usize,
}

impl RouterPolicy for CriticalityAffinity {
    fn name(&self) -> &'static str {
        "criticality-affinity"
    }

    fn route(&mut self, _source: usize, criticality: Criticality,
             view: &FleetView<'_>) -> usize {
        if criticality == Criticality::Critical {
            return view.fastest_live;
        }
        // Rotate over the live devices with `fastest_live` skipped.
        // The rotor counts placements (not indices), so with all
        // devices live `k` walks the same 0..others cycle as the
        // pre-chaos router and zero-event runs stay bitwise identical.
        let others = view
            .live
            .iter()
            .enumerate()
            .filter(|&(d, &l)| l && d != view.fastest_live)
            .count();
        if others == 0 {
            return view.fastest_live;
        }
        let k = self.next_normal % others;
        self.next_normal = self.next_normal.wrapping_add(1);
        view.live
            .iter()
            .enumerate()
            .filter(|&(d, &l)| l && d != view.fastest_live)
            .nth(k)
            .map(|(d, _)| d)
            .unwrap_or(view.fastest_live)
    }
}

/// Build a router by (case-insensitive) name for a fleet of
/// `devices` devices. `None` for an unknown name — callers report the
/// [`ROUTERS`] vocabulary in their error.
pub fn router_for(name: &str, devices: usize)
                  -> Option<Box<dyn RouterPolicy>> {
    match name.to_ascii_lowercase().as_str() {
        "round-robin" | "round_robin" | "rr" => {
            Some(Box::new(RoundRobin { devices, next: 0 }))
        }
        "least-outstanding-work" | "least_outstanding_work" | "low" => {
            Some(Box::new(LeastOutstandingWork))
        }
        "criticality-affinity" | "criticality_affinity" | "affinity" => {
            Some(Box::new(CriticalityAffinity { next_normal: 0 }))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view<'a>(outstanding: &'a [f64], env: &'a [Vec<f64>],
                live: &'a [bool], fastest_live: usize) -> FleetView<'a> {
        FleetView { outstanding_us: outstanding, env_solo_us: env,
                    live, fastest_live }
    }

    #[test]
    fn all_router_names_resolve_and_round_trip() {
        for name in ROUTERS {
            let r = router_for(name, 3)
                .unwrap_or_else(|| panic!("router {name} does not resolve"));
            assert_eq!(r.name(), name);
        }
        assert!(router_for("ROUND-ROBIN", 2).is_some());
        assert!(router_for("least_outstanding_work", 2).is_some());
        assert!(router_for("random", 2).is_none());
    }

    #[test]
    fn round_robin_cycles_over_all_devices() {
        let env = vec![vec![1.0]; 3];
        let out = [0.0; 3];
        let live = [true; 3];
        let v = view(&out, &env, &live, 0);
        let mut r = router_for("round-robin", 3).unwrap();
        let picks: Vec<usize> = (0..7)
            .map(|_| r.route(0, Criticality::Normal, &v))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn round_robin_skips_dead_devices() {
        let env = vec![vec![1.0]; 3];
        let out = [0.0; 3];
        let live = [true, false, true];
        let v = view(&out, &env, &live, 0);
        let mut r = router_for("round-robin", 3).unwrap();
        let picks: Vec<usize> = (0..4)
            .map(|_| r.route(0, Criticality::Normal, &v))
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "dead device 1 was routed to");
    }

    #[test]
    fn least_outstanding_work_prices_in_device_speed() {
        // Device 0 is idle but slow (envelope 100us); device 1 carries
        // 30us of backlog but is fast (envelope 10us): 0+100 > 30+10.
        let env = vec![vec![100.0], vec![10.0]];
        let out = [0.0, 30.0];
        let live = [true, true];
        let v = view(&out, &env, &live, 1);
        let mut r = router_for("least-outstanding-work", 2).unwrap();
        assert_eq!(r.route(0, Criticality::Normal, &v), 1);
        // Equal resulting backlogs tie toward the lowest index.
        let env = vec![vec![10.0], vec![10.0]];
        let out = [5.0, 5.0];
        let v = view(&out, &env, &live, 0);
        assert_eq!(r.route(0, Criticality::Normal, &v), 0);
        // A dead device never wins, however empty its backlog looks.
        let env = vec![vec![10.0], vec![10.0]];
        let out = [0.0, 500.0];
        let dead0 = [false, true];
        let v = view(&out, &env, &dead0, 1);
        assert_eq!(r.route(0, Criticality::Normal, &v), 1);
    }

    #[test]
    fn criticality_affinity_pins_critical_and_rotates_normals() {
        let env = vec![vec![1.0]; 3];
        let out = [0.0; 3];
        let live = [true; 3];
        let v = view(&out, &env, &live, 1); // device 1 is fastest
        let mut r = router_for("criticality-affinity", 3).unwrap();
        for _ in 0..5 {
            assert_eq!(r.route(0, Criticality::Critical, &v), 1);
        }
        let normals: Vec<usize> = (0..4)
            .map(|_| r.route(0, Criticality::Normal, &v))
            .collect();
        assert_eq!(normals, vec![0, 2, 0, 2], "normals skip the affine device");
        // 1-device fleet: everything lands on the only device.
        let env1 = vec![vec![1.0]];
        let out1 = [0.0];
        let live1 = [true];
        let v1 = view(&out1, &env1, &live1, 0);
        let mut r1 = router_for("criticality-affinity", 1).unwrap();
        assert_eq!(r1.route(0, Criticality::Normal, &v1), 0);
        assert_eq!(r1.route(0, Criticality::Critical, &v1), 0);
    }

    #[test]
    fn criticality_affinity_follows_the_fastest_survivor() {
        // The fastest device (1) dies: the fleet loop recomputes
        // fastest_live to the fastest survivor (2) and critical work
        // must follow the new pin; normals rotate over what's left.
        let env = vec![vec![1.0]; 3];
        let out = [0.0; 3];
        let live = [true, false, true];
        let v = view(&out, &env, &live, 2);
        let mut r = router_for("criticality-affinity", 3).unwrap();
        assert_eq!(r.route(0, Criticality::Critical, &v), 2);
        assert_eq!(r.route(0, Criticality::Normal, &v), 0);
        assert_eq!(r.route(0, Criticality::Normal, &v), 0);
        // Heal: the pin snaps back to device 1.
        let live = [true, true, true];
        let v = view(&out, &env, &live, 1);
        assert_eq!(r.route(0, Criticality::Critical, &v), 1);
        // Only the pinned device left: normals fall through to it.
        let live = [false, true, false];
        let v = view(&out, &env, &live, 1);
        assert_eq!(r.route(0, Criticality::Normal, &v), 1);
    }

    #[test]
    fn rebalance_defaults_to_the_live_routing_path() {
        let env = vec![vec![1.0]; 2];
        let out = [0.0; 2];
        let live = [false, true];
        let v = view(&out, &env, &live, 1);
        for name in ROUTERS {
            let mut r = router_for(name, 2).unwrap();
            assert_eq!(r.rebalance(0, Criticality::Normal, &v), 1,
                       "{name}: rebalance targeted a dead device");
            assert_eq!(r.rebalance(0, Criticality::Critical, &v), 1,
                       "{name}: critical rebalance missed the survivor");
        }
    }
}
