//! The dynamic-sized **shaded binary tree** for elastic-kernel shard
//! formation (paper §7, Fig. 7).
//!
//! The root represents a normal kernel with `M` logical thread blocks.
//! Each level halves the shard size (the *sharding degree*); each node's
//! "shading" is the elastic block size the shard would run with. At
//! runtime the coordinator walks the tree head: it carves the largest
//! shard that fits the resources left over by resident critical kernels
//! ("actual shards"), leaving the rest of the kernel as "virtual shards"
//! to be re-evaluated against whatever critical kernel is resident when
//! their turn comes.

use crate::elastic::candidate::Candidate;
use crate::gpu::kernel::{KernelDesc, LaunchConfig};

/// Resources currently left over for padding (derived from a
/// [`crate::gpu::engine::GpuSnapshot`]).
#[derive(Debug, Clone, Copy)]
pub struct Leftover {
    /// Thread blocks that can dispatch without displacing critical work
    /// (Eq. 2 first constraint: `N_SM - N_blk_rt mod N_SM`).
    pub blocks: u32,
    /// Threads per SM left beside a resident critical block (Eq. 2 second
    /// constraint: `L_threads - S_blk_rt`).
    pub threads: u32,
    /// Whether any critical work is resident or pending — when false the
    /// padder may use the whole GPU (identity geometry).
    pub critical_active: bool,
}

/// Tracks the shard decomposition of one elastic kernel instance.
#[derive(Debug, Clone)]
pub struct ShadedTree {
    kernel: KernelDesc,
    /// Candidate schedules, best-ranked first (from the offline shrink).
    candidates: Vec<Candidate>,
    /// Logical blocks not yet dispatched.
    remaining: u32,
    /// Logical blocks dispatched but not yet completed.
    inflight_blocks: u32,
    /// Shards dispatched so far (the sharding degree achieved).
    shards_cut: u32,
}

impl ShadedTree {
    pub fn new(kernel: KernelDesc, candidates: Vec<Candidate>) -> Self {
        assert!(!candidates.is_empty(), "need at least the identity candidate");
        let remaining = kernel.grid;
        ShadedTree { kernel, candidates, remaining, inflight_blocks: 0, shards_cut: 0 }
    }

    pub fn kernel(&self) -> &KernelDesc {
        &self.kernel
    }

    /// The top-ranked offline candidate (used by the static-sharding
    /// ablation; the dynamic policy re-fits per carve instead).
    pub fn first_candidate(&self) -> Candidate {
        self.candidates[0]
    }

    /// Logical blocks still to dispatch.
    pub fn remaining(&self) -> u32 {
        self.remaining
    }

    /// All work dispatched (tree fully carved)?
    pub fn fully_dispatched(&self) -> bool {
        self.remaining == 0
    }

    /// All work dispatched *and* completed?
    pub fn finished(&self) -> bool {
        self.remaining == 0 && self.inflight_blocks == 0
    }

    pub fn shards_cut(&self) -> u32 {
        self.shards_cut
    }

    /// Carve the next actual shard given current leftovers. Returns `None`
    /// when nothing remains or nothing fits (the coordinator retries at the
    /// next event). The policy (paper §7): the largest candidate shard that
    /// respects Eq. 2 against the resident critical kernel; with no
    /// critical work resident, the whole remainder goes out at the
    /// original block size — "allocate all available resources".
    pub fn next_shard(&mut self, left: &Leftover) -> Option<LaunchConfig> {
        if self.remaining == 0 {
            return None;
        }
        let (blocks, threads) = if !left.critical_active {
            // Run-alone fast path: identity geometry for the remainder.
            (self.remaining, self.kernel.block_threads)
        } else {
            if left.blocks == 0 || left.threads == 0 {
                return None;
            }
            // Largest-first fit over the ranked candidate lattice.
            let fit = self
                .candidates
                .iter()
                .filter(|c| {
                    c.n_blocks <= left.blocks && c.block_threads <= left.threads
                })
                .max_by_key(|c| (c.n_blocks, c.block_threads))?;
            (fit.n_blocks.min(self.remaining), fit.block_threads)
        };
        let frac = blocks as f64 / self.kernel.grid as f64;
        self.remaining -= blocks;
        self.inflight_blocks += blocks;
        self.shards_cut += 1;
        Some(LaunchConfig {
            name: format!("{}#es{}", self.kernel.name, self.shards_cut - 1),
            grid: blocks,
            block_threads: threads.min(self.kernel.block_threads).max(1),
            smem_per_block: self.kernel.smem_per_block.min(
                ((self.kernel.smem_per_block as f64
                    * (threads as f64 / self.kernel.block_threads as f64)
                        .min(1.0))
                    .ceil()) as u32,
            ),
            regs_per_thread: self.kernel.regs_per_thread,
            flops: self.kernel.flops * frac,
            bytes: self.kernel.bytes * frac,
        })
    }

    /// Record completion of a previously carved shard.
    pub fn shard_done(&mut self, grid: u32) {
        assert!(grid <= self.inflight_blocks,
                "completing more blocks than inflight");
        self.inflight_blocks -= grid;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel(grid: u32) -> KernelDesc {
        KernelDesc {
            name: "n/k".into(),
            grid,
            block_threads: 256,
            smem_per_block: 8192,
            regs_per_thread: 32,
            flops: 1e7,
            bytes: 2e5,
        }
    }

    fn cands() -> Vec<Candidate> {
        vec![
            Candidate { n_blocks: 16, block_threads: 256 },
            Candidate { n_blocks: 8, block_threads: 128 },
            Candidate { n_blocks: 4, block_threads: 64 },
            Candidate { n_blocks: 2, block_threads: 32 },
        ]
    }

    #[test]
    fn no_critical_dispatches_identity_remainder() {
        let mut t = ShadedTree::new(kernel(64), cands());
        let l = Leftover { blocks: 0, threads: 0, critical_active: false };
        let s = t.next_shard(&l).unwrap();
        assert_eq!(s.grid, 64);
        assert_eq!(s.block_threads, 256);
        assert!(t.fully_dispatched());
        assert!(!t.finished());
        t.shard_done(64);
        assert!(t.finished());
    }

    #[test]
    fn critical_active_carves_fitting_shards() {
        let mut t = ShadedTree::new(kernel(64), cands());
        let l = Leftover { blocks: 10, threads: 200, critical_active: true };
        // Largest fit: blocks<=10 & threads<=200 -> (8, 128).
        let s = t.next_shard(&l).unwrap();
        assert_eq!(s.grid, 8);
        assert_eq!(s.block_threads, 128);
        assert_eq!(t.remaining(), 56);
        // Work fraction proportional to carved blocks.
        assert!((s.flops - 1e7 * 8.0 / 64.0).abs() < 1.0);
    }

    #[test]
    fn tight_leftover_blocks_padding() {
        let mut t = ShadedTree::new(kernel(64), cands());
        let l = Leftover { blocks: 1, threads: 16, critical_active: true };
        assert!(t.next_shard(&l).is_none(), "nothing fits");
        assert_eq!(t.remaining(), 64);
        let l2 = Leftover { blocks: 0, threads: 512, critical_active: true };
        assert!(t.next_shard(&l2).is_none());
    }

    #[test]
    fn shards_partition_grid() {
        let mut t = ShadedTree::new(kernel(50), cands());
        let l = Leftover { blocks: 16, threads: 512, critical_active: true };
        let mut total = 0;
        while let Some(s) = t.next_shard(&l) {
            total += s.grid;
        }
        assert_eq!(total, 50);
        assert!(t.fully_dispatched());
    }

    #[test]
    fn tail_shard_clipped_to_remainder() {
        let mut t = ShadedTree::new(kernel(10), cands());
        let l = Leftover { blocks: 16, threads: 512, critical_active: true };
        let s1 = t.next_shard(&l).unwrap();
        assert_eq!(s1.grid, 10); // candidate 16 clipped to remaining 10
        assert!(t.fully_dispatched());
    }

    #[test]
    fn work_fraction_sums_to_total() {
        let mut t = ShadedTree::new(kernel(64), cands());
        let l = Leftover { blocks: 4, threads: 128, critical_active: true };
        let mut flops = 0.0;
        let mut bytes = 0.0;
        while let Some(s) = t.next_shard(&l) {
            flops += s.flops;
            bytes += s.bytes;
        }
        assert!((flops - 1e7).abs() < 1e-3);
        assert!((bytes - 2e5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "more blocks than inflight")]
    fn over_completion_panics() {
        let mut t = ShadedTree::new(kernel(8), cands());
        t.shard_done(1);
    }
}
