//! The dynamic-sized **shaded binary tree** for elastic-kernel shard
//! formation (paper §7, Fig. 7).
//!
//! The root represents a normal kernel with `M` logical thread blocks.
//! Each level halves the shard size (the *sharding degree*); each node's
//! "shading" is the elastic block size the shard would run with. At
//! runtime the coordinator walks the tree head: it carves the largest
//! shard that fits the resources left over by resident critical kernels
//! ("actual shards"), leaving the rest of the kernel as "virtual shards"
//! to be re-evaluated against whatever critical kernel is resident when
//! their turn comes.
//!
//! The tree borrows its kernel and candidate lattice from a shared
//! [`Arc<ElasticKernel>`] (the coordinator's per-name cache entry), so
//! rebuilding the tree for the next kernel of a task reuses the candidate
//! storage instead of cloning it (ISSUE 3 zero-clone fast path); a carved
//! [`Shard`] is a `Copy` [`LaunchShape`] plus its shard index — naming is
//! the coordinator's job, which interns each `name#esN` string once.

use std::sync::Arc;

use crate::elastic::candidate::Candidate;
use crate::elastic::ElasticKernel;
use crate::gpu::kernel::{KernelDesc, LaunchShape};

/// Resources currently left over for padding (derived from a
/// [`crate::gpu::engine::Residency`]).
#[derive(Debug, Clone, Copy)]
pub struct Leftover {
    /// Thread blocks that can dispatch without displacing critical work
    /// (Eq. 2 first constraint: `N_SM - N_blk_rt mod N_SM`).
    pub blocks: u32,
    /// Threads per SM left beside a resident critical block (Eq. 2 second
    /// constraint: `L_threads - S_blk_rt`).
    pub threads: u32,
    /// Whether any critical work is resident or pending — when false the
    /// padder may use the whole GPU (identity geometry).
    pub critical_active: bool,
}

/// One carved ("actual") shard: the launch geometry/work plus the shard
/// index within its kernel instance (names as `kernel#es{index}`).
#[derive(Debug, Clone, Copy)]
pub struct Shard {
    /// Shard index within its kernel instance.
    pub index: u32,
    /// The shard's launch geometry and covered work.
    pub shape: LaunchShape,
}

/// Tracks the shard decomposition of one elastic kernel instance.
#[derive(Debug, Clone)]
pub struct ShadedTree {
    /// Shared offline artifact: kernel descriptor + ranked candidates.
    ek: Arc<ElasticKernel>,
    /// Logical blocks not yet dispatched.
    remaining: u32,
    /// Logical blocks dispatched but not yet completed.
    inflight_blocks: u32,
    /// Shards dispatched so far (the sharding degree achieved).
    shards_cut: u32,
}

impl ShadedTree {
    /// A fresh tree over one elastic-kernel instance (all work pending).
    pub fn new(ek: Arc<ElasticKernel>) -> Self {
        assert!(!ek.candidates.is_empty(),
                "need at least the identity candidate");
        let remaining = ek.kernel.grid;
        ShadedTree { ek, remaining, inflight_blocks: 0, shards_cut: 0 }
    }

    /// The base kernel this tree decomposes.
    pub fn kernel(&self) -> &KernelDesc {
        &self.ek.kernel
    }

    /// The top-ranked offline candidate (used by the static-sharding
    /// ablation; the dynamic policy re-fits per carve instead).
    pub fn first_candidate(&self) -> Candidate {
        self.ek.candidates[0]
    }

    /// Logical blocks still to dispatch.
    pub fn remaining(&self) -> u32 {
        self.remaining
    }

    /// All work dispatched (tree fully carved)?
    pub fn fully_dispatched(&self) -> bool {
        self.remaining == 0
    }

    /// All work dispatched *and* completed?
    pub fn finished(&self) -> bool {
        self.remaining == 0 && self.inflight_blocks == 0
    }

    /// Shards dispatched so far (the sharding degree achieved).
    pub fn shards_cut(&self) -> u32 {
        self.shards_cut
    }

    /// Carve the next actual shard given current leftovers. Returns `None`
    /// when nothing remains or nothing fits (the coordinator retries at the
    /// next event). The policy (paper §7): the largest candidate shard that
    /// respects Eq. 2 against the resident critical kernel; with no
    /// critical work resident, the whole remainder goes out at the
    /// original block size — "allocate all available resources".
    pub fn next_shard(&mut self, left: &Leftover) -> Option<Shard> {
        if self.remaining == 0 {
            return None;
        }
        let (blocks, threads) = if !left.critical_active {
            // Run-alone fast path: identity geometry for the remainder.
            (self.remaining, self.ek.kernel.block_threads)
        } else {
            if left.blocks == 0 || left.threads == 0 {
                return None;
            }
            // Largest-first fit over the ranked candidate lattice.
            let fit = self
                .ek
                .candidates
                .iter()
                .filter(|c| {
                    c.n_blocks <= left.blocks && c.block_threads <= left.threads
                })
                .max_by_key(|c| (c.n_blocks, c.block_threads))?;
            (fit.n_blocks.min(self.remaining), fit.block_threads)
        };
        let k = &self.ek.kernel;
        let frac = blocks as f64 / k.grid as f64;
        self.remaining -= blocks;
        self.inflight_blocks += blocks;
        self.shards_cut += 1;
        Some(Shard {
            index: self.shards_cut - 1,
            shape: LaunchShape {
                grid: blocks,
                block_threads: threads.min(k.block_threads).max(1),
                smem_per_block: k.smem_per_block.min(
                    ((k.smem_per_block as f64
                        * (threads as f64 / k.block_threads as f64).min(1.0))
                        .ceil()) as u32,
                ),
                regs_per_thread: k.regs_per_thread,
                flops: k.flops * frac,
                bytes: k.bytes * frac,
            },
        })
    }

    /// Record completion of a previously carved shard.
    pub fn shard_done(&mut self, grid: u32) {
        assert!(grid <= self.inflight_blocks,
                "completing more blocks than inflight");
        self.inflight_blocks -= grid;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel(grid: u32) -> KernelDesc {
        KernelDesc {
            name: "n/k".into(),
            grid,
            block_threads: 256,
            smem_per_block: 8192,
            regs_per_thread: 32,
            flops: 1e7,
            bytes: 2e5,
        }
    }

    fn cands() -> Vec<Candidate> {
        vec![
            Candidate { n_blocks: 16, block_threads: 256 },
            Candidate { n_blocks: 8, block_threads: 128 },
            Candidate { n_blocks: 4, block_threads: 64 },
            Candidate { n_blocks: 2, block_threads: 32 },
        ]
    }

    fn tree(grid: u32) -> ShadedTree {
        ShadedTree::new(Arc::new(ElasticKernel {
            kernel: kernel(grid),
            candidates: cands(),
        }))
    }

    #[test]
    fn no_critical_dispatches_identity_remainder() {
        let mut t = tree(64);
        let l = Leftover { blocks: 0, threads: 0, critical_active: false };
        let s = t.next_shard(&l).unwrap();
        assert_eq!(s.shape.grid, 64);
        assert_eq!(s.shape.block_threads, 256);
        assert_eq!(s.index, 0);
        assert!(t.fully_dispatched());
        assert!(!t.finished());
        t.shard_done(64);
        assert!(t.finished());
    }

    #[test]
    fn critical_active_carves_fitting_shards() {
        let mut t = tree(64);
        let l = Leftover { blocks: 10, threads: 200, critical_active: true };
        // Largest fit: blocks<=10 & threads<=200 -> (8, 128).
        let s = t.next_shard(&l).unwrap();
        assert_eq!(s.shape.grid, 8);
        assert_eq!(s.shape.block_threads, 128);
        assert_eq!(t.remaining(), 56);
        // Work fraction proportional to carved blocks.
        assert!((s.shape.flops - 1e7 * 8.0 / 64.0).abs() < 1.0);
    }

    #[test]
    fn tight_leftover_blocks_padding() {
        let mut t = tree(64);
        let l = Leftover { blocks: 1, threads: 16, critical_active: true };
        assert!(t.next_shard(&l).is_none(), "nothing fits");
        assert_eq!(t.remaining(), 64);
        let l2 = Leftover { blocks: 0, threads: 512, critical_active: true };
        assert!(t.next_shard(&l2).is_none());
    }

    #[test]
    fn shards_partition_grid_with_sequential_indexes() {
        let mut t = tree(50);
        let l = Leftover { blocks: 16, threads: 512, critical_active: true };
        let mut total = 0;
        let mut expect_idx = 0;
        while let Some(s) = t.next_shard(&l) {
            assert_eq!(s.index, expect_idx);
            expect_idx += 1;
            total += s.shape.grid;
        }
        assert_eq!(total, 50);
        assert!(t.fully_dispatched());
        assert_eq!(t.shards_cut(), expect_idx);
    }

    #[test]
    fn tail_shard_clipped_to_remainder() {
        let mut t = tree(10);
        let l = Leftover { blocks: 16, threads: 512, critical_active: true };
        let s1 = t.next_shard(&l).unwrap();
        assert_eq!(s1.shape.grid, 10); // candidate 16 clipped to remaining 10
        assert!(t.fully_dispatched());
    }

    #[test]
    fn work_fraction_sums_to_total() {
        let mut t = tree(64);
        let l = Leftover { blocks: 4, threads: 128, critical_active: true };
        let mut flops = 0.0;
        let mut bytes = 0.0;
        while let Some(s) = t.next_shard(&l) {
            flops += s.shape.flops;
            bytes += s.shape.bytes;
        }
        assert!((flops - 1e7).abs() < 1e-3);
        assert!((bytes - 2e5).abs() < 1e-6);
    }

    #[test]
    fn rebuilds_share_candidate_storage() {
        // The zero-clone contract: trees built from the same cache entry
        // alias the same ElasticKernel allocation.
        let ek = Arc::new(ElasticKernel { kernel: kernel(8), candidates: cands() });
        let t1 = ShadedTree::new(ek.clone());
        let t2 = ShadedTree::new(ek.clone());
        assert!(std::ptr::eq(
            t1.first_candidate_ptr(), t2.first_candidate_ptr()));
        assert_eq!(Arc::strong_count(&ek), 3);
    }

    #[test]
    #[should_panic(expected = "more blocks than inflight")]
    fn over_completion_panics() {
        let mut t = tree(8);
        t.shard_done(1);
    }
}

#[cfg(test)]
impl ShadedTree {
    /// Test hook: address of the shared candidate storage.
    fn first_candidate_ptr(&self) -> *const Candidate {
        &self.ek.candidates[0]
    }
}
