//! The Miriam coordinator (paper §5–§7): critical kernels launch
//! untouched and immediately on a high-priority stream; normal kernels are
//! elasticized offline and padded at runtime as shards carved from a
//! shaded binary tree, sized to the GPU resources the resident critical
//! blocks leave over ("bin-packing", §7).
//!
//! Runtime policy (§7's greedy coordinator):
//! * when critical work is resident, shards are carved *thin*: block
//!   threads bounded to `pad_fill_frac` of the intra-SM leftover (Eq. 2's
//!   "do not exceed too much of the spare intra-SM resources"), so the
//!   foreign-thread interference on critical blocks stays trivial;
//! * when the GPU is free of critical work, the remainder of the kernel
//!   launches at its original geometry ("allocate all available
//!   resources").
//!
//! Per-decision cost (ISSUE 3 zero-clone fast path): the elastic cache is
//! keyed by interned kernel-name id (`Req::name_ids`) and holds
//! `Arc<ElasticKernel>`, so cache hits clone a pointer, not a candidate
//! vector; shard names are interned once per (kernel, shard index) and
//! submitted through [`Engine::submit_interned`]; per-pad-stream load is a
//! flat `Vec` indexed by stream id; and the leftover read is the scalar
//! [`Engine::residency`] — once caches are warm the pump + completion path
//! allocates nothing per event (pinned by
//! `rust/tests/alloc_steady_state.rs`). The pre-change path — String-keyed
//! cache, deep `ElasticKernel` clones per kernel advance, `LaunchConfig`
//! submits — is retained behind [`Miriam::with_reference_path`] as the
//! "before" leg of the coordinator-in-the-loop bench
//! (`rust/benches/engine_throughput.rs`, scheduler name `miriam-ref`); it
//! makes identical decisions, only slower.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::coordinator::shaded_tree::{Leftover, ShadedTree};
use crate::elastic::shrink::{CriticalProfile, ShrinkConfig};
use crate::elastic::ElasticKernel;
use crate::gpu::engine::{Completion, Engine, GpuSnapshot, Residency};
use crate::gpu::kernel::{Criticality, LaunchConfig, LaunchShape};
use crate::gpu::stream::{LaunchTag, StreamId};
use crate::workloads::models::ModelRef;

/// Sentinel for a not-yet-interned shard name id.
const UNINTERNED: u32 = u32::MAX;

/// A normal task making its way through its kernels.
struct NormalTask {
    req_id: u64,
    model: ModelRef,
    /// Interned base-kernel name ids, parallel to `model.kernels`.
    name_ids: Arc<Vec<u32>>,
    /// Index of the kernel the tree currently covers.
    kernel_idx: usize,
    tree: ShadedTree,
}

/// A critical task: all kernels submitted at arrival; finished when the
/// last one completes. Tags are contiguous (`first_tag..=last_tag`) —
/// the chain is submitted in one uninterrupted loop — which is what
/// best-effort cancellation sweeps when a hedge loses (ISSUE 8).
struct CriticalTask {
    req_id: u64,
    first_tag: LaunchTag,
    last_tag: LaunchTag,
}

/// The Miriam scheduler.
pub struct Miriam {
    critical_stream: StreamId,
    /// Padding streams for elastic shards (shards on different streams can
    /// co-run; within one stream they serialize).
    pad_streams: Vec<StreamId>,
    num_pad_streams: usize,
    /// Fraction of the intra-SM thread leftover one elastic block may use
    /// while critical work is resident (the interference bound).
    pad_fill_frac: f64,
    /// Offline-generated elastic candidate sets, indexed by the interned
    /// name id of the base kernel. Hits clone the `Arc`, never the
    /// candidates.
    elastic: Vec<Option<Arc<ElasticKernel>>>,
    /// The retained pre-change cache (String-keyed, deep-cloned per use);
    /// only touched when `reference_path` is set.
    elastic_by_name: HashMap<String, Arc<ElasticKernel>>,
    /// Interned `"{kernel}#es{i}"` ids: `shard_name_ids[base_id][i]`,
    /// `UNINTERNED` until first use. Warm carves never format a name.
    shard_name_ids: Vec<Vec<u32>>,
    /// Representative critical launch geometries for the offline shrink.
    crit_profiles: Vec<CriticalProfile>,
    shrink_cfg: ShrinkConfig,
    critical_tasks: Vec<CriticalTask>,
    /// FIFO of normal tasks; any task with undispatched work may be padded
    /// (multiple closed-loop clients keep several in flight).
    normal_queue: VecDeque<NormalTask>,
    /// Outstanding shard tags -> (pad stream, grid blocks, task req id).
    inflight_shards: HashMap<LaunchTag, (StreamId, u32, u64)>,
    /// Shards outstanding per stream, indexed by stream id (bounded to one
    /// per pad stream so carving stays late-bound — geometry is chosen
    /// against the *current* critical context, the shaded tree's
    /// virtual-shard property).
    stream_load: Vec<u32>,
    /// Ablation switch: carve every shard at the top offline candidate's
    /// geometry instead of re-fitting against the live leftover (§7's
    /// "fixed size ... easily become inefficient" failure mode).
    static_sharding: bool,
    /// Run the retained pre-change decision plumbing (bench "before" leg).
    reference_path: bool,
    /// Brownout mode (ISSUE 8): while on, best-effort shards are carved
    /// at half their usual thread budget — degrading normal quality and
    /// latency instead of shedding — so critical work sees extra
    /// headroom when deadline-risk is high. Critical launches are never
    /// touched (they bypass [`Miriam::leftover`] entirely).
    brownout: bool,
    initialized: bool,
}

impl Miriam {
    /// `critical_models` are the models the critical queue may carry —
    /// their kernels give the representative [`CriticalProfile`]s the
    /// offline shrink runs against (paper §6.3 profiles the task set
    /// offline).
    pub fn new(critical_models: &[ModelRef]) -> Self {
        let mut profiles: Vec<CriticalProfile> = Vec::new();
        for m in critical_models {
            for k in &m.kernels {
                let p = CriticalProfile::from_kernel(k);
                if !profiles.contains(&p) {
                    profiles.push(p);
                }
            }
        }
        // Cap the profile set: dedupe keeps it small already, but a bound
        // keeps the offline pass O(candidates * profiles) predictable.
        profiles.truncate(32);
        Miriam {
            critical_stream: 0,
            pad_streams: Vec::new(),
            num_pad_streams: 3,
            pad_fill_frac: 0.6,
            elastic: Vec::new(),
            elastic_by_name: HashMap::new(),
            shard_name_ids: Vec::new(),
            crit_profiles: profiles,
            shrink_cfg: ShrinkConfig::default(),
            critical_tasks: Vec::new(),
            normal_queue: VecDeque::new(),
            inflight_shards: HashMap::new(),
            stream_load: Vec::new(),
            static_sharding: false,
            reference_path: false,
            brownout: false,
            initialized: false,
        }
    }

    /// Builder: override the pad fill fraction (ablation 1).
    pub fn with_fill(mut self, fill: f64) -> Self {
        self.pad_fill_frac = fill;
        self
    }

    /// Builder: use static (offline-fixed) shard geometry (ablation 2).
    pub fn with_static_sharding(mut self, enabled: bool) -> Self {
        self.static_sharding = enabled;
        self
    }

    /// Builder: run the retained pre-change decision plumbing —
    /// String-keyed elastic cache with a deep clone per kernel advance and
    /// `String`-named submits. Identical scheduling decisions, pre-ISSUE-3
    /// cost profile; the "before" leg of the coordinator-in-the-loop bench.
    pub fn with_reference_path(mut self, enabled: bool) -> Self {
        self.reference_path = enabled;
        self
    }

    /// Elastic candidates for a kernel, generated on first use and cached
    /// (the real system does this fully offline; lazy generation keeps the
    /// cache warm across requests of the same model). Fast path: flat-Vec
    /// lookup by interned id, `Arc` clone out. Reference path: the
    /// pre-change String lookup plus deep clone.
    fn elastic_for(&mut self, eng: &Engine, name_id: u32, model: &ModelRef,
                   kernel_idx: usize) -> Arc<ElasticKernel> {
        if self.reference_path {
            let name = &model.kernels[kernel_idx].name;
            if let Some(e) = self.elastic_by_name.get(name) {
                // Deep clone per use — the pre-change cost being measured.
                return Arc::new(ElasticKernel {
                    kernel: e.kernel.clone(),
                    candidates: e.candidates.clone(),
                });
            }
            let k = model.kernels[kernel_idx].clone();
            let e = Arc::new(ElasticKernel::generate(
                k, &self.crit_profiles, &eng.spec, &self.shrink_cfg));
            self.elastic_by_name.insert(name.clone(), e.clone());
            return Arc::new(ElasticKernel {
                kernel: e.kernel.clone(),
                candidates: e.candidates.clone(),
            });
        }
        let idx = name_id as usize;
        if self.elastic.len() <= idx {
            self.elastic.resize_with(idx + 1, || None);
        }
        if let Some(e) = &self.elastic[idx] {
            return e.clone();
        }
        let k = model.kernels[kernel_idx].clone();
        let e = Arc::new(ElasticKernel::generate(
            k, &self.crit_profiles, &eng.spec, &self.shrink_cfg));
        self.elastic[idx] = Some(e.clone());
        e
    }

    /// Interned id of `"{base}#es{shard_idx}"`, formatted and interned at
    /// most once per (kernel, shard index) — warm carves never allocate.
    fn shard_name_id(&mut self, eng: &mut Engine, base: u32, shard_idx: u32)
                     -> u32 {
        let b = base as usize;
        if self.shard_name_ids.len() <= b {
            self.shard_name_ids.resize_with(b + 1, Vec::new);
        }
        let i = shard_idx as usize;
        if self.shard_name_ids[b].len() <= i {
            self.shard_name_ids[b].resize(i + 1, UNINTERNED);
        }
        if self.shard_name_ids[b][i] == UNINTERNED {
            let name = format!("{}#es{shard_idx}", eng.names().resolve(base));
            let id = eng.intern_name(&name);
            debug_assert_ne!(id, UNINTERNED,
                             "interned id collides with the sentinel");
            self.shard_name_ids[b][i] = id;
        }
        self.shard_name_ids[b][i]
    }

    /// Leftover resources for padding, from the scalar residency counters
    /// (Eq. 2 applied to the *current* residency instead of offline
    /// profiles), with the intra-SM bound tightened by `pad_fill_frac`.
    fn leftover(&self, res: &Residency, eng: &Engine) -> Leftover {
        let spec = &eng.spec;
        let critical_active = res.critical_blocks > 0 || res.critical_pending > 0;
        if !critical_active {
            let threads = if self.brownout {
                // Brownout (ISSUE 8): thin best-effort shards even with
                // no critical resident, keeping headroom for the
                // imminent critical arrivals the risk signal predicted.
                (spec.max_threads_per_sm / 2).max(32)
            } else {
                spec.max_threads_per_sm
            };
            return Leftover {
                blocks: spec.num_sms,
                threads,
                critical_active: false,
            };
        }
        let resident_wave = res.critical_blocks % spec.num_sms;
        let blocks = spec.num_sms - resident_wave;
        let crit_threads = if res.critical_block_threads > 0 {
            res.critical_block_threads
        } else {
            // Critical launch still in overhead: assume a fat block until
            // it lands (conservative).
            spec.max_threads_per_sm / 2
        };
        let spare = spec.max_threads_per_sm.saturating_sub(crit_threads);
        let mut threads = ((spare as f64 * self.pad_fill_frac) as u32).max(32);
        if self.brownout {
            threads = (threads / 2).max(32);
        }
        Leftover { blocks, threads, critical_active: true }
    }

    /// [`Miriam::leftover`] through a full [`GpuSnapshot`] — the
    /// pre-change read path (two per-SM `Vec` allocations per carving
    /// decision), kept for the `miriam-ref` bench leg. Same values, same
    /// decisions; only the cost differs.
    fn leftover_from_snapshot(&self, snap: &GpuSnapshot, eng: &Engine)
                              -> Leftover {
        let res = Residency {
            now_us: snap.now_us,
            critical_blocks: snap.critical_blocks,
            critical_block_threads: snap.critical_block_threads,
            critical_pending: snap.critical_pending,
            normal_blocks: snap.normal_blocks,
        };
        self.leftover(&res, eng)
    }

    /// The padding pump: keep each pad stream primed with at most one
    /// outstanding shard; any queued normal task with undispatched work
    /// may be carved (multiple clients pad concurrently).
    fn pump(&mut self, eng: &mut Engine) {
        for si in 0..self.pad_streams.len() {
            let stream = self.pad_streams[si];
            if self.stream_load[stream as usize] > 0 {
                continue;
            }
            // Fresh residency per carving decision: a shard submitted for
            // the previous stream may already be resident, and the next
            // shard must be sized against that reality (late binding).
            // (§Perf change #3 cached this; reverted — neutral wall-clock,
            // stale-leftover semantics. The scalar read costs nothing.)
            let mut left = if self.reference_path {
                // Pre-change read: a full per-SM snapshot per decision.
                let snap = eng.snapshot();
                self.leftover_from_snapshot(&snap, eng)
            } else {
                let res = eng.residency();
                self.leftover(&res, eng)
            };
            let (shard, base, req_id) = {
                // First task with work to dispatch.
                let Some(task) = self
                    .normal_queue
                    .iter_mut()
                    .find(|t| !t.tree.fully_dispatched())
                else {
                    return;
                };
                if self.static_sharding {
                    // Ablation: pin the geometry to the best offline
                    // candidate regardless of what is resident right now.
                    let c = task.tree.first_candidate();
                    left = Leftover {
                        blocks: c.n_blocks,
                        threads: c.block_threads,
                        critical_active: true,
                    };
                }
                let Some(shard) = task.tree.next_shard(&left) else {
                    continue;
                };
                (shard, task.name_ids[task.kernel_idx], task.req_id)
            };
            let tag = if self.reference_path {
                // Pre-change submit: format the shard name every carve and
                // go through the String-keyed `LaunchConfig` path.
                let name =
                    format!("{}#es{}", eng.names().resolve(base), shard.index);
                let cfg = LaunchConfig {
                    name,
                    grid: shard.shape.grid,
                    block_threads: shard.shape.block_threads,
                    smem_per_block: shard.shape.smem_per_block,
                    regs_per_thread: shard.shape.regs_per_thread,
                    flops: shard.shape.flops,
                    bytes: shard.shape.bytes,
                };
                eng.submit(stream, cfg, Criticality::Normal)
            } else {
                let sid = self.shard_name_id(eng, base, shard.index);
                eng.submit_interned(stream, sid, shard.shape,
                                    Criticality::Normal, 0.0)
            };
            self.inflight_shards
                .insert(tag, (stream, shard.shape.grid, req_id));
            self.stream_load[stream as usize] += 1;
        }
    }

    /// Advance a task past a finished kernel (or retire it). Returns the
    /// finished request id when the whole model completed. Arc clones
    /// only — no model, name, or candidate copies.
    fn advance_task(&mut self, eng: &Engine, req_id: u64) -> Option<u64> {
        let pos = self.normal_queue.iter().position(|t| t.req_id == req_id)?;
        if !self.normal_queue[pos].tree.finished() {
            return None;
        }
        let (model, ids, next_idx) = {
            let t = &mut self.normal_queue[pos];
            t.kernel_idx += 1;
            (t.model.clone(), t.name_ids.clone(), t.kernel_idx)
        };
        if next_idx >= model.kernels.len() {
            let done = self.normal_queue.remove(pos).unwrap();
            return Some(done.req_id);
        }
        let ek = self.elastic_for(eng, ids[next_idx], &model, next_idx);
        self.normal_queue[pos].tree = ShadedTree::new(ek);
        None
    }
}

impl Scheduler for Miriam {
    fn name(&self) -> &str {
        if self.reference_path { "miriam-ref" } else { "miriam" }
    }

    fn init(&mut self, eng: &mut Engine) {
        assert!(!self.initialized);
        self.critical_stream = eng.add_stream(10);
        for _ in 0..self.num_pad_streams {
            self.pad_streams.push(eng.add_stream(0));
        }
        self.stream_load = vec![0; eng.num_streams()];
        self.initialized = true;
    }

    fn on_request(&mut self, req: Req, eng: &mut Engine) {
        match req.criticality {
            Criticality::Critical => {
                // Critical kernels run untouched, enqueued immediately —
                // through the interned path, so a critical arrival clones
                // no kernel-name Strings (the per-request cost the paper
                // says must stay cheap).
                let mut last = 0;
                let mut first = None;
                if self.reference_path {
                    for k in &req.model.kernels {
                        last = eng.submit(self.critical_stream,
                                          LaunchConfig::from_kernel(k),
                                          Criticality::Critical);
                        first.get_or_insert(last);
                    }
                } else {
                    for (k, &nid) in
                        req.model.kernels.iter().zip(req.name_ids.iter())
                    {
                        last = eng.submit_interned(
                            self.critical_stream, nid,
                            LaunchShape::from_kernel(k),
                            Criticality::Critical, 0.0);
                        first.get_or_insert(last);
                    }
                }
                self.critical_tasks.push(CriticalTask {
                    req_id: req.id,
                    first_tag: first.unwrap_or(last),
                    last_tag: last,
                });
                // A critical arrival changes the leftover landscape; the
                // next carved shard will see it (already-resident shards
                // are small by construction — the paper's "trivial
                // contention" claim).
            }
            Criticality::Normal => {
                let ek = self.elastic_for(eng, req.name_ids[0], &req.model, 0);
                self.normal_queue.push_back(NormalTask {
                    req_id: req.id,
                    model: req.model,
                    name_ids: req.name_ids,
                    kernel_idx: 0,
                    tree: ShadedTree::new(ek),
                });
            }
        }
        self.pump(eng);
    }

    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine,
                     finished: &mut Vec<u64>) {
        if let Some((stream, grid, req_id)) =
            self.inflight_shards.remove(&comp.tag)
        {
            // A shard of a normal task completed.
            self.stream_load[stream as usize] -= 1;
            if let Some(t) = self
                .normal_queue
                .iter_mut()
                .find(|t| t.req_id == req_id)
            {
                t.tree.shard_done(grid);
            }
            if let Some(done) = self.advance_task(eng, req_id) {
                finished.push(done);
            }
        } else if let Some(pos) = self
            .critical_tasks
            .iter()
            .position(|t| t.last_tag == comp.tag)
        {
            finished.push(self.critical_tasks.swap_remove(pos).req_id);
        }
        // Either way resources were freed: pad.
        self.pump(eng);
    }

    fn pending_normal(&self) -> Option<usize> {
        Some(self.normal_queue.len())
    }

    /// Best-effort cancellation (ISSUE 8 recovery layer). Normal tasks:
    /// remove the task so no further shards are carved, reclaim
    /// still-queued shards from their pad streams; already-active
    /// shards complete into the void ([`Miriam::on_completion`]
    /// tolerates orphan tags by construction). Critical tasks (hedge
    /// losers): sweep the contiguous tag range off the critical stream
    /// — the chain is FIFO on one stream, so if any launch is still
    /// queued the last one is, and removing it guarantees the task
    /// never reports finished. A chain whose last launch already
    /// activated cannot be recalled (no preemption) and declines.
    fn cancel(&mut self, req_id: u64, eng: &mut Engine) -> bool {
        if let Some(pos) =
            self.normal_queue.iter().position(|t| t.req_id == req_id)
        {
            let queued: Vec<(LaunchTag, StreamId)> = self
                .inflight_shards
                .iter()
                .filter(|(_, &(_, _, rid))| rid == req_id)
                .map(|(&tag, &(stream, _, _))| (tag, stream))
                .collect();
            for (tag, stream) in queued {
                if eng.cancel_queued(stream, &[tag]) == 1 {
                    self.inflight_shards.remove(&tag);
                    self.stream_load[stream as usize] -= 1;
                }
            }
            self.normal_queue.remove(pos);
            self.pump(eng);
            return true;
        }
        if let Some(pos) =
            self.critical_tasks.iter().position(|t| t.req_id == req_id)
        {
            let t = &self.critical_tasks[pos];
            let tags: Vec<LaunchTag> = (t.first_tag..=t.last_tag).collect();
            if eng.cancel_queued(self.critical_stream, &tags) > 0 {
                self.critical_tasks.swap_remove(pos);
                self.pump(eng);
                return true;
            }
            return false;
        }
        false
    }

    fn set_brownout(&mut self, on: bool) {
        self.brownout = on;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::driver;
    use crate::gpu::spec::GpuSpec;
    use crate::workloads::mdtb;
    use crate::workloads::models;

    fn miriam_for(wl: &crate::workloads::mdtb::Workload) -> Miriam {
        let crits: Vec<ModelRef> = wl
            .sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .map(|s| s.model.clone())
            .collect();
        Miriam::new(&crits)
    }

    #[test]
    fn completes_tasks_on_mdtb_a() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let mut m = miriam_for(&wl);
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut m);
        assert!(stats.completed_critical() > 0);
        assert!(stats.completed_normal() > 0);
    }

    #[test]
    fn critical_latency_close_to_solo() {
        // Solo critical run (no normal source): baseline latency.
        let wl_solo = crate::workloads::mdtb::Workload {
            name: "solo".into(),
            sources: vec![crate::workloads::mdtb::Source {
                model: Arc::new(models::alexnet()),
                arrival: crate::workloads::Arrival::ClosedLoop { clients: 1 },
                criticality: Criticality::Critical,
                deadline_us: None,
            }],
            duration_us: 100_000.0,
            seed: 1,
        };
        let mut m = Miriam::new(&[Arc::new(models::alexnet())]);
        let solo = driver::run(GpuSpec::rtx2060(), &wl_solo, &mut m);
        let solo_lat = solo.critical_latency_mean_us();

        let wl = mdtb::mdtb_a(100_000.0).build();
        let mut m = miriam_for(&wl);
        let co = driver::run(GpuSpec::rtx2060(), &wl, &mut m);
        let co_lat = co.critical_latency_mean_us();
        // Paper: Miriam keeps critical overhead small (~21-28% on MDTB-A).
        assert!(co_lat < solo_lat * 1.6,
                "critical latency inflated: solo {solo_lat} co {co_lat}");
    }

    #[test]
    fn shards_respect_leftover_under_critical_load() {
        // All normal launches carry the elastic-shard suffix (every normal
        // kernel goes through the shaded tree, never raw geometry).
        let wl = mdtb::mdtb_a(30_000.0).build();
        let mut m = miriam_for(&wl);
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut m);
        assert!(stats
            .timeline
            .iter()
            .filter(|r| r.criticality == Criticality::Normal)
            .all(|r| r.name.contains("#es")));
    }

    #[test]
    fn reference_path_makes_identical_decisions() {
        // The retained pre-change plumbing is a cost model, not a policy
        // change: trajectories must match the fast path exactly.
        let wl = mdtb::mdtb_a(40_000.0).build();
        let mut fast = miriam_for(&wl);
        let mut refp = miriam_for(&wl).with_reference_path(true);
        assert_eq!(refp.name(), "miriam-ref");
        let a = driver::run(GpuSpec::rtx2060(), &wl, &mut fast);
        let b = driver::run(GpuSpec::rtx2060(), &wl, &mut refp);
        assert_eq!(a.events, b.events);
        assert_eq!(a.timeline.len(), b.timeline.len());
        assert_eq!(a.completed_critical(), b.completed_critical());
        assert_eq!(a.completed_normal(), b.completed_normal());
        for (x, y) in a.timeline.iter().zip(&b.timeline) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.tag, y.tag);
            assert!((x.end_us - y.end_us).abs() < 1e-9,
                    "{}: {} vs {}", x.name, x.end_us, y.end_us);
        }
    }

    fn req_for(eng: &mut Engine, model: ModelRef, id: u64,
               criticality: Criticality) -> Req {
        let ids: Vec<u32> = model
            .kernels
            .iter()
            .map(|k| eng.intern_name(&k.name))
            .collect();
        Req {
            id,
            source: 0,
            model,
            name_ids: Arc::new(ids),
            criticality,
            arrival_us: 0.0,
        }
    }

    #[test]
    fn brownout_thins_shards_but_never_critical_geometry() {
        let model: ModelRef = Arc::new(models::alexnet());
        let mut eng = Engine::new(GpuSpec::rtx2060());
        let mut m = Miriam::new(&[model]);
        m.init(&mut eng);
        // No critical resident: brownout halves the leftover budget.
        let res = eng.residency();
        let full = m.leftover(&res, &eng);
        m.set_brownout(true);
        let thin = m.leftover(&res, &eng);
        assert!(!full.critical_active && !thin.critical_active);
        assert_eq!(thin.blocks, full.blocks,
                   "brownout thins threads, not SM coverage");
        assert_eq!(thin.threads, (full.threads / 2).max(32));
        // Critical resident: the already-tightened budget halves again.
        let res = Residency {
            now_us: 0.0,
            critical_blocks: 1,
            critical_block_threads: 256,
            critical_pending: 0,
            normal_blocks: 0,
        };
        m.set_brownout(false);
        let full = m.leftover(&res, &eng);
        m.set_brownout(true);
        let thin = m.leftover(&res, &eng);
        assert_eq!(thin.threads, (full.threads / 2).max(32));
        // Critical launches bypass leftover entirely: geometry in a
        // browned-out run is still the raw kernel shape.
        let model: ModelRef = Arc::new(models::alexnet());
        m.on_request(req_for(&mut eng, model.clone(), 1,
                             Criticality::Critical),
                     &mut eng);
        let res = eng.residency();
        assert!(res.critical_blocks > 0 || res.critical_pending > 0);
        if res.critical_block_threads > 0 {
            assert_eq!(res.critical_block_threads,
                       model.kernels[0].block_threads,
                       "brownout must never thin critical geometry");
        }
        while !eng.idle() {
            for c in eng.step() {
                let mut fin = Vec::new();
                m.on_completion(&c, &mut eng, &mut fin);
            }
        }
    }

    #[test]
    fn cancel_removes_normal_tasks_and_reclaims_queue() {
        let model: ModelRef = Arc::new(models::alexnet());
        let mut eng = Engine::new(GpuSpec::rtx2060());
        let mut m = Miriam::new(&[model.clone()]);
        m.init(&mut eng);
        m.on_request(req_for(&mut eng, model.clone(), 7,
                             Criticality::Normal),
                     &mut eng);
        assert_eq!(m.pending_normal(), Some(1));
        assert!(m.cancel(7, &mut eng), "queued normal task must cancel");
        assert_eq!(m.pending_normal(), Some(0));
        assert!(!m.cancel(7, &mut eng), "double cancel must decline");
        assert!(!m.cancel(999, &mut eng), "unknown id must decline");
        // The orphaned active shards (if any) complete without panicking
        // and without reporting the cancelled request finished.
        let mut fin = Vec::new();
        while !eng.idle() {
            for c in eng.step() {
                m.on_completion(&c, &mut eng, &mut fin);
            }
        }
        assert!(fin.is_empty(), "cancelled request must never finish");
    }

    #[test]
    fn cancel_critical_sweeps_queued_chain_tail() {
        let model: ModelRef = Arc::new(models::alexnet());
        assert!(model.kernels.len() > 1, "test needs a multi-kernel chain");
        let mut eng = Engine::new(GpuSpec::rtx2060());
        let mut m = Miriam::new(&[model.clone()]);
        m.init(&mut eng);
        m.on_request(req_for(&mut eng, model.clone(), 3,
                             Criticality::Critical),
                     &mut eng);
        // Head kernel activated on submit; the rest are still queued, so
        // the chain cancels (the active head completes into the void).
        assert!(m.cancel(3, &mut eng));
        assert!(m.critical_tasks.is_empty());
        let mut fin = Vec::new();
        while !eng.idle() {
            for c in eng.step() {
                m.on_completion(&c, &mut eng, &mut fin);
            }
        }
        assert!(fin.is_empty(), "cancelled critical must never finish");
    }
}
