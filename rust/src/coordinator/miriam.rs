//! The Miriam coordinator (paper §5–§7): critical kernels launch
//! untouched and immediately on a high-priority stream; normal kernels are
//! elasticized offline and padded at runtime as shards carved from a
//! shaded binary tree, sized to the GPU resources the resident critical
//! blocks leave over ("bin-packing", §7).
//!
//! Runtime policy (§7's greedy coordinator):
//! * when critical work is resident, shards are carved *thin*: block
//!   threads bounded to `pad_fill_frac` of the intra-SM leftover (Eq. 2's
//!   "do not exceed too much of the spare intra-SM resources"), so the
//!   foreign-thread interference on critical blocks stays trivial;
//! * when the GPU is free of critical work, the remainder of the kernel
//!   launches at its original geometry ("allocate all available
//!   resources").

use std::collections::{HashMap, VecDeque};

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::coordinator::shaded_tree::{Leftover, ShadedTree};
use crate::elastic::shrink::{CriticalProfile, ShrinkConfig};
use crate::elastic::ElasticKernel;
use crate::gpu::engine::{Completion, Engine, GpuSnapshot};
use crate::gpu::kernel::{Criticality, LaunchConfig};
use crate::gpu::stream::{LaunchTag, StreamId};
use crate::workloads::models::ModelRef;

/// A normal task making its way through its kernels.
struct NormalTask {
    req_id: u64,
    model: ModelRef,
    /// Index of the kernel the tree currently covers.
    kernel_idx: usize,
    tree: ShadedTree,
}

/// A critical task: all kernels submitted at arrival; finished when the
/// last one completes.
struct CriticalTask {
    req_id: u64,
    last_tag: LaunchTag,
}

/// The Miriam scheduler.
pub struct Miriam {
    critical_stream: StreamId,
    /// Padding streams for elastic shards (shards on different streams can
    /// co-run; within one stream they serialize).
    pad_streams: Vec<StreamId>,
    num_pad_streams: usize,
    /// Fraction of the intra-SM thread leftover one elastic block may use
    /// while critical work is resident (the interference bound).
    pad_fill_frac: f64,
    /// Offline-generated elastic candidate sets per kernel name.
    elastic: HashMap<String, ElasticKernel>,
    /// Representative critical launch geometries for the offline shrink.
    crit_profiles: Vec<CriticalProfile>,
    shrink_cfg: ShrinkConfig,
    critical_tasks: Vec<CriticalTask>,
    /// FIFO of normal tasks; any task with undispatched work may be padded
    /// (multiple closed-loop clients keep several in flight).
    normal_queue: VecDeque<NormalTask>,
    /// Outstanding shard tags -> (pad stream, grid blocks, task req id).
    inflight_shards: HashMap<LaunchTag, (StreamId, u32, u64)>,
    /// Shards outstanding per pad stream (bounded to one so carving stays
    /// late-bound — geometry is chosen against the *current* critical
    /// context, the shaded tree's virtual-shard property).
    stream_load: HashMap<StreamId, usize>,
    /// Ablation switch: carve every shard at the top offline candidate's
    /// geometry instead of re-fitting against the live leftover (§7's
    /// "fixed size ... easily become inefficient" failure mode).
    static_sharding: bool,
    initialized: bool,
}

impl Miriam {
    /// `critical_models` are the models the critical queue may carry —
    /// their kernels give the representative [`CriticalProfile`]s the
    /// offline shrink runs against (paper §6.3 profiles the task set
    /// offline).
    pub fn new(critical_models: &[ModelRef]) -> Self {
        let mut profiles: Vec<CriticalProfile> = Vec::new();
        for m in critical_models {
            for k in &m.kernels {
                let p = CriticalProfile::from_kernel(k);
                if !profiles.contains(&p) {
                    profiles.push(p);
                }
            }
        }
        // Cap the profile set: dedupe keeps it small already, but a bound
        // keeps the offline pass O(candidates * profiles) predictable.
        profiles.truncate(32);
        Miriam {
            critical_stream: 0,
            pad_streams: Vec::new(),
            num_pad_streams: 3,
            pad_fill_frac: 0.6,
            elastic: HashMap::new(),
            crit_profiles: profiles,
            shrink_cfg: ShrinkConfig::default(),
            critical_tasks: Vec::new(),
            normal_queue: VecDeque::new(),
            inflight_shards: HashMap::new(),
            stream_load: HashMap::new(),
            static_sharding: false,
            initialized: false,
        }
    }

    /// Builder: override the pad fill fraction (ablation 1).
    pub fn with_fill(mut self, fill: f64) -> Self {
        self.pad_fill_frac = fill;
        self
    }

    /// Builder: use static (offline-fixed) shard geometry (ablation 2).
    pub fn with_static_sharding(mut self, enabled: bool) -> Self {
        self.static_sharding = enabled;
        self
    }

    /// Elastic candidates for a kernel, generated on first use and cached
    /// (the real system does this fully offline; lazy generation keeps the
    /// cache warm across requests of the same model).
    fn elastic_for(&mut self, eng: &Engine, kernel_name: &str,
                   model: &ModelRef, kernel_idx: usize) -> ElasticKernel {
        if let Some(e) = self.elastic.get(kernel_name) {
            return e.clone();
        }
        let k = model.kernels[kernel_idx].clone();
        let e = ElasticKernel::generate(k, &self.crit_profiles, &eng.spec,
                                        &self.shrink_cfg);
        self.elastic.insert(kernel_name.to_string(), e.clone());
        e
    }

    /// Leftover resources for padding, from the engine snapshot (Eq. 2
    /// applied to the *current* residency instead of offline profiles),
    /// with the intra-SM bound tightened by `pad_fill_frac`.
    fn leftover(&self, snap: &GpuSnapshot, eng: &Engine) -> Leftover {
        let spec = &eng.spec;
        let critical_active = snap.critical_blocks > 0 || snap.critical_pending > 0;
        if !critical_active {
            return Leftover {
                blocks: spec.num_sms,
                threads: spec.max_threads_per_sm,
                critical_active: false,
            };
        }
        let resident_wave = snap.critical_blocks % spec.num_sms;
        let blocks = spec.num_sms - resident_wave;
        let crit_threads = if snap.critical_block_threads > 0 {
            snap.critical_block_threads
        } else {
            // Critical launch still in overhead: assume a fat block until
            // it lands (conservative).
            spec.max_threads_per_sm / 2
        };
        let spare = spec.max_threads_per_sm.saturating_sub(crit_threads);
        let threads = ((spare as f64 * self.pad_fill_frac) as u32).max(32);
        Leftover { blocks, threads, critical_active: true }
    }

    /// The padding pump: keep each pad stream primed with at most one
    /// outstanding shard; any queued normal task with undispatched work
    /// may be carved (multiple clients pad concurrently).
    fn pump(&mut self, eng: &mut Engine) {
        for si in 0..self.pad_streams.len() {
            let stream = self.pad_streams[si];
            if self.stream_load.get(&stream).copied().unwrap_or(0) > 0 {
                continue;
            }
            // Fresh snapshot per carving decision: a shard submitted for
            // the previous stream may already be resident, and the next
            // shard must be sized against that reality (late binding).
            // (§Perf change #3 cached this; reverted — neutral wall-clock,
            // stale-leftover semantics.)
            let snap = eng.snapshot();
            let mut left = self.leftover(&snap, eng);
            // First task with work to dispatch.
            let Some(task) = self
                .normal_queue
                .iter_mut()
                .find(|t| !t.tree.fully_dispatched())
            else {
                return;
            };
            if self.static_sharding {
                // Ablation: pin the geometry to the best offline candidate
                // regardless of what is resident right now.
                let c = task.tree.first_candidate();
                left = crate::coordinator::shaded_tree::Leftover {
                    blocks: c.n_blocks,
                    threads: c.block_threads,
                    critical_active: true,
                };
            }
            let Some(shard) = task.tree.next_shard(&left) else { continue };
            let grid = shard.grid;
            let req_id = task.req_id;
            let tag = eng.submit(stream, shard, Criticality::Normal);
            self.inflight_shards.insert(tag, (stream, grid, req_id));
            *self.stream_load.entry(stream).or_insert(0) += 1;
        }
    }

    /// Advance a task past a finished kernel (or retire it). Returns the
    /// finished request id when the whole model completed.
    fn advance_task(&mut self, eng: &Engine, req_id: u64) -> Option<u64> {
        let pos = self.normal_queue.iter().position(|t| t.req_id == req_id)?;
        if !self.normal_queue[pos].tree.finished() {
            return None;
        }
        let (model, next_idx) = {
            let t = &mut self.normal_queue[pos];
            t.kernel_idx += 1;
            (t.model.clone(), t.kernel_idx)
        };
        if next_idx >= model.kernels.len() {
            let done = self.normal_queue.remove(pos).unwrap();
            return Some(done.req_id);
        }
        let name = model.kernels[next_idx].name.clone();
        let ek = self.elastic_for(eng, &name, &model, next_idx);
        self.normal_queue[pos].tree = ShadedTree::new(ek.kernel, ek.candidates);
        None
    }
}

impl Scheduler for Miriam {
    fn name(&self) -> &'static str {
        "miriam"
    }

    fn init(&mut self, eng: &mut Engine) {
        assert!(!self.initialized);
        self.critical_stream = eng.add_stream(10);
        for _ in 0..self.num_pad_streams {
            self.pad_streams.push(eng.add_stream(0));
        }
        self.initialized = true;
    }

    fn on_request(&mut self, req: Req, eng: &mut Engine) {
        match req.criticality {
            Criticality::Critical => {
                // Critical kernels run untouched, enqueued immediately.
                let mut last = 0;
                for k in &req.model.kernels {
                    last = eng.submit(self.critical_stream,
                                      LaunchConfig::from_kernel(k),
                                      Criticality::Critical);
                }
                self.critical_tasks.push(CriticalTask {
                    req_id: req.id,
                    last_tag: last,
                });
                // A critical arrival changes the leftover landscape; the
                // next carved shard will see it (already-resident shards
                // are small by construction — the paper's "trivial
                // contention" claim).
            }
            Criticality::Normal => {
                let model = req.model.clone();
                let name = model.kernels[0].name.clone();
                let ek = self.elastic_for(eng, &name, &model, 0);
                self.normal_queue.push_back(NormalTask {
                    req_id: req.id,
                    model,
                    kernel_idx: 0,
                    tree: ShadedTree::new(ek.kernel, ek.candidates),
                });
            }
        }
        self.pump(eng);
    }

    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine) -> Vec<u64> {
        let mut finished = Vec::new();
        if let Some((stream, grid, req_id)) = self.inflight_shards.remove(&comp.tag) {
            // A shard of a normal task completed.
            *self.stream_load.get_mut(&stream).unwrap() -= 1;
            if let Some(t) = self
                .normal_queue
                .iter_mut()
                .find(|t| t.req_id == req_id)
            {
                t.tree.shard_done(grid);
            }
            if let Some(done) = self.advance_task(eng, req_id) {
                finished.push(done);
            }
        } else if let Some(pos) = self
            .critical_tasks
            .iter()
            .position(|t| t.last_tag == comp.tag)
        {
            finished.push(self.critical_tasks.swap_remove(pos).req_id);
        }
        // Either way resources were freed: pad.
        self.pump(eng);
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::coordinator::driver;
    use crate::gpu::spec::GpuSpec;
    use crate::workloads::mdtb;
    use crate::workloads::models;

    fn miriam_for(wl: &crate::workloads::mdtb::Workload) -> Miriam {
        let crits: Vec<ModelRef> = wl
            .sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .map(|s| s.model.clone())
            .collect();
        Miriam::new(&crits)
    }

    #[test]
    fn completes_tasks_on_mdtb_a() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let mut m = miriam_for(&wl);
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut m);
        assert!(stats.completed_critical() > 0);
        assert!(stats.completed_normal() > 0);
    }

    #[test]
    fn critical_latency_close_to_solo() {
        // Solo critical run (no normal source): baseline latency.
        let wl_solo = crate::workloads::mdtb::Workload {
            name: "solo".into(),
            sources: vec![crate::workloads::mdtb::Source {
                model: Arc::new(models::alexnet()),
                arrival: crate::workloads::Arrival::ClosedLoop { clients: 1 },
                criticality: Criticality::Critical,
                deadline_us: None,
            }],
            duration_us: 100_000.0,
            seed: 1,
        };
        let mut m = Miriam::new(&[Arc::new(models::alexnet())]);
        let solo = driver::run(GpuSpec::rtx2060(), &wl_solo, &mut m);
        let solo_lat = solo.critical_latency_mean_us();

        let wl = mdtb::mdtb_a(100_000.0).build();
        let mut m = miriam_for(&wl);
        let co = driver::run(GpuSpec::rtx2060(), &wl, &mut m);
        let co_lat = co.critical_latency_mean_us();
        // Paper: Miriam keeps critical overhead small (~21-28% on MDTB-A).
        assert!(co_lat < solo_lat * 1.6,
                "critical latency inflated: solo {solo_lat} co {co_lat}");
    }

    #[test]
    fn shards_respect_leftover_under_critical_load() {
        // All normal launches carry the elastic-shard suffix (every normal
        // kernel goes through the shaded tree, never raw geometry).
        let wl = mdtb::mdtb_a(30_000.0).build();
        let mut m = miriam_for(&wl);
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut m);
        assert!(stats
            .timeline
            .iter()
            .filter(|r| r.criticality == Criticality::Normal)
            .all(|r| r.name.contains("#es")));
    }
}
