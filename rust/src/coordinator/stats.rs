//! Per-run statistics: the three paper metrics (§8.1.4) — end-to-end
//! critical-task latency, overall throughput, achieved occupancy — plus
//! timelines and scheduling-overhead counters.

use std::collections::HashMap;

use crate::gpu::metrics::LaunchRecord;
use crate::gpu::trace::Trace;

/// Outcome of one simulated run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Scheduler the run used.
    pub scheduler: String,
    /// Workload name.
    pub workload: String,
    /// GPU preset name.
    pub platform: String,
    /// End-to-end latency (us) of each completed critical task.
    pub critical_latencies_us: Vec<f64>,
    /// End-to-end latency (us) of each completed normal task.
    pub normal_latencies_us: Vec<f64>,
    /// Wall-clock span of the simulation (us).
    pub span_us: f64,
    /// Average achieved occupancy over active SM time, [0, 1].
    pub achieved_occupancy: f64,
    /// Achieved occupancy attributed per kernel name (Fig. 9).
    pub per_name_occupancy: HashMap<String, f64>,
    /// Full launch timeline (Fig. 9 upper).
    pub timeline: Vec<LaunchRecord>,
    /// Simulator events processed (perf counter).
    pub events: u64,
    /// Host wall-clock time of the whole run (ns) — denominator of the
    /// events/sec engine-throughput metric (EXPERIMENTS.md §Perf).
    pub wall_ns: u64,
    /// Wall time the scheduler spent making decisions (ns) — the §8.6
    /// scheduling-overhead metric, measured on the host.
    pub sched_decision_ns: u64,
    /// Number of scheduler decisions taken.
    pub sched_decisions: u64,
    /// Completed critical tasks that exceeded their source's deadline
    /// (only sources with `deadline_us` set are scored).
    pub deadline_misses_critical: u64,
    /// Completed normal tasks that exceeded their source's deadline.
    pub deadline_misses_normal: u64,
    /// Full engine event trace, when `RunOpts::trace` was set.
    pub trace: Option<Trace>,
}

/// Quantile of a sorted sample. Pinned semantics (ISSUE 2 satellite):
///
/// * linear interpolation between closest order statistics (Hyndman–Fan
///   type 7, the numpy/R default) — so the p99 of n < 100 samples
///   interpolates between the two largest values rather than simply
///   returning the maximum;
/// * a single sample is every quantile of itself;
/// * an empty sample has no quantiles: NaN, never a panic (callers of
///   `critical_latency_p99_us` on a run with zero completions rely on
///   this);
/// * `q` is clamped into [0, 1], so an out-of-range request degrades to
///   min/max instead of indexing out of bounds.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

impl RunStats {
    /// Completed critical tasks.
    pub fn completed_critical(&self) -> usize {
        self.critical_latencies_us.len()
    }

    /// Completed normal tasks.
    pub fn completed_normal(&self) -> usize {
        self.normal_latencies_us.len()
    }

    /// Overall throughput in requests/second (critical + normal, §8.1.4).
    pub fn throughput_rps(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        (self.completed_critical() + self.completed_normal()) as f64
            / (self.span_us / 1e6)
    }

    /// Mean critical-task latency (us; NaN when none completed).
    pub fn critical_latency_mean_us(&self) -> f64 {
        mean(&self.critical_latencies_us)
    }

    /// p99 critical-task latency (us; NaN when none completed).
    pub fn critical_latency_p99_us(&self) -> f64 {
        self.critical_latency_quantile_us(0.99)
    }

    /// Critical-task latency quantile (Hyndman–Fan type 7 semantics).
    pub fn critical_latency_quantile_us(&self, q: f64) -> f64 {
        sorted_quantile(&self.critical_latencies_us, q)
    }

    /// Mean normal-task latency (us; NaN when none completed).
    pub fn normal_latency_mean_us(&self) -> f64 {
        mean(&self.normal_latencies_us)
    }

    /// Normal-task latency quantile (HF-7 semantics).
    pub fn normal_latency_quantile_us(&self, q: f64) -> f64 {
        sorted_quantile(&self.normal_latencies_us, q)
    }

    /// Fraction of completed critical tasks that missed their deadline
    /// (0.0 when nothing completed or no deadline was set).
    pub fn critical_deadline_miss_rate(&self) -> f64 {
        if self.completed_critical() == 0 {
            return 0.0;
        }
        self.deadline_misses_critical as f64 / self.completed_critical() as f64
    }

    /// Mean scheduler decision time in microseconds (§8.6).
    pub fn sched_decision_mean_us(&self) -> f64 {
        if self.sched_decisions == 0 {
            return 0.0;
        }
        self.sched_decision_ns as f64 / self.sched_decisions as f64 / 1e3
    }

    /// Simulator events processed per host wall-clock second.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Simulated-time-to-wall-time ratio (how much faster than real time
    /// the substrate runs — the ROADMAP's "as fast as the hardware
    /// allows" tracking number).
    pub fn sim_speedup(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        (self.span_us * 1e3) / self.wall_ns as f64
    }
}

/// Arithmetic mean; NaN on an empty sample. Shared with the online
/// serving loop's per-tenant accounting, like [`sorted_quantile`].
pub(crate) fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        f64::NAN
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// [`quantile`] over an unsorted sample (sorts a copy). Shared with the
/// online serving loop's per-tenant outcome accounting
/// (`crate::server::online`), so "p99" means the same thing in
/// `BENCH_serve.json` as it does in `BENCH_sweep.json`.
///
/// NaN-safe (ISSUE 7 bugfix): sorts with [`f64::total_cmp`] instead of
/// the old `partial_cmp(..).unwrap()`, which panicked on any NaN sample.
/// NaN placement: `total_cmp` orders NaN after +∞, so a NaN sample lands
/// at the top of the sort and only perturbs the quantiles that would
/// read it (high `q`) — a NaN-poisoned report stays a report, it is
/// never a panic.
pub(crate) fn sorted_quantile(v: &[f64], q: f64) -> f64 {
    let mut v = v.to_vec();
    v.sort_by(f64::total_cmp);
    quantile(&v, q)
}

/// [`quantile`] over the concatenation of several unsorted samples —
/// the class-level and fleet-level view over per-tenant (and, for the
/// fleet, per-device) latency vectors, identical in semantics to calling
/// [`sorted_quantile`] on a pre-merged vector (including its
/// NaN-sorts-last placement). Shared by `crate::server::online` and
/// `crate::fleet::report`.
pub(crate) fn merged_quantile<'a, I>(parts: I, q: f64) -> f64
where
    I: IntoIterator<Item = &'a [f64]>,
{
    let mut v: Vec<f64> =
        parts.into_iter().flat_map(|s| s.iter().copied()).collect();
    v.sort_by(f64::total_cmp);
    quantile(&v, q)
}

/// Deterministic constant-memory streaming quantile estimator: the
/// classic P² (piecewise-parabolic) five-marker algorithm of Jain &
/// Chlamtac (ISSUE 7). No RNG, no buffers — five marker heights and
/// positions, updated in O(1) per sample, so per-tenant accounting stays
/// constant-memory at 100k-tenant scale.
///
/// Contract (pinned by unit tests here and the property test in
/// `rust/tests/prop_invariants.rs`):
///
/// * **exact for n ≤ 5** — [`value`](Self::value) computes the
///   Hyndman–Fan type 7 quantile of the raw samples, bitwise equal to
///   [`sorted_quantile`];
/// * deterministic: same sample stream ⇒ same estimate, independent of
///   host or thread count (plain f64 arithmetic, no RNG, no time);
/// * estimates stay within the observed sample range, and NaN samples
///   are rejected loudly in every build profile (feeding the sketch NaN
///   is a caller bug; the exact path *reports* NaN instead — see
///   [`sorted_quantile`]);
/// * empty stream ⇒ NaN, matching the exact path.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    /// Target quantile in [0, 1].
    q: f64,
    /// Samples seen.
    n: u64,
    /// Marker heights; the first `n` raw samples until n = 5, then the
    /// five P² markers (min, q/2, q, (1+q)/2, max estimates).
    heights: [f64; 5],
    /// Actual marker positions (1-based sample ranks).
    positions: [f64; 5],
    /// Desired-position increments per sample: [0, q/2, q, (1+q)/2, 1].
    dn: [f64; 5],
}

impl P2Quantile {
    /// A sketch targeting quantile `q` (clamped into [0, 1], like
    /// [`sorted_quantile`]).
    pub fn new(q: f64) -> Self {
        let q = q.clamp(0.0, 1.0);
        P2Quantile {
            q,
            n: 0,
            heights: [0.0; 5],
            positions: [1.0, 2.0, 3.0, 4.0, 5.0],
            dn: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
        }
    }

    /// Feed one sample. O(1), allocation-free.
    ///
    /// # Panics
    ///
    /// On NaN, in every build profile (same contract as the timing
    /// wheel's push: a NaN latency is a simulator bug, not a sample).
    pub fn record(&mut self, x: f64) {
        assert!(!x.is_nan(), "NaN sample fed to P2Quantile");
        if self.n < 5 {
            self.heights[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                self.heights.sort_unstable_by(f64::total_cmp);
            }
            return;
        }
        // Locate the marker cell containing x, extending the extremes.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 1..4 {
                if x >= self.heights[i] {
                    k = i;
                }
            }
            k
        };
        self.n += 1;
        for p in self.positions[k + 1..].iter_mut() {
            *p += 1.0;
        }
        // Nudge the three interior markers toward their desired
        // positions, adjusting heights parabolically (linearly when the
        // parabola would leave the bracket).
        for i in 1..4 {
            let desired = 1.0 + self.dn[i] * (self.n - 1) as f64;
            let d = desired - self.positions[i];
            if (d >= 1.0 && self.positions[i + 1] - self.positions[i] > 1.0)
                || (d <= -1.0
                    && self.positions[i - 1] - self.positions[i] < -1.0)
            {
                let d = if d >= 0.0 { 1.0 } else { -1.0 };
                let hp = self.parabolic(i, d);
                self.heights[i] = if self.heights[i - 1] < hp
                    && hp < self.heights[i + 1]
                {
                    hp
                } else {
                    self.linear(i, d)
                };
                self.positions[i] += d;
            }
        }
    }

    /// Piecewise-parabolic height prediction for marker `i` moved by `d`.
    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (h, p) = (&self.heights, &self.positions);
        h[i]
            + d / (p[i + 1] - p[i - 1])
                * ((p[i] - p[i - 1] + d) * (h[i + 1] - h[i])
                    / (p[i + 1] - p[i])
                    + (p[i + 1] - p[i] - d) * (h[i] - h[i - 1])
                        / (p[i] - p[i - 1]))
    }

    /// Linear fallback when the parabolic prediction leaves the bracket.
    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + d * (self.heights[j] - self.heights[i])
                / (self.positions[j] - self.positions[i])
    }

    /// Current estimate: NaN for an empty stream, the exact HF-7
    /// quantile for n ≤ 5, the middle P² marker after that.
    pub fn value(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        if self.n <= 5 {
            let mut v = self.heights;
            let s = &mut v[..self.n as usize];
            s.sort_unstable_by(f64::total_cmp);
            return quantile(s, self.q);
        }
        self.heights[2]
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Constant-memory per-tenant latency summary (ISSUE 7): count, sum,
/// min, max, plus P² sketches for p50 and p99. ~200 bytes per tenant
/// regardless of how many requests it served — the representation behind
/// [`LatencyAccum::Sketch`] on the 100k-tenant scale path.
#[derive(Debug, Clone)]
pub struct StreamingSummary {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    p50: P2Quantile,
    p99: P2Quantile,
}

impl Default for StreamingSummary {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingSummary {
    /// An empty summary.
    pub fn new() -> Self {
        StreamingSummary {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            p50: P2Quantile::new(0.5),
            p99: P2Quantile::new(0.99),
        }
    }

    /// Feed one sample (panics on NaN, like [`P2Quantile::record`]).
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.p50.record(x);
        self.p99.record(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Arithmetic mean (NaN when empty, matching [`mean`]).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest sample (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest sample (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Streaming p50 estimate (exact for ≤ 5 samples; NaN when empty).
    pub fn p50(&self) -> f64 {
        self.p50.value()
    }

    /// Streaming p99 estimate (exact for ≤ 5 samples; NaN when empty).
    pub fn p99(&self) -> f64 {
        self.p99.value()
    }
}

/// Tenant-count threshold above which the scale path
/// ([`LatencyAccum::for_tenants`]) switches per-tenant accounting from
/// exact latency vectors to [`StreamingSummary`] sketches. Sized an
/// order of magnitude above the committed scenario family (≤ 6 tenants),
/// so every existing baseline stays on the exact path, bitwise
/// unchanged.
pub const SKETCH_TENANT_THRESHOLD: usize = 64;

/// Per-tenant latency accounting with a representation chosen by tenant
/// count (ISSUE 7): exact vectors below [`SKETCH_TENANT_THRESHOLD`]
/// (quantiles via [`sorted_quantile`], as everywhere else), constant-
/// memory [`StreamingSummary`] sketches above it.
#[derive(Debug, Clone)]
pub enum LatencyAccum {
    /// Every sample retained; quantiles are exact HF-7.
    Exact(Vec<f64>),
    /// Constant-memory streaming sketch (P²) for huge tenant counts.
    Sketch(StreamingSummary),
}

impl LatencyAccum {
    /// The representation for a scenario with `tenants` tenants.
    pub fn for_tenants(tenants: usize) -> Self {
        if tenants > SKETCH_TENANT_THRESHOLD {
            LatencyAccum::Sketch(StreamingSummary::new())
        } else {
            LatencyAccum::Exact(Vec::new())
        }
    }

    /// True on the sketch representation.
    pub fn is_sketch(&self) -> bool {
        matches!(self, LatencyAccum::Sketch(_))
    }

    /// Feed one sample.
    pub fn record(&mut self, x: f64) {
        match self {
            LatencyAccum::Exact(v) => v.push(x),
            LatencyAccum::Sketch(s) => s.record(x),
        }
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        match self {
            LatencyAccum::Exact(v) => v.len() as u64,
            LatencyAccum::Sketch(s) => s.count(),
        }
    }

    /// Mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        match self {
            LatencyAccum::Exact(v) => mean(v),
            LatencyAccum::Sketch(s) => s.mean(),
        }
    }

    /// p50 (exact or sketched; NaN when empty).
    pub fn p50(&self) -> f64 {
        match self {
            LatencyAccum::Exact(v) => sorted_quantile(v, 0.5),
            LatencyAccum::Sketch(s) => s.p50(),
        }
    }

    /// p99 (exact or sketched; NaN when empty).
    pub fn p99(&self) -> f64 {
        match self {
            LatencyAccum::Exact(v) => sorted_quantile(v, 0.99),
            LatencyAccum::Sketch(s) => s.p99(),
        }
    }

    /// Deterministic memory footprint in bytes (struct + retained
    /// samples). The `bytes_per_tenant` metric of `BENCH_scale.json`:
    /// constant for the sketch, linear in served samples for the exact
    /// path — capacity-independent so the number is reproducible.
    pub fn bytes(&self) -> usize {
        let own = std::mem::size_of::<Self>();
        match self {
            LatencyAccum::Exact(v) => {
                own + v.len() * std::mem::size_of::<f64>()
            }
            LatencyAccum::Sketch(_) => own,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_both_classes() {
        let s = RunStats {
            critical_latencies_us: vec![1.0; 10],
            normal_latencies_us: vec![1.0; 30],
            span_us: 2e6,
            ..Default::default()
        };
        assert!((s.throughput_rps() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_interpolate() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&v, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_latencies_are_nan_not_panic() {
        let s = RunStats::default();
        assert!(s.critical_latency_mean_us().is_nan());
        assert!(s.critical_latency_p99_us().is_nan());
        assert!(s.normal_latency_quantile_us(0.5).is_nan());
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.critical_deadline_miss_rate(), 0.0);
        assert!(s.trace.is_none());
    }

    #[test]
    fn p99_of_small_samples_interpolates_between_top_order_stats() {
        // Pinned semantics (Hyndman–Fan type 7): with n=2, p99 sits at
        // pos 0.99 -> 0.01*v[0] + 0.99*v[1].
        let s = RunStats {
            critical_latencies_us: vec![2.0, 1.0],
            ..Default::default()
        };
        assert!((s.critical_latency_p99_us() - 1.99).abs() < 1e-12);
        // n=10: pos = 0.99 * 9 = 8.91 between v[8] and v[9].
        let s = RunStats {
            critical_latencies_us: (1..=10).map(f64::from).collect(),
            ..Default::default()
        };
        let want = 9.0 * 0.09 + 10.0 * 0.91;
        assert!((s.critical_latency_p99_us() - want).abs() < 1e-9);
    }

    #[test]
    fn single_sample_is_every_quantile_of_itself() {
        let v = [7.5];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert!((quantile(&v, q) - 7.5).abs() < 1e-12, "q={q}");
        }
    }

    #[test]
    fn out_of_range_quantiles_clamp_to_min_max() {
        let v = [1.0, 2.0, 3.0];
        assert!((quantile(&v, -0.5) - 1.0).abs() < 1e-12);
        assert!((quantile(&v, 1.5) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn exactly_100_samples_p99_lands_on_interpolated_99th() {
        let v: Vec<f64> = (1..=100).map(f64::from).collect();
        // pos = 0.99 * 99 = 98.01 -> between v[98]=99 and v[99]=100.
        let want = 99.0 * 0.99 + 100.0 * 0.01;
        assert!((quantile(&v, 0.99) - want).abs() < 1e-9);
    }

    #[test]
    fn merged_quantile_equals_quantile_of_concatenation() {
        let a = [3.0, 1.0];
        let b: [f64; 0] = [];
        let c = [2.0, 5.0, 4.0];
        let parts: Vec<&[f64]> = vec![&a, &b, &c];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            let merged = merged_quantile(parts.iter().copied(), q);
            let flat = sorted_quantile(&[3.0, 1.0, 2.0, 5.0, 4.0], q);
            assert!((merged - flat).abs() < 1e-12, "q={q}");
        }
        assert!(merged_quantile(std::iter::empty::<&[f64]>(), 0.5).is_nan());
        assert!(merged_quantile(vec![&b as &[f64]], 0.5).is_nan());
    }

    #[test]
    fn deadline_miss_rate() {
        let s = RunStats {
            critical_latencies_us: vec![1.0; 8],
            deadline_misses_critical: 2,
            ..Default::default()
        };
        assert!((s.critical_deadline_miss_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn events_per_sec_and_speedup() {
        let s = RunStats {
            events: 1_000_000,
            span_us: 2_000_000.0,
            wall_ns: 500_000_000, // 0.5s wall
            ..Default::default()
        };
        assert!((s.events_per_sec() - 2_000_000.0).abs() < 1e-6);
        assert!((s.sim_speedup() - 4.0).abs() < 1e-9);
        let z = RunStats::default();
        assert_eq!(z.events_per_sec(), 0.0);
        assert_eq!(z.sim_speedup(), 0.0);
    }

    #[test]
    fn nan_sample_reports_instead_of_panicking() {
        // ISSUE 7 bugfix: the old partial_cmp(..).unwrap() sort panicked
        // on any NaN latency. total_cmp sorts NaN after +inf, so low
        // quantiles still read the finite samples and high quantiles
        // report NaN — a report, never a panic.
        let v = [3.0, f64::NAN, 1.0, 2.0];
        assert!((sorted_quantile(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!(sorted_quantile(&v, 1.0).is_nan());
        let a = [f64::NAN, 5.0];
        let b = [4.0];
        let merged =
            merged_quantile(vec![&a as &[f64], &b as &[f64]], 0.0);
        assert!((merged - 4.0).abs() < 1e-12);
        assert!(merged_quantile(vec![&a as &[f64]], 1.0).is_nan());
    }

    #[test]
    fn zero_wall_ratios_are_finite_and_json_clean() {
        use crate::runtime::json::Json;
        // ISSUE 7 satellite: an instantaneous run (wall_ns == 0) must
        // not leak inf/nan into canonical JSON. The accessors guard the
        // division, and the JSON layer maps any residual non-finite
        // number to null — pinned end to end here.
        let z = RunStats {
            events: 10,
            span_us: 100.0,
            ..Default::default()
        };
        assert_eq!(z.wall_ns, 0);
        assert_eq!(z.events_per_sec(), 0.0);
        assert_eq!(z.sim_speedup(), 0.0);
        let doc = Json::Obj(vec![
            ("events_per_sec".into(), Json::Num(z.events_per_sec())),
            ("sim_speedup".into(), Json::Num(z.sim_speedup())),
            ("p99_us".into(), Json::Num(z.critical_latency_p99_us())),
            ("raw_ratio".into(),
             Json::Num(z.events as f64 / z.wall_ns as f64)),
        ]);
        let s = doc.to_canonical_string();
        assert!(!s.contains("inf") && !s.contains("nan"),
                "canonical JSON leaked a non-finite number: {s}");
    }

    #[test]
    fn sketch_is_exact_up_to_five_samples() {
        let samples = [9.0, 2.0, 7.0, 4.0, 1.0];
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let mut sk = P2Quantile::new(q);
            assert!(sk.value().is_nan());
            for (i, &x) in samples.iter().enumerate() {
                sk.record(x);
                let exact = sorted_quantile(&samples[..=i], q);
                assert_eq!(sk.value().to_bits(), exact.to_bits(),
                           "q={q} n={}", i + 1);
            }
        }
    }

    #[test]
    fn sketch_tracks_quantiles_of_a_uniform_ramp() {
        // 10k distinct samples 1..=10000 fed in a scrambled but
        // deterministic order; exact p50 = 5000.5, p99 = 9900.01.
        let mut p50 = P2Quantile::new(0.5);
        let mut p99 = P2Quantile::new(0.99);
        for i in 0u64..10_000 {
            // Stride permutation: 7919 is coprime with 10000, so this
            // visits every value in 1..=10000 exactly once.
            let x = (i * 7919) % 10_000 + 1;
            p50.record(x as f64);
            p99.record(x as f64);
        }
        assert_eq!(p50.count(), 10_000);
        let v50 = p50.value();
        let v99 = p99.value();
        assert!((v50 - 5_000.0).abs() / 5_000.0 < 0.05,
                "p50 estimate {v50} too far from ~5000");
        assert!((v99 - 9_900.0).abs() / 9_900.0 < 0.05,
                "p99 estimate {v99} too far from ~9900");
        assert!(v50 <= v99);
    }

    #[test]
    #[should_panic(expected = "NaN sample")]
    fn sketch_rejects_nan_loudly() {
        P2Quantile::new(0.5).record(f64::NAN);
    }

    #[test]
    fn streaming_summary_basics() {
        let mut s = StreamingSummary::new();
        assert!(s.mean().is_nan() && s.min().is_nan() && s.max().is_nan());
        assert!(s.p50().is_nan() && s.p99().is_nan());
        for x in [4.0, 1.0, 3.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 8.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        // n <= 5: exact HF-7, bitwise.
        assert_eq!(s.p50().to_bits(),
                   sorted_quantile(&[4.0, 1.0, 3.0], 0.5).to_bits());
    }

    #[test]
    fn latency_accum_switches_representation_at_threshold() {
        assert!(!LatencyAccum::for_tenants(SKETCH_TENANT_THRESHOLD)
            .is_sketch());
        assert!(LatencyAccum::for_tenants(SKETCH_TENANT_THRESHOLD + 1)
            .is_sketch());
        // The committed scenario family (<= 6 tenants) stays exact.
        assert!(!LatencyAccum::for_tenants(6).is_sketch());

        let mut exact = LatencyAccum::for_tenants(2);
        let mut sketch = LatencyAccum::for_tenants(100_000);
        for x in [5.0, 2.0, 9.0] {
            exact.record(x);
            sketch.record(x);
        }
        assert_eq!(exact.count(), 3);
        assert_eq!(sketch.count(), 3);
        // Both exact at tiny n.
        assert_eq!(exact.p99().to_bits(), sketch.p99().to_bits());
        assert!((exact.mean() - sketch.mean()).abs() < 1e-12);
        // Sketch footprint is constant; exact grows with samples.
        let sk_bytes = sketch.bytes();
        for x in 0..1000 {
            sketch.record(x as f64);
            exact.record(x as f64);
        }
        assert_eq!(sketch.bytes(), sk_bytes);
        assert!(exact.bytes() > sk_bytes);
    }

    #[test]
    fn decision_overhead_mean() {
        let s = RunStats {
            sched_decision_ns: 3_000_000,
            sched_decisions: 1000,
            ..Default::default()
        };
        assert!((s.sched_decision_mean_us() - 3.0).abs() < 1e-9);
    }
}
