//! Per-run statistics: the three paper metrics (§8.1.4) — end-to-end
//! critical-task latency, overall throughput, achieved occupancy — plus
//! timelines and scheduling-overhead counters.

use std::collections::HashMap;

use crate::gpu::metrics::LaunchRecord;
use crate::gpu::trace::Trace;

/// Outcome of one simulated run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Scheduler the run used.
    pub scheduler: String,
    /// Workload name.
    pub workload: String,
    /// GPU preset name.
    pub platform: String,
    /// End-to-end latency (us) of each completed critical task.
    pub critical_latencies_us: Vec<f64>,
    /// End-to-end latency (us) of each completed normal task.
    pub normal_latencies_us: Vec<f64>,
    /// Wall-clock span of the simulation (us).
    pub span_us: f64,
    /// Average achieved occupancy over active SM time, [0, 1].
    pub achieved_occupancy: f64,
    /// Achieved occupancy attributed per kernel name (Fig. 9).
    pub per_name_occupancy: HashMap<String, f64>,
    /// Full launch timeline (Fig. 9 upper).
    pub timeline: Vec<LaunchRecord>,
    /// Simulator events processed (perf counter).
    pub events: u64,
    /// Host wall-clock time of the whole run (ns) — denominator of the
    /// events/sec engine-throughput metric (EXPERIMENTS.md §Perf).
    pub wall_ns: u64,
    /// Wall time the scheduler spent making decisions (ns) — the §8.6
    /// scheduling-overhead metric, measured on the host.
    pub sched_decision_ns: u64,
    /// Number of scheduler decisions taken.
    pub sched_decisions: u64,
    /// Completed critical tasks that exceeded their source's deadline
    /// (only sources with `deadline_us` set are scored).
    pub deadline_misses_critical: u64,
    /// Completed normal tasks that exceeded their source's deadline.
    pub deadline_misses_normal: u64,
    /// Full engine event trace, when `RunOpts::trace` was set.
    pub trace: Option<Trace>,
}

/// Quantile of a sorted sample. Pinned semantics (ISSUE 2 satellite):
///
/// * linear interpolation between closest order statistics (Hyndman–Fan
///   type 7, the numpy/R default) — so the p99 of n < 100 samples
///   interpolates between the two largest values rather than simply
///   returning the maximum;
/// * a single sample is every quantile of itself;
/// * an empty sample has no quantiles: NaN, never a panic (callers of
///   `critical_latency_p99_us` on a run with zero completions rely on
///   this);
/// * `q` is clamped into [0, 1], so an out-of-range request degrades to
///   min/max instead of indexing out of bounds.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

impl RunStats {
    /// Completed critical tasks.
    pub fn completed_critical(&self) -> usize {
        self.critical_latencies_us.len()
    }

    /// Completed normal tasks.
    pub fn completed_normal(&self) -> usize {
        self.normal_latencies_us.len()
    }

    /// Overall throughput in requests/second (critical + normal, §8.1.4).
    pub fn throughput_rps(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        (self.completed_critical() + self.completed_normal()) as f64
            / (self.span_us / 1e6)
    }

    /// Mean critical-task latency (us; NaN when none completed).
    pub fn critical_latency_mean_us(&self) -> f64 {
        mean(&self.critical_latencies_us)
    }

    /// p99 critical-task latency (us; NaN when none completed).
    pub fn critical_latency_p99_us(&self) -> f64 {
        self.critical_latency_quantile_us(0.99)
    }

    /// Critical-task latency quantile (Hyndman–Fan type 7 semantics).
    pub fn critical_latency_quantile_us(&self, q: f64) -> f64 {
        sorted_quantile(&self.critical_latencies_us, q)
    }

    /// Mean normal-task latency (us; NaN when none completed).
    pub fn normal_latency_mean_us(&self) -> f64 {
        mean(&self.normal_latencies_us)
    }

    /// Normal-task latency quantile (HF-7 semantics).
    pub fn normal_latency_quantile_us(&self, q: f64) -> f64 {
        sorted_quantile(&self.normal_latencies_us, q)
    }

    /// Fraction of completed critical tasks that missed their deadline
    /// (0.0 when nothing completed or no deadline was set).
    pub fn critical_deadline_miss_rate(&self) -> f64 {
        if self.completed_critical() == 0 {
            return 0.0;
        }
        self.deadline_misses_critical as f64 / self.completed_critical() as f64
    }

    /// Mean scheduler decision time in microseconds (§8.6).
    pub fn sched_decision_mean_us(&self) -> f64 {
        if self.sched_decisions == 0 {
            return 0.0;
        }
        self.sched_decision_ns as f64 / self.sched_decisions as f64 / 1e3
    }

    /// Simulator events processed per host wall-clock second.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Simulated-time-to-wall-time ratio (how much faster than real time
    /// the substrate runs — the ROADMAP's "as fast as the hardware
    /// allows" tracking number).
    pub fn sim_speedup(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        (self.span_us * 1e3) / self.wall_ns as f64
    }
}

/// Arithmetic mean; NaN on an empty sample. Shared with the online
/// serving loop's per-tenant accounting, like [`sorted_quantile`].
pub(crate) fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        f64::NAN
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// [`quantile`] over an unsorted sample (sorts a copy). Shared with the
/// online serving loop's per-tenant outcome accounting
/// (`crate::server::online`), so "p99" means the same thing in
/// `BENCH_serve.json` as it does in `BENCH_sweep.json`.
pub(crate) fn sorted_quantile(v: &[f64], q: f64) -> f64 {
    let mut v = v.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile(&v, q)
}

/// [`quantile`] over the concatenation of several unsorted samples —
/// the class-level and fleet-level view over per-tenant (and, for the
/// fleet, per-device) latency vectors, identical in semantics to calling
/// [`sorted_quantile`] on a pre-merged vector. Shared by
/// `crate::server::online` and `crate::fleet::report`.
pub(crate) fn merged_quantile<'a, I>(parts: I, q: f64) -> f64
where
    I: IntoIterator<Item = &'a [f64]>,
{
    let mut v: Vec<f64> =
        parts.into_iter().flat_map(|s| s.iter().copied()).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile(&v, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_both_classes() {
        let s = RunStats {
            critical_latencies_us: vec![1.0; 10],
            normal_latencies_us: vec![1.0; 30],
            span_us: 2e6,
            ..Default::default()
        };
        assert!((s.throughput_rps() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_interpolate() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&v, 1.0) - 4.0).abs() < 1e-12);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_latencies_are_nan_not_panic() {
        let s = RunStats::default();
        assert!(s.critical_latency_mean_us().is_nan());
        assert!(s.critical_latency_p99_us().is_nan());
        assert!(s.normal_latency_quantile_us(0.5).is_nan());
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.critical_deadline_miss_rate(), 0.0);
        assert!(s.trace.is_none());
    }

    #[test]
    fn p99_of_small_samples_interpolates_between_top_order_stats() {
        // Pinned semantics (Hyndman–Fan type 7): with n=2, p99 sits at
        // pos 0.99 -> 0.01*v[0] + 0.99*v[1].
        let s = RunStats {
            critical_latencies_us: vec![2.0, 1.0],
            ..Default::default()
        };
        assert!((s.critical_latency_p99_us() - 1.99).abs() < 1e-12);
        // n=10: pos = 0.99 * 9 = 8.91 between v[8] and v[9].
        let s = RunStats {
            critical_latencies_us: (1..=10).map(f64::from).collect(),
            ..Default::default()
        };
        let want = 9.0 * 0.09 + 10.0 * 0.91;
        assert!((s.critical_latency_p99_us() - want).abs() < 1e-9);
    }

    #[test]
    fn single_sample_is_every_quantile_of_itself() {
        let v = [7.5];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert!((quantile(&v, q) - 7.5).abs() < 1e-12, "q={q}");
        }
    }

    #[test]
    fn out_of_range_quantiles_clamp_to_min_max() {
        let v = [1.0, 2.0, 3.0];
        assert!((quantile(&v, -0.5) - 1.0).abs() < 1e-12);
        assert!((quantile(&v, 1.5) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn exactly_100_samples_p99_lands_on_interpolated_99th() {
        let v: Vec<f64> = (1..=100).map(f64::from).collect();
        // pos = 0.99 * 99 = 98.01 -> between v[98]=99 and v[99]=100.
        let want = 99.0 * 0.99 + 100.0 * 0.01;
        assert!((quantile(&v, 0.99) - want).abs() < 1e-9);
    }

    #[test]
    fn merged_quantile_equals_quantile_of_concatenation() {
        let a = [3.0, 1.0];
        let b: [f64; 0] = [];
        let c = [2.0, 5.0, 4.0];
        let parts: Vec<&[f64]> = vec![&a, &b, &c];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            let merged = merged_quantile(parts.iter().copied(), q);
            let flat = sorted_quantile(&[3.0, 1.0, 2.0, 5.0, 4.0], q);
            assert!((merged - flat).abs() < 1e-12, "q={q}");
        }
        assert!(merged_quantile(std::iter::empty::<&[f64]>(), 0.5).is_nan());
        assert!(merged_quantile(vec![&b as &[f64]], 0.5).is_nan());
    }

    #[test]
    fn deadline_miss_rate() {
        let s = RunStats {
            critical_latencies_us: vec![1.0; 8],
            deadline_misses_critical: 2,
            ..Default::default()
        };
        assert!((s.critical_deadline_miss_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn events_per_sec_and_speedup() {
        let s = RunStats {
            events: 1_000_000,
            span_us: 2_000_000.0,
            wall_ns: 500_000_000, // 0.5s wall
            ..Default::default()
        };
        assert!((s.events_per_sec() - 2_000_000.0).abs() < 1e-6);
        assert!((s.sim_speedup() - 4.0).abs() < 1e-9);
        let z = RunStats::default();
        assert_eq!(z.events_per_sec(), 0.0);
        assert_eq!(z.sim_speedup(), 0.0);
    }

    #[test]
    fn decision_overhead_mean() {
        let s = RunStats {
            sched_decision_ns: 3_000_000,
            sched_decisions: 1000,
            ..Default::default()
        };
        assert!((s.sched_decision_mean_us() - 3.0).abs() < 1e-9);
    }
}
