//! Online admission control for the serving pipeline (ISSUE 4 tentpole).
//!
//! The batch driver ([`crate::coordinator::driver`]) admits every arrival
//! unconditionally — fine for closed evaluation runs, wrong for the
//! deployment regime the ROADMAP targets, where bursty tenants can bury
//! the GPU far past any deadline (the DeepRT / EdgeServing observation).
//! This module decides, *at arrival time and in simulated time*, whether
//! a request enters the live coordinator or is shed:
//!
//! * [`AdmissionPolicy::Open`] (`none`) — admit everything; the
//!   no-admission baseline every comparison is made against.
//! * [`AdmissionPolicy::TokenBucket`] (`token-bucket`) — classic
//!   per-tenant rate limiting: each tenant holds a bucket of
//!   [`AdmissionConfig::bucket_capacity`] tokens refilled at
//!   [`AdmissionConfig::refill_hz`]; a best-effort request is shed when
//!   its tenant's bucket is empty.
//! * [`AdmissionPolicy::DeadlineFeasible`] (`deadline-feasible`) —
//!   model-aware control built on **elastic-kernel latency envelopes**
//!   ([`ModelEnvelope`]): a best-effort request is shed when the
//!   estimated backlog already exceeds [`AdmissionConfig::max_queue_us`]
//!   (load shedding under burst), or when even the queue-drain estimate
//!   plus the request's own padded envelope cannot meet its deadline.
//!
//! **Critical requests are never shed, under any policy** — the whole
//! point of Miriam is that critical work owns the high-priority stream;
//! admission control exists to protect it by trimming *best-effort*
//! load. `rust/tests/prop_invariants.rs` pins this invariant together
//! with token conservation and shed + admitted == offered accounting.
//!
//! Every decision is pure arithmetic over simulated time, so a serving
//! run is byte-deterministic per seed (`rust/tests/serve_determinism.rs`).
//!
//! ```
//! use miriam::coordinator::admission::{
//!     AdmissionConfig, AdmissionController, AdmissionPolicy, Decision,
//! };
//! use miriam::gpu::contention::ContentionParams;
//! use miriam::gpu::spec::GpuSpec;
//! use miriam::workloads::mdtb;
//!
//! let wl = mdtb::mdtb_a(10_000.0).build();
//! let mut ctrl = AdmissionController::new(
//!     AdmissionPolicy::TokenBucket,
//!     AdmissionConfig::default(),
//!     &wl,
//!     &GpuSpec::rtx2060(),
//!     &ContentionParams::default(),
//! );
//! // Source 0 is MDTB-A's critical tenant: admitted under any policy.
//! assert_eq!(ctrl.decide(0, 0.0), Decision::Admitted);
//! ```

use crate::gpu::contention::{standalone_demand, ContentionParams};
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::workloads::mdtb::Workload;
use crate::workloads::models::ModelDesc;

/// Smallest elastic block the coordinator will carve
/// (`Miriam::leftover` floors pad blocks at 32 threads); the padded
/// envelope assumes every shard degrades to this size.
const ELASTIC_MIN_THREADS: u32 = 32;

/// The admission policy applied to best-effort arrivals
/// (CLI: `miriam serve-sim --policy <none|token-bucket|deadline-feasible>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything (the no-admission baseline; CLI name `none`).
    Open,
    /// Per-tenant token buckets (CLI name `token-bucket`).
    TokenBucket,
    /// Envelope-based deadline feasibility + burst load shedding
    /// (CLI name `deadline-feasible`).
    DeadlineFeasible,
}

/// All policies, in presentation order (baseline first) — the default
/// `serve-sim` / `benches/serve_online.rs` comparison set.
pub const POLICIES: [AdmissionPolicy; 3] = [
    AdmissionPolicy::Open,
    AdmissionPolicy::TokenBucket,
    AdmissionPolicy::DeadlineFeasible,
];

impl AdmissionPolicy {
    /// The CLI / report name of the policy.
    pub fn name(self) -> &'static str {
        match self {
            AdmissionPolicy::Open => "none",
            AdmissionPolicy::TokenBucket => "token-bucket",
            AdmissionPolicy::DeadlineFeasible => "deadline-feasible",
        }
    }

    /// Parse a CLI policy name (case-insensitive).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "open" => Some(AdmissionPolicy::Open),
            "token-bucket" | "token_bucket" => Some(AdmissionPolicy::TokenBucket),
            "deadline-feasible" | "deadline_feasible" => {
                Some(AdmissionPolicy::DeadlineFeasible)
            }
            _ => None,
        }
    }
}

/// Tunables shared by the admission policies. Every field has a CLI flag
/// on `miriam serve-sim` (see `config/cli.rs` usage in `main.rs`).
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Token-bucket capacity per tenant (tokens; buckets start full).
    pub bucket_capacity: f64,
    /// Token refill rate per tenant (tokens per second).
    pub refill_hz: f64,
    /// Deadline-feasible burst guard: best-effort arrivals are shed while
    /// the estimated admitted-but-unserved backlog exceeds this (us).
    pub max_queue_us: f64,
    /// How many ways the best-effort backlog drains concurrently — the
    /// coordinator's pad-stream count (Miriam runs 3 pad streams;
    /// CLI: `--drain-ways`).
    pub drain_ways: f64,
    /// How long a shed *closed-loop* client waits before retrying (us).
    /// Open-loop shed requests are simply lost; a closed-loop client
    /// would otherwise stall forever on its first shed.
    pub shed_backoff_us: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            bucket_capacity: 16.0,
            refill_hz: 40.0,
            max_queue_us: 100_000.0,
            drain_ways: 3.0,
            shed_backoff_us: 2_000.0,
        }
    }
}

/// Why a request was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Token bucket empty (tenant over its sustained rate).
    RateLimited,
    /// Best-effort backlog above [`AdmissionConfig::max_queue_us`].
    Overloaded,
    /// Even the drain estimate plus the request's own padded envelope
    /// cannot meet its deadline.
    Infeasible,
}

/// One admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// The request enters the coordinator.
    Admitted,
    /// The request is dropped before touching the GPU.
    Shed(ShedReason),
}

/// End-to-end latency envelope of one model, derived offline from its
/// kernel descriptors against a [`GpuSpec`] — the same inputs the elastic
/// shrink consumes, so no simulation is needed to estimate feasibility.
#[derive(Debug, Clone, Copy)]
pub struct ModelEnvelope {
    /// Best-case end-to-end latency (us): the model alone on an idle GPU,
    /// every kernel spread over all SMs at its standalone rate, bounded by
    /// SM peak and DRAM bandwidth. A *lower* bound: if even this misses a
    /// deadline, the request is infeasible on this hardware.
    pub solo_us: f64,
    /// Degraded end-to-end latency (us): every kernel carved to
    /// minimum-size elastic shards (32-thread blocks, one per SM) as the
    /// coordinator does under critical load, plus per-shard launch
    /// overhead. An *upper*-flavored estimate of best-effort service time
    /// while critical work is resident.
    pub padded_us: f64,
}

/// Best-case envelope of one kernel: contention-free, every SM available.
fn kernel_solo_us(
    k: &crate::gpu::kernel::KernelDesc,
    spec: &GpuSpec,
    params: &ContentionParams,
) -> f64 {
    let d = standalone_demand(spec, params, k.block_threads);
    // Blocks one SM can host concurrently under its thread/slot budgets.
    let per_sm = (spec.max_threads_per_sm / k.block_threads.max(1))
        .min(spec.max_blocks_per_sm)
        .max(1);
    let concurrent = (per_sm * spec.num_sms).min(k.grid.max(1)) as f64;
    let total_rate =
        (concurrent * d).min(spec.num_sms as f64 * spec.flops_per_sm_us);
    let compute = k.flops / total_rate.max(1e-12);
    let memory = if k.bytes > 0.0 {
        k.bytes / spec.dram_bw_bytes_us
    } else {
        0.0
    };
    spec.kernel_launch_us + compute.max(memory)
}

/// Degraded envelope of one kernel: thin elastic shards under critical
/// residency (one [`ELASTIC_MIN_THREADS`]-thread block per SM), charging
/// launch overhead per shard wave.
fn kernel_padded_us(
    k: &crate::gpu::kernel::KernelDesc,
    spec: &GpuSpec,
    params: &ContentionParams,
) -> f64 {
    let d = standalone_demand(spec, params, ELASTIC_MIN_THREADS);
    let total_rate = spec.num_sms as f64 * d;
    let compute = k.flops / total_rate.max(1e-12);
    let memory = if k.bytes > 0.0 {
        k.bytes / spec.dram_bw_bytes_us
    } else {
        0.0
    };
    let shard_waves = k.grid.div_ceil(spec.num_sms).max(1) as f64;
    shard_waves * spec.kernel_launch_us + compute.max(memory)
}

impl ModelEnvelope {
    /// Compute both envelope bounds for `model` on `spec`.
    pub fn of(model: &ModelDesc, spec: &GpuSpec, params: &ContentionParams)
              -> Self {
        let mut solo = 0.0;
        let mut padded = 0.0;
        for k in &model.kernels {
            let ks = kernel_solo_us(k, spec, params);
            solo += ks;
            // Degraded service can never beat the contention-free bound
            // (a 1-block kernel "spread" as thin shards would otherwise
            // see more SMs than it ever uses).
            padded += kernel_padded_us(k, spec, params).max(ks);
        }
        ModelEnvelope { solo_us: solo, padded_us: padded }
    }
}

/// One [`ModelEnvelope`] per workload source, in source order — the
/// per-device envelope table the admission controller and the fleet
/// routers (`crate::fleet`) both index by source, derived from the same
/// (model, spec) arithmetic so an admission estimate and a routing weight
/// can never disagree about a model's cost on a device.
pub fn model_envelopes(
    workload: &Workload,
    spec: &GpuSpec,
    params: &ContentionParams,
) -> Vec<ModelEnvelope> {
    workload
        .sources
        .iter()
        .map(|s| ModelEnvelope::of(&s.model, spec, params))
        .collect()
}

/// Per-tenant admission state.
#[derive(Debug, Clone)]
struct TenantState {
    criticality: Criticality,
    deadline_us: Option<f64>,
    /// Token-bucket fill; starts at capacity.
    tokens: f64,
    /// Simulated time of the last refill.
    last_refill_us: f64,
}

/// The admission controller: one per serving run, consulted on every
/// arrival before the request reaches the coordinator. All state advances
/// in simulated time, so decisions are deterministic per seed.
#[derive(Debug)]
pub struct AdmissionController {
    policy: AdmissionPolicy,
    cfg: AdmissionConfig,
    tenants: Vec<TenantState>,
    envelopes: Vec<ModelEnvelope>,
    /// Estimated best-effort work admitted but not yet served (us of solo
    /// service time) — the burst-guard signal.
    backlog_us: f64,
    critical_at_risk: u64,
}

impl AdmissionController {
    /// Build a controller for `workload` on `spec`: envelopes are derived
    /// per source model up front; buckets start full.
    pub fn new(
        policy: AdmissionPolicy,
        cfg: AdmissionConfig,
        workload: &Workload,
        spec: &GpuSpec,
        params: &ContentionParams,
    ) -> Self {
        let tenants = workload
            .sources
            .iter()
            .map(|s| TenantState {
                criticality: s.criticality,
                deadline_us: s.deadline_us,
                tokens: cfg.bucket_capacity,
                last_refill_us: 0.0,
            })
            .collect();
        let envelopes = model_envelopes(workload, spec, params);
        AdmissionController {
            policy,
            cfg,
            tenants,
            envelopes,
            backlog_us: 0.0,
            critical_at_risk: 0,
        }
    }

    /// The policy this controller applies.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// The latency envelope of `source`'s model.
    pub fn envelope(&self, source: usize) -> &ModelEnvelope {
        &self.envelopes[source]
    }

    /// Estimated admitted-but-unserved best-effort work (us).
    pub fn backlog_us(&self) -> f64 {
        self.backlog_us
    }

    /// Critical arrivals whose own deadline was already infeasible by the
    /// solo envelope (admitted anyway — critical is never shed — but
    /// worth surfacing: the deadline, not the scheduler, is the problem).
    pub fn critical_at_risk(&self) -> u64 {
        self.critical_at_risk
    }

    /// Decide whether the arrival from `source` at simulated time
    /// `now_us` enters the coordinator. Critical sources are always
    /// admitted; best-effort sources go through the configured policy.
    pub fn decide(&mut self, source: usize, now_us: f64) -> Decision {
        let env = self.envelopes[source];
        let t = &mut self.tenants[source];
        if t.criticality == Criticality::Critical {
            // Counted under every policy (the quantity is a property of
            // the deadline vs the hardware, not of the admission policy),
            // so the field compares cleanly across BENCH_serve.json cells.
            if let Some(d) = t.deadline_us {
                if env.solo_us > d {
                    self.critical_at_risk += 1;
                }
            }
            return Decision::Admitted;
        }
        match self.policy {
            AdmissionPolicy::Open => {
                self.backlog_us += env.solo_us;
                Decision::Admitted
            }
            AdmissionPolicy::TokenBucket => {
                let dt = (now_us - t.last_refill_us).max(0.0);
                t.tokens = (t.tokens + dt * self.cfg.refill_hz / 1e6)
                    .min(self.cfg.bucket_capacity);
                t.last_refill_us = now_us;
                if t.tokens >= 1.0 {
                    t.tokens -= 1.0;
                    self.backlog_us += env.solo_us;
                    Decision::Admitted
                } else {
                    Decision::Shed(ShedReason::RateLimited)
                }
            }
            AdmissionPolicy::DeadlineFeasible => {
                if self.backlog_us > self.cfg.max_queue_us {
                    return Decision::Shed(ShedReason::Overloaded);
                }
                let est = self.backlog_us / self.cfg.drain_ways.max(1.0)
                    + env.padded_us;
                if t.deadline_us.is_some_and(|d| est > d) {
                    return Decision::Shed(ShedReason::Infeasible);
                }
                self.backlog_us += env.solo_us;
                Decision::Admitted
            }
        }
    }

    /// A previously admitted request from `source` finished: release its
    /// backlog contribution (critical requests carry none).
    pub fn on_served(&mut self, source: usize) {
        if self.tenants[source].criticality == Criticality::Normal {
            self.backlog_us =
                (self.backlog_us - self.envelopes[source].solo_us).max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mdtb;

    fn ctrl(policy: AdmissionPolicy, cfg: AdmissionConfig)
            -> AdmissionController {
        let wl = mdtb::mdtb_a(50_000.0).build();
        AdmissionController::new(policy, cfg, &wl, &GpuSpec::rtx2060(),
                                 &ContentionParams::default())
    }

    #[test]
    fn policy_names_round_trip() {
        for p in POLICIES {
            assert_eq!(AdmissionPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("NONE"),
                   Some(AdmissionPolicy::Open));
        assert!(AdmissionPolicy::parse("drop-everything").is_none());
    }

    #[test]
    fn envelopes_are_positive_and_ordered() {
        let wl = mdtb::mdtb_a(1.0).build();
        let spec = GpuSpec::rtx2060();
        let params = ContentionParams::default();
        for s in &wl.sources {
            let e = ModelEnvelope::of(&s.model, &spec, &params);
            assert!(e.solo_us > 0.0);
            assert!(e.padded_us >= e.solo_us,
                    "padded {} < solo {}", e.padded_us, e.solo_us);
        }
    }

    #[test]
    fn envelope_table_matches_per_source_envelopes() {
        let wl = mdtb::mdtb_a(1.0).build();
        let params = ContentionParams::default();
        for spec in GpuSpec::presets() {
            let table = model_envelopes(&wl, &spec, &params);
            assert_eq!(table.len(), wl.sources.len());
            for (e, s) in table.iter().zip(&wl.sources) {
                let direct = ModelEnvelope::of(&s.model, &spec, &params);
                assert_eq!(e.solo_us.to_bits(), direct.solo_us.to_bits());
                assert_eq!(e.padded_us.to_bits(), direct.padded_us.to_bits());
            }
        }
    }

    #[test]
    fn open_policy_admits_everything() {
        let mut c = ctrl(AdmissionPolicy::Open, AdmissionConfig::default());
        for i in 0..1000 {
            assert_eq!(c.decide(1, i as f64), Decision::Admitted);
        }
        assert!(c.backlog_us() > 0.0);
    }

    #[test]
    fn critical_is_never_shed_even_with_empty_bucket() {
        let cfg = AdmissionConfig {
            bucket_capacity: 0.0,
            refill_hz: 0.0,
            ..AdmissionConfig::default()
        };
        let mut c = ctrl(AdmissionPolicy::TokenBucket, cfg);
        for i in 0..100 {
            assert_eq!(c.decide(0, i as f64), Decision::Admitted);
            assert_eq!(c.decide(1, i as f64),
                       Decision::Shed(ShedReason::RateLimited));
        }
    }

    #[test]
    fn token_bucket_drains_and_refills() {
        let cfg = AdmissionConfig {
            bucket_capacity: 2.0,
            refill_hz: 1000.0, // 1 token per ms
            ..AdmissionConfig::default()
        };
        let mut c = ctrl(AdmissionPolicy::TokenBucket, cfg);
        assert_eq!(c.decide(1, 0.0), Decision::Admitted);
        assert_eq!(c.decide(1, 0.0), Decision::Admitted);
        assert_eq!(c.decide(1, 0.0),
                   Decision::Shed(ShedReason::RateLimited));
        // 1ms later one token has refilled.
        assert_eq!(c.decide(1, 1_000.0), Decision::Admitted);
        assert_eq!(c.decide(1, 1_000.0),
                   Decision::Shed(ShedReason::RateLimited));
    }

    #[test]
    fn burst_guard_sheds_when_backlog_exceeds_bound() {
        let cfg = AdmissionConfig {
            max_queue_us: 1.0, // absurdly tight: second admit must shed
            ..AdmissionConfig::default()
        };
        let mut c = ctrl(AdmissionPolicy::DeadlineFeasible, cfg);
        assert_eq!(c.decide(1, 0.0), Decision::Admitted);
        assert_eq!(c.decide(1, 0.0),
                   Decision::Shed(ShedReason::Overloaded));
        // Serving the first request frees the backlog again.
        c.on_served(1);
        assert_eq!(c.decide(1, 0.0), Decision::Admitted);
    }

    #[test]
    fn infeasible_deadline_sheds_normal_but_not_critical() {
        use std::sync::Arc;

        use crate::workloads::arrival::Arrival;
        use crate::workloads::mdtb::{Source, Workload};
        use crate::workloads::models;

        let mk = |crit| Source {
            model: Arc::new(models::alexnet()),
            arrival: Arrival::Uniform { rate_hz: 10.0 },
            criticality: crit,
            deadline_us: Some(1.0), // far below any envelope
        };
        let wl = Workload {
            name: "t".into(),
            sources: vec![mk(Criticality::Critical), mk(Criticality::Normal)],
            duration_us: 10_000.0,
            seed: 1,
        };
        let mut c = AdmissionController::new(
            AdmissionPolicy::DeadlineFeasible, AdmissionConfig::default(),
            &wl, &GpuSpec::rtx2060(), &ContentionParams::default());
        assert_eq!(c.decide(0, 0.0), Decision::Admitted);
        assert_eq!(c.critical_at_risk(), 1);
        assert_eq!(c.decide(1, 0.0),
                   Decision::Shed(ShedReason::Infeasible));
    }

    #[test]
    fn served_backlog_never_goes_negative() {
        let mut c = ctrl(AdmissionPolicy::Open, AdmissionConfig::default());
        c.on_served(1);
        c.on_served(1);
        assert_eq!(c.backlog_us(), 0.0);
        // Critical completions never touch the backlog.
        c.decide(0, 0.0);
        c.on_served(0);
        assert_eq!(c.backlog_us(), 0.0);
    }
}
