//! **Hard-isolation** scheduler family (ISSUE 9): MPS-style SM
//! partitioning, the comparison point the isolation literature asks for
//! ("Performance Isolation for Inference Processes in Edge GPU Systems",
//! PAPERS.md). Each criticality class owns a *disjoint* SM set — the
//! critical partition is SMs `[0, crit_sms)`, the normal partition
//! `[crit_sms, num_sms)` — enforced by the engine's per-stream placement
//! masks ([`crate::gpu::sm::SmMask`]), so a class can never steal the
//! other's compute no matter how bursty it gets.
//!
//! Two modes:
//!
//! * **strict** (`isolation:70/30`): the partition boundary never moves.
//!   Critical latency is near-solo on its slice; throughput pays for
//!   every idle reserved SM — the hard-partitioning strawman Miriam's
//!   elastic kernels are claimed to dominate.
//! * **spillover** (`isolation:70/30+spill`): work-conserving lending —
//!   while a class is fully idle (no running request, empty queue) the
//!   other class's stream is widened to the whole device; the loan is
//!   revoked the moment the lender has work again (before the lender
//!   submits anything, so no *new* foreign blocks land after the
//!   revocation). Already-resident foreign blocks drain to completion:
//!   like real MPS reconfiguration there is no preemption, which is
//!   exactly the residual interference the spillover benchmarks measure.
//!
//! Within each partition the policy is Sequential's: one request in
//! flight per class, critical queue FIFO, normal queue FIFO. That makes
//! `isolation:100/0` (no spill) on critical-only traffic *provably*
//! identical to the Sequential baseline — pinned by the differential
//! tests in `rust/tests/prop_invariants.rs`.

use std::collections::VecDeque;

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::{Criticality, LaunchShape};
use crate::gpu::sm::SmMask;
use crate::gpu::stream::{LaunchTag, StreamId};

/// Parsed isolation split: `critical_pct/normal_pct[+spill]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IsolationConfig {
    /// Percentage of SMs reserved for the critical class (0..=100).
    pub critical_pct: u32,
    /// Percentage of SMs reserved for the normal class (100 - critical).
    pub normal_pct: u32,
    /// Work-conserving spillover: an idle partition lends its SMs to the
    /// other class until its next arrival.
    pub spillover: bool,
}

impl Default for IsolationConfig {
    /// The documented default split: 70% critical / 30% normal, strict.
    fn default() -> Self {
        IsolationConfig { critical_pct: 70, normal_pct: 30, spillover: false }
    }
}

impl IsolationConfig {
    /// Parse the CLI split grammar `A/B` or `A/B+spill`, where `A + B`
    /// must equal 100 (EXPERIMENTS.md §Isolation).
    pub fn parse(s: &str) -> Result<Self, String> {
        let (split, spillover) = match s.strip_suffix("+spill") {
            Some(rest) => (rest, true),
            None => (s, false),
        };
        let Some((a, b)) = split.split_once('/') else {
            return Err(format!(
                "isolation split '{s}': expected 'A/B' or 'A/B+spill'"));
        };
        let critical_pct: u32 = a.trim().parse().map_err(|_| {
            format!("isolation split '{s}': bad critical share '{a}'")
        })?;
        let normal_pct: u32 = b.trim().parse().map_err(|_| {
            format!("isolation split '{s}': bad normal share '{b}'")
        })?;
        if critical_pct + normal_pct != 100 {
            return Err(format!(
                "isolation split '{s}': shares must sum to 100 \
                 (got {critical_pct}+{normal_pct})"));
        }
        // A 0% share may not spill: the borrowing class would run on an
        // entirely borrowed device, and revoking that loan on the
        // lender's arrival would strand its pending blocks on an empty
        // mask (no preemption) — the run could never finish. Strict 0%
        // splits are fine (the starved class just queues forever).
        if spillover && (critical_pct == 0 || normal_pct == 0) {
            return Err(format!(
                "isolation split '{s}': spillover needs both shares > 0 \
                 (a loan of the whole device cannot be revoked without \
                 preemption)"));
        }
        Ok(IsolationConfig { critical_pct, normal_pct, spillover })
    }

    /// SMs in the critical partition on an `num_sms`-SM device (nearest
    /// rounding; the normal class gets the rest). Fail-fast validation:
    /// a non-zero share that rounds to zero SMs is an error — silently
    /// starving a class would wedge its traffic — as is a device with
    /// more SMs than the 64-bit placement mask can address.
    pub fn partition(&self, num_sms: u32) -> Result<u32, String> {
        if num_sms == 0 {
            return Err("isolation: device has no SMs".into());
        }
        if num_sms > 64 {
            return Err(format!(
                "isolation: device has {num_sms} SMs, beyond the 64-bit \
                 placement mask"));
        }
        let crit = ((num_sms * self.critical_pct + 50) / 100).min(num_sms);
        if self.critical_pct > 0 && crit == 0 {
            return Err(format!(
                "isolation split {}/{} on a {num_sms}-SM device rounds the \
                 critical partition to zero SMs",
                self.critical_pct, self.normal_pct));
        }
        if self.normal_pct > 0 && crit == num_sms {
            return Err(format!(
                "isolation split {}/{} on a {num_sms}-SM device rounds the \
                 normal partition to zero SMs",
                self.critical_pct, self.normal_pct));
        }
        Ok(crit)
    }

    /// The registry/report name of this config: `isolation:A/B[+spill]`.
    pub fn scheduler_name(&self) -> String {
        format!("isolation:{}/{}{}", self.critical_pct, self.normal_pct,
                if self.spillover { "+spill" } else { "" })
    }
}

/// One class's lane: a FIFO queue and the single request in flight.
struct Lane {
    stream: StreamId,
    queue: VecDeque<Req>,
    /// (req id, last kernel tag) of the request on the partition.
    running: Option<(u64, LaunchTag)>,
    /// Whether this lane currently borrows the other partition (its
    /// stream mask is widened to the whole device).
    widened: bool,
}

impl Lane {
    fn new() -> Self {
        Lane { stream: 0, queue: VecDeque::new(), running: None,
               widened: false }
    }

    /// Idle = nothing running *and* nothing queued: the condition under
    /// which this lane lends its partition away.
    fn idle(&self) -> bool {
        self.running.is_none() && self.queue.is_empty()
    }
}

/// The hard-isolation scheduler (see module docs).
pub struct Isolation {
    cfg: IsolationConfig,
    name: String,
    crit: Lane,
    norm: Lane,
    num_sms: u32,
    /// SMs `[0, crit_sms)` are the critical partition.
    crit_sms: u32,
}

impl Isolation {
    /// A fresh isolation scheduler for `cfg` (call `init` before use;
    /// `init` fail-fast-panics if `cfg` cannot partition the device —
    /// CLI entry points pre-validate with [`IsolationConfig::partition`]).
    pub fn new(cfg: IsolationConfig) -> Self {
        Isolation {
            cfg,
            name: cfg.scheduler_name(),
            crit: Lane::new(),
            norm: Lane::new(),
            num_sms: 0,
            crit_sms: 0,
        }
    }

    fn crit_mask(&self) -> SmMask {
        SmMask::range(0, self.crit_sms)
    }

    fn norm_mask(&self) -> SmMask {
        SmMask::range(self.crit_sms, self.num_sms)
    }

    fn full_mask(&self) -> SmMask {
        SmMask::range(0, self.num_sms)
    }

    /// Re-derive both stream masks from lane idleness (spillover mode
    /// only — strict partitions never move). Called after every arrival
    /// *before* the arriving lane submits — so a loan is revoked ahead
    /// of the lender's next submission, never after — and after every
    /// completion, where widening takes effect immediately (the engine
    /// re-attempts dispatch inside `set_stream_mask`, placing the
    /// borrower's waiting blocks at the completion instant).
    fn refresh_masks(&mut self, eng: &mut Engine) {
        if !self.cfg.spillover {
            return;
        }
        let widen_crit = self.norm.idle() && !self.crit.idle();
        let widen_norm = self.crit.idle() && !self.norm.idle();
        if widen_crit != self.crit.widened {
            self.crit.widened = widen_crit;
            let mask = if widen_crit { self.full_mask() }
                       else { self.crit_mask() };
            eng.set_stream_mask(self.crit.stream, mask);
        }
        if widen_norm != self.norm.widened {
            self.norm.widened = widen_norm;
            let mask = if widen_norm { self.full_mask() }
                       else { self.norm_mask() };
            eng.set_stream_mask(self.norm.stream, mask);
        }
    }

    /// Start the next queued request on `critical`'s lane if it is free.
    /// A lane whose partition is empty (a 0% share) and not currently
    /// widened must keep its requests queued: submitting would wedge the
    /// run, since blocks on an empty mask can never place.
    fn start_next(&mut self, critical: bool, eng: &mut Engine) {
        let own_sms = if critical { self.crit_sms }
                      else { self.num_sms - self.crit_sms };
        let lane = if critical { &mut self.crit } else { &mut self.norm };
        if lane.running.is_some() || (own_sms == 0 && !lane.widened) {
            return;
        }
        let Some(req) = lane.queue.pop_front() else { return };
        let mut last = 0;
        for (k, &nid) in req.model.kernels.iter().zip(req.name_ids.iter()) {
            last = eng.submit_interned(lane.stream, nid,
                                       LaunchShape::from_kernel(k),
                                       req.criticality, 0.0);
        }
        lane.running = Some((req.id, last));
    }
}

impl Scheduler for Isolation {
    fn name(&self) -> &str {
        &self.name
    }

    fn init(&mut self, eng: &mut Engine) {
        self.num_sms = eng.spec.num_sms;
        self.crit_sms = match self.cfg.partition(self.num_sms) {
            Ok(c) => c,
            Err(e) => panic!("{e}"),
        };
        // Critical stream first (dispatch priority under spillover
        // overlap), then the normal stream.
        self.crit.stream = eng.add_stream(10);
        self.norm.stream = eng.add_stream(0);
        eng.set_stream_mask(self.crit.stream, self.crit_mask());
        eng.set_stream_mask(self.norm.stream, self.norm_mask());
    }

    fn on_request(&mut self, req: Req, eng: &mut Engine) {
        let critical = req.criticality == Criticality::Critical;
        if critical {
            self.crit.queue.push_back(req);
        } else {
            self.norm.queue.push_back(req);
        }
        // Revoke any loan this arrival invalidates *before* submitting:
        // the spillover-conservation property (no new foreign placements
        // after the lender's arrival) holds by construction.
        self.refresh_masks(eng);
        self.start_next(critical, eng);
    }

    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine,
                     finished: &mut Vec<u64>) {
        if let Some((id, last)) = self.crit.running {
            if comp.tag == last {
                finished.push(id);
                self.crit.running = None;
                self.start_next(true, eng);
            }
        }
        if let Some((id, last)) = self.norm.running {
            if comp.tag == last {
                finished.push(id);
                self.norm.running = None;
                self.start_next(false, eng);
            }
        }
        // A lane that just drained may now lend its partition.
        self.refresh_masks(eng);
    }

    fn pending_normal(&self) -> Option<usize> {
        Some(self.norm.queue.len())
    }

    /// Real cancellation (ISSUE 9 satellite): a request still in either
    /// class queue is removed outright — nothing was submitted yet, so
    /// there is no engine state to unwind. The running request per lane
    /// has every kernel submitted and its head active; with no
    /// preemption it is not cancellable, matching the trait contract.
    fn cancel(&mut self, req_id: u64, eng: &mut Engine) -> bool {
        let mut hit = false;
        for lane in [&mut self.crit, &mut self.norm] {
            if let Some(pos) = lane.queue.iter()
                .position(|r| r.id == req_id)
            {
                lane.queue.remove(pos);
                hit = true;
                break;
            }
        }
        if hit {
            // Emptying a queue can make the lane idle and thus a lender.
            self.refresh_masks(eng);
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::coordinator::driver;
    use crate::gpu::spec::GpuSpec;
    use crate::workloads::arrival::Arrival;
    use crate::workloads::mdtb::{Source, Workload};
    use crate::workloads::models;

    #[test]
    fn parse_grammar() {
        let c = IsolationConfig::parse("70/30").unwrap();
        assert_eq!((c.critical_pct, c.normal_pct, c.spillover), (70, 30, false));
        let c = IsolationConfig::parse("70/30+spill").unwrap();
        assert!(c.spillover);
        assert_eq!(c.scheduler_name(), "isolation:70/30+spill");
        let c = IsolationConfig::parse("100/0").unwrap();
        assert_eq!(c.scheduler_name(), "isolation:100/0");
        assert!(IsolationConfig::parse("70/40").is_err());
        assert!(IsolationConfig::parse("70").is_err());
        assert!(IsolationConfig::parse("x/30").is_err());
        assert!(IsolationConfig::parse("70/y").is_err());
        assert!(IsolationConfig::parse("70/30+spil").is_err());
        // Spillover from/into a 0% share is unrevocable without
        // preemption and is rejected at parse time.
        assert!(IsolationConfig::parse("100/0+spill").is_err());
        assert!(IsolationConfig::parse("0/100+spill").is_err());
        assert!(IsolationConfig::parse("0/100").is_ok());
    }

    #[test]
    fn partition_arithmetic_per_device() {
        let c = IsolationConfig::parse("70/30").unwrap();
        // rtx2060: 30 SMs -> 21/9; xavier: 8 -> 6/2; tx2: 2 -> 1/1.
        assert_eq!(c.partition(GpuSpec::rtx2060().num_sms), Ok(21));
        assert_eq!(c.partition(GpuSpec::xavier().num_sms), Ok(6));
        assert_eq!(c.partition(GpuSpec::tx2().num_sms), Ok(1));
        // 100/0 reserves everything for criticals on any device.
        let all = IsolationConfig::parse("100/0").unwrap();
        assert_eq!(all.partition(2), Ok(2));
    }

    #[test]
    fn partition_fails_fast_when_a_share_starves() {
        // 90/10 on a 2-SM device: normal's 10% rounds to zero SMs.
        let c = IsolationConfig::parse("90/10").unwrap();
        assert!(c.partition(2).is_err());
        // 1/99 on a 30-SM device: critical's 1% rounds to zero SMs.
        let c = IsolationConfig::parse("1/99").unwrap();
        assert!(c.partition(30).is_err());
        // Devices beyond the mask width are rejected outright.
        let c = IsolationConfig::parse("50/50").unwrap();
        assert!(c.partition(65).is_err());
        assert!(c.partition(0).is_err());
        assert_eq!(c.partition(64), Ok(32));
    }

    fn req(id: u64, crit: Criticality, eng: &mut Engine) -> Req {
        let model: crate::workloads::models::ModelRef =
            Arc::new(models::cifarnet());
        let ids: Vec<u32> =
            model.kernels.iter().map(|k| eng.intern_name(&k.name)).collect();
        Req {
            id,
            source: 0,
            model,
            name_ids: Arc::new(ids),
            criticality: crit,
            arrival_us: 0.0,
        }
    }

    #[test]
    fn cancel_removes_queued_but_not_running() {
        let mut eng = Engine::new(GpuSpec::rtx2060());
        let mut iso = Isolation::new(IsolationConfig::parse("70/30").unwrap());
        iso.init(&mut eng);
        let r1 = req(1, Criticality::Normal, &mut eng);
        let r2 = req(2, Criticality::Normal, &mut eng);
        let r3 = req(3, Criticality::Critical, &mut eng);
        iso.on_request(r1, &mut eng); // starts immediately on the lane
        iso.on_request(r2, &mut eng); // queued behind it
        iso.on_request(r3, &mut eng); // starts on the critical lane
        assert_eq!(iso.pending_normal(), Some(1));
        // Queued request: cancellable; running requests: not.
        assert!(iso.cancel(2, &mut eng));
        assert!(!iso.cancel(1, &mut eng));
        assert!(!iso.cancel(3, &mut eng));
        assert!(!iso.cancel(2, &mut eng), "already cancelled");
        assert_eq!(iso.pending_normal(), Some(0));
        // Drain: only the two running requests ever finish.
        let mut finished = Vec::new();
        loop {
            let comps = eng.step();
            if comps.is_empty() && eng.idle() {
                break;
            }
            for c in &comps {
                iso.on_completion(c, &mut eng, &mut finished);
            }
        }
        finished.sort_unstable();
        assert_eq!(finished, vec![1, 3]);
    }

    #[test]
    fn strict_split_serves_both_classes() {
        let wl = Workload {
            name: "t".into(),
            sources: vec![
                Source {
                    model: Arc::new(models::gru()),
                    arrival: Arrival::Uniform { rate_hz: 20.0 },
                    criticality: Criticality::Critical,
                    deadline_us: None,
                },
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::ClosedLoop { clients: 1 },
                    criticality: Criticality::Normal,
                    deadline_us: None,
                },
            ],
            duration_us: 200_000.0,
            seed: 7,
        };
        let mut iso = Isolation::new(IsolationConfig::parse("70/30").unwrap());
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut iso);
        assert!(stats.completed_critical() > 0);
        assert!(stats.completed_normal() > 0);
    }

    #[test]
    fn spillover_beats_strict_on_normal_throughput() {
        // Critical source idle most of the time; a closed-loop normal
        // source should complete strictly more work when it can borrow
        // the idle critical partition.
        let wl = Workload {
            name: "t".into(),
            sources: vec![
                Source {
                    model: Arc::new(models::gru()),
                    arrival: Arrival::Uniform { rate_hz: 5.0 },
                    criticality: Criticality::Critical,
                    deadline_us: None,
                },
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::ClosedLoop { clients: 2 },
                    criticality: Criticality::Normal,
                    deadline_us: None,
                },
            ],
            duration_us: 400_000.0,
            seed: 11,
        };
        let strict = {
            let mut s =
                Isolation::new(IsolationConfig::parse("70/30").unwrap());
            driver::run(GpuSpec::rtx2060(), &wl, &mut s)
        };
        let spill = {
            let mut s =
                Isolation::new(IsolationConfig::parse("70/30+spill").unwrap());
            driver::run(GpuSpec::rtx2060(), &wl, &mut s)
        };
        assert!(spill.completed_normal() > strict.completed_normal(),
                "spillover {} vs strict {}", spill.completed_normal(),
                strict.completed_normal());
        assert!(spill.completed_critical() > 0);
    }
}
