//! The simulation driver: merges workload arrivals with simulator events,
//! feeds a [`Scheduler`], regenerates closed-loop arrivals, and assembles
//! [`RunStats`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::coordinator::stats::RunStats;
use crate::gpu::engine::Engine;
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::workloads::mdtb::Workload;
use crate::workloads::rng::Rng;

/// Total-ordered f64 key for the arrival heap.
#[derive(Debug, Clone, Copy, PartialEq)]
struct T(f64);
impl Eq for T {}
impl PartialOrd for T {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for T {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Engine configuration for a run; perf experiments and differential
/// tests flip these, normal callers use [`run`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RunOpts {
    /// Use the retained O(events × resident) full-recompute rate model
    /// instead of the incremental aggregates (EXPERIMENTS.md §Perf
    /// change #4): the differential-testing oracle and the "before" leg
    /// of `benches/engine_throughput.rs`.
    pub reference_rates: bool,
}

/// Run `workload` under `scheduler` on `spec`. Deterministic for a given
/// (workload.seed, scheduler) pair.
pub fn run(spec: GpuSpec, workload: &Workload, scheduler: &mut dyn Scheduler)
           -> RunStats {
    run_with(spec, workload, scheduler, RunOpts::default())
}

/// [`run`] with explicit engine options.
pub fn run_with(spec: GpuSpec, workload: &Workload,
                scheduler: &mut dyn Scheduler, opts: RunOpts) -> RunStats {
    let platform = spec.name.clone();
    let mut eng = Engine::new(spec);
    if opts.reference_rates {
        eng = eng.with_reference_rates();
    }
    scheduler.init(&mut eng);

    let mut rng = Rng::new(workload.seed);
    // (time, source) min-heap of pending arrivals.
    let mut arrivals: BinaryHeap<Reverse<(T, usize)>> = BinaryHeap::new();
    for (i, src) in workload.sources.iter().enumerate() {
        for t in src.arrival.schedule(workload.duration_us, &mut rng) {
            arrivals.push(Reverse((T(t), i)));
        }
    }

    let mut stats = RunStats {
        scheduler: scheduler.name().to_string(),
        workload: workload.name.clone(),
        platform,
        ..Default::default()
    };
    let mut next_id: u64 = 1;
    // req id -> (arrival time, criticality, source)
    let mut open: std::collections::HashMap<u64, (f64, Criticality, usize)> =
        std::collections::HashMap::new();
    let wall = Instant::now();

    loop {
        let t_arr = arrivals.peek().map(|Reverse((T(t), _))| *t);
        let t_ev = eng.next_event_time();
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |te| ta <= te) => {
                // Deliver every arrival at time ta.
                eng.advance_to(ta);
                while let Some(Reverse((T(t), src))) = arrivals.peek().copied() {
                    if t > ta {
                        break;
                    }
                    arrivals.pop();
                    let s = &workload.sources[src];
                    let req = Req {
                        id: next_id,
                        source: src,
                        model: s.model.clone(),
                        criticality: s.criticality,
                        arrival_us: t,
                    };
                    open.insert(next_id, (t, s.criticality, src));
                    next_id += 1;
                    let d0 = Instant::now();
                    scheduler.on_request(req, &mut eng);
                    stats.sched_decision_ns += d0.elapsed().as_nanos() as u64;
                    stats.sched_decisions += 1;
                }
            }
            (_, Some(_)) => {
                let completions = eng.step();
                for c in completions {
                    let d0 = Instant::now();
                    let finished = scheduler.on_completion(&c, &mut eng);
                    stats.sched_decision_ns += d0.elapsed().as_nanos() as u64;
                    stats.sched_decisions += 1;
                    for fid in finished {
                        let (arr, crit, src) = open
                            .remove(&fid)
                            .expect("scheduler finished unknown request");
                        let lat = eng.now_us() - arr;
                        match crit {
                            Criticality::Critical => {
                                stats.critical_latencies_us.push(lat)
                            }
                            Criticality::Normal => {
                                stats.normal_latencies_us.push(lat)
                            }
                        }
                        // Closed-loop: next request the moment this returns.
                        let s = &workload.sources[src];
                        if s.arrival.is_closed_loop()
                            && eng.now_us() < workload.duration_us
                        {
                            arrivals.push(Reverse((T(eng.now_us()), src)));
                        }
                    }
                }
            }
            // (Some(ta), None) with a failed guard cannot occur: the guard
            // is vacuously true when the engine has no next event.
            _ => unreachable!("driver loop: impossible arrival/event state"),
        }
    }

    stats.span_us = eng.now_us();
    let spec = eng.spec.clone();
    let metrics = eng.into_metrics();
    stats.achieved_occupancy = metrics.occupancy.achieved(&spec);
    for name in metrics.occupancy.per_name_warp_time.keys() {
        stats
            .per_name_occupancy
            .insert(name.clone(), metrics.occupancy.achieved_for(&spec, name));
    }
    stats.timeline = metrics.records;
    stats.events = metrics.events;
    stats.wall_ns = wall.elapsed().as_nanos() as u64;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baselines::sequential::Sequential;
    use crate::workloads::mdtb;

    #[test]
    fn sequential_runs_mdtb_a_briefly() {
        let wl = mdtb::mdtb_a(50_000.0).build(); // 50ms closed-loop
        let mut s = Sequential::new();
        let stats = run(GpuSpec::rtx2060(), &wl, &mut s);
        assert!(stats.completed_critical() > 0, "no critical tasks done");
        assert!(stats.completed_normal() > 0, "no normal tasks done");
        assert!(stats.span_us > 0.0);
        assert!(stats.achieved_occupancy > 0.0);
        assert!(stats.achieved_occupancy <= 1.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let wl = mdtb::mdtb_c(200_000.0).build();
        let a = run(GpuSpec::xavier(), &wl, &mut Sequential::new());
        let b = run(GpuSpec::xavier(), &wl, &mut Sequential::new());
        assert_eq!(a.completed_critical(), b.completed_critical());
        assert_eq!(a.completed_normal(), b.completed_normal());
        assert!((a.span_us - b.span_us).abs() < 1e-6);
    }

    #[test]
    fn wall_clock_and_events_recorded() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let st = run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(st.events > 0);
        assert!(st.wall_ns > 0);
        assert!(st.events_per_sec() > 0.0);
    }

    #[test]
    fn reference_rates_option_reaches_same_totals() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let inc = run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        let refr = run_with(GpuSpec::rtx2060(), &wl, &mut Sequential::new(),
                            RunOpts { reference_rates: true });
        assert_eq!(inc.completed_critical(), refr.completed_critical());
        assert_eq!(inc.completed_normal(), refr.completed_normal());
        assert_eq!(inc.events, refr.events);
    }
}
