//! The simulation driver: merges workload arrivals with simulator events,
//! feeds a [`Scheduler`], regenerates closed-loop arrivals, and assembles
//! [`RunStats`].

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::coordinator::stats::RunStats;
use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::runtime::timewheel::TimingWheel;
use crate::workloads::mdtb::Workload;
use crate::workloads::rng::Rng;

/// The pending-arrival queue: ascending `(time, source index)` order.
///
/// Since ISSUE 7 this is the hierarchical timing wheel
/// ([`crate::runtime::timewheel`]) rather than a
/// `BinaryHeap<Reverse<(TimeKey, usize)>>`: O(1)-amortized per event
/// instead of O(log n), with the exact same pop order (the total-ordered
/// `TimeKey` comparison — the old NaN-maps-to-`Equal` comparator lived
/// here, at driver.rs:31 — moved to
/// [`crate::runtime::timewheel::TimeKey`] and is differential-tested
/// against a heap in `rust/tests/wheel_vs_heap.rs`).
pub(crate) type ArrivalQueue = TimingWheel;

/// Pre-generate every source's open-loop arrivals (closed-loop sources
/// contribute their t=0 seeds) into a fresh [`ArrivalQueue`]. Shared by
/// [`run_with`] and the online serving loop so the two paths draw the
/// exact same arrival stream from a given `(workload, rng)` state.
pub(crate) fn initial_arrivals(workload: &Workload, rng: &mut Rng)
                               -> ArrivalQueue {
    let mut arrivals = ArrivalQueue::new();
    for (i, src) in workload.sources.iter().enumerate() {
        for t in src.arrival.schedule(workload.duration_us, rng) {
            // A NaN arrival would corrupt the queue ordering silently in
            // release builds, where debug_assert! compiles out — so this
            // is a release-mode error (ISSUE 7 bugfix; the wheel's push
            // re-checks, this one names the offending source).
            assert!(t.is_finite(),
                    "source {i} produced non-finite arrival {t}");
            arrivals.push(t, i);
        }
    }
    arrivals
}

/// Engine configuration for a run; perf experiments and differential
/// tests flip these, normal callers use [`run`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RunOpts {
    /// Use the retained O(events × resident) full-recompute rate model
    /// instead of the incremental aggregates (EXPERIMENTS.md §Perf
    /// change #4): the differential-testing oracle and the "before" leg
    /// of `benches/engine_throughput.rs`.
    pub reference_rates: bool,
    /// Record the full engine event trace ([`crate::gpu::trace`]) into
    /// [`RunStats::trace`]. Off by default — the conformance suite and
    /// the `scenarios --trace-out/--record-golden` CLI turn it on.
    pub trace: bool,
}

/// Run `workload` under `scheduler` on `spec`. Deterministic for a given
/// (workload.seed, scheduler) pair.
pub fn run(spec: GpuSpec, workload: &Workload, scheduler: &mut dyn Scheduler)
           -> RunStats {
    run_with(spec, workload, scheduler, RunOpts::default())
}

/// [`run`] with explicit engine options.
pub fn run_with(spec: GpuSpec, workload: &Workload,
                scheduler: &mut dyn Scheduler, opts: RunOpts) -> RunStats {
    let platform = spec.name.clone();
    let mut eng = Engine::new(spec);
    if opts.reference_rates {
        eng = eng.with_reference_rates();
    }
    if opts.trace {
        eng = eng.with_trace();
    }
    scheduler.init(&mut eng);

    // Intern every source model's kernel names once, up front, in
    // deterministic (source, kernel) order — requests then carry dense ids
    // (`Req::name_ids`) and no scheduler hashes a name `String` on the
    // per-request path (ISSUE 3 zero-clone fast path).
    let name_ids: Vec<Arc<Vec<u32>>> = workload
        .sources
        .iter()
        .map(|s| Arc::new(s.model.intern_kernels(|n| eng.intern_name(n))))
        .collect();

    let mut rng = Rng::new(workload.seed);
    let mut arrivals = initial_arrivals(workload, &mut rng);

    let mut stats = RunStats {
        scheduler: scheduler.name().to_string(),
        workload: workload.name.clone(),
        platform,
        ..Default::default()
    };
    let mut next_id: u64 = 1;
    // req id -> (arrival time, criticality, source)
    let mut open: std::collections::HashMap<u64, (f64, Criticality, usize)> =
        std::collections::HashMap::new();
    // Scratch buffers reused across every event (ISSUE 3 satellite: the
    // steady-state loop performs no per-event allocation).
    let mut completions: Vec<Completion> = Vec::new();
    let mut finished: Vec<u64> = Vec::new();
    let wall = Instant::now();

    loop {
        let t_arr = arrivals.peek().map(|(t, _)| t);
        let t_ev = eng.next_event_time();
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |te| ta <= te) => {
                // Deliver every arrival at time ta.
                eng.advance_to(ta);
                while let Some((t, src)) = arrivals.peek() {
                    if t > ta {
                        break;
                    }
                    arrivals.pop();
                    let s = &workload.sources[src];
                    let req = Req {
                        id: next_id,
                        source: src,
                        model: s.model.clone(),
                        name_ids: name_ids[src].clone(),
                        criticality: s.criticality,
                        arrival_us: t,
                    };
                    open.insert(next_id, (t, s.criticality, src));
                    next_id += 1;
                    let d0 = Instant::now();
                    scheduler.on_request(req, &mut eng);
                    stats.sched_decision_ns += d0.elapsed().as_nanos() as u64;
                    stats.sched_decisions += 1;
                }
            }
            (_, Some(_)) => {
                eng.step_into(&mut completions);
                for c in &completions {
                    let d0 = Instant::now();
                    finished.clear();
                    scheduler.on_completion(c, &mut eng, &mut finished);
                    stats.sched_decision_ns += d0.elapsed().as_nanos() as u64;
                    stats.sched_decisions += 1;
                    for &fid in &finished {
                        let (arr, crit, src) = open
                            .remove(&fid)
                            .expect("scheduler finished unknown request");
                        let lat = eng.now_us() - arr;
                        let s = &workload.sources[src];
                        let missed =
                            s.deadline_us.is_some_and(|d| lat > d);
                        match crit {
                            Criticality::Critical => {
                                stats.critical_latencies_us.push(lat);
                                if missed {
                                    stats.deadline_misses_critical += 1;
                                }
                            }
                            Criticality::Normal => {
                                stats.normal_latencies_us.push(lat);
                                if missed {
                                    stats.deadline_misses_normal += 1;
                                }
                            }
                        }
                        // Closed-loop: next request the moment this returns.
                        if s.arrival.is_closed_loop()
                            && eng.now_us() < workload.duration_us
                        {
                            arrivals.push(eng.now_us(), src);
                        }
                    }
                }
            }
            // (Some(ta), None) with a failed guard cannot occur: the guard
            // is vacuously true when the engine has no next event.
            _ => unreachable!("driver loop: impossible arrival/event state"),
        }
    }

    stats.span_us = eng.now_us();
    stats.trace = eng.take_trace();
    let spec = eng.spec.clone();
    let metrics = eng.into_metrics();
    stats.achieved_occupancy = metrics.occupancy.achieved(&spec);
    for name in metrics.occupancy.per_name_warp_time.keys() {
        stats
            .per_name_occupancy
            .insert(name.clone(), metrics.occupancy.achieved_for(&spec, name));
    }
    stats.timeline = metrics.records;
    stats.events = metrics.events;
    stats.wall_ns = wall.elapsed().as_nanos() as u64;
    stats
}

/// Record the pinned golden-trace cells
/// ([`crate::workloads::scenario::GOLDEN_CELLS`] at
/// [`crate::workloads::scenario::GOLDEN_PLATFORM`] /
/// [`crate::workloads::scenario::GOLDEN_DURATION_US`]) into
/// `dir` as canonical JSON. Returns (path, event count) per cell. The
/// single writer shared by the `scenarios --record-golden` CLI and the
/// conformance suite's bootstrap/UPDATE_GOLDEN path, so the two can
/// never desynchronize on platform, duration, options, or file naming.
pub fn record_golden_traces(
    dir: &std::path::Path,
) -> std::io::Result<Vec<(std::path::PathBuf, usize)>> {
    use crate::coordinator::sweep;
    use crate::workloads::scenario;
    std::fs::create_dir_all(dir)?;
    let spec = GpuSpec::by_name(scenario::GOLDEN_PLATFORM)
        .expect("golden platform preset exists");
    // The pinned paper-scheduler cells plus the isolation anchors
    // (ISSUE 9) — one recording pass so the two sets can never drift
    // apart on platform or duration.
    let names: Vec<(&str, &str)> = scenario::GOLDEN_CELLS
        .iter()
        .chain(scenario::ISOLATION_GOLDEN_CELLS.iter())
        .copied()
        .collect();
    let cells: Vec<(scenario::ScenarioSpec, String)> = names
        .iter()
        .map(|&(sc_name, sched)| {
            (scenario::by_name(sc_name, scenario::GOLDEN_DURATION_US)
                 .expect("golden cell scenario exists"),
             sched.to_string())
        })
        .collect();
    // Recorded through the sweep executor (ISSUE 3): cells run in
    // parallel, and per-cell traces are independent of worker count, so
    // parallel recording cannot change the goldens.
    let stats = sweep::run_cells(
        &spec, &cells,
        RunOpts { reference_rates: false, trace: true },
        cells.len().min(4));
    let mut out = Vec::new();
    for (&(sc_name, sched), mut st) in names.iter().zip(stats) {
        let trace = st.trace.take().expect("trace was requested");
        let path = dir.join(scenario::golden_file_name(sc_name, sched));
        std::fs::write(&path, trace.to_canonical_json())?;
        out.push((path, trace.len()));
    }
    Ok(out)
}

/// Record the pinned *per-device* golden-trace cells (ISSUE 5 satellite):
/// every [`crate::workloads::scenario::DEVICE_GOLDEN_PLATFORMS`] preset ×
/// [`crate::workloads::scenario::DEVICE_GOLDEN_SCENARIOS`] scenario ×
/// scheduler, at the shared golden duration, into `dir` (conventionally
/// the golden dir's `devices/` subdirectory) as canonical JSON. Returns
/// (path, event count) per cell. Like [`record_golden_traces`], this is
/// the single writer shared by `scenarios --record-golden` and the
/// conformance suite's bootstrap/UPDATE_GOLDEN path.
pub fn record_device_golden_traces(
    dir: &std::path::Path,
) -> std::io::Result<Vec<(std::path::PathBuf, usize)>> {
    use crate::coordinator::{sweep, SCHEDULERS};
    use crate::workloads::scenario;
    std::fs::create_dir_all(dir)?;
    let mut out = Vec::new();
    let opts = RunOpts { reference_rates: false, trace: true };
    for platform in scenario::DEVICE_GOLDEN_PLATFORMS {
        let spec = GpuSpec::by_name(platform)
            .expect("device golden platform preset exists");
        let cells: Vec<(scenario::ScenarioSpec, String)> =
            scenario::DEVICE_GOLDEN_SCENARIOS
                .iter()
                .flat_map(|&sc_name| {
                    let sc = scenario::by_name(
                        sc_name, scenario::GOLDEN_DURATION_US)
                        .expect("device golden scenario exists");
                    // Paper schedulers plus the pinned isolation splits
                    // (ISSUE 9) — the per-device set is where partition
                    // rounding down to tx2's 1/1 split gets anchored.
                    SCHEDULERS
                        .iter()
                        .chain(scenario::ISOLATION_GOLDEN_SCHEDULERS.iter())
                        .map(move |&sched| (sc.clone(), sched.to_string()))
                })
                .collect();
        // Same parallel-safe executor as the main goldens: per-cell
        // traces are independent of worker count.
        let stats = sweep::run_cells(&spec, &cells, opts,
                                     cells.len().min(4));
        for ((sc, sched), mut st) in cells.into_iter().zip(stats) {
            let trace = st.trace.take().expect("trace was requested");
            let path = dir.join(scenario::device_golden_file_name(
                platform, &sc.name, &sched));
            std::fs::write(&path, trace.to_canonical_json())?;
            out.push((path, trace.len()));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baselines::sequential::Sequential;
    use crate::workloads::mdtb;

    #[test]
    fn sequential_runs_mdtb_a_briefly() {
        let wl = mdtb::mdtb_a(50_000.0).build(); // 50ms closed-loop
        let mut s = Sequential::new();
        let stats = run(GpuSpec::rtx2060(), &wl, &mut s);
        assert!(stats.completed_critical() > 0, "no critical tasks done");
        assert!(stats.completed_normal() > 0, "no normal tasks done");
        assert!(stats.span_us > 0.0);
        assert!(stats.achieved_occupancy > 0.0);
        assert!(stats.achieved_occupancy <= 1.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let wl = mdtb::mdtb_c(200_000.0).build();
        let a = run(GpuSpec::xavier(), &wl, &mut Sequential::new());
        let b = run(GpuSpec::xavier(), &wl, &mut Sequential::new());
        assert_eq!(a.completed_critical(), b.completed_critical());
        assert_eq!(a.completed_normal(), b.completed_normal());
        assert!((a.span_us - b.span_us).abs() < 1e-6);
    }

    #[test]
    fn wall_clock_and_events_recorded() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let st = run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(st.events > 0);
        assert!(st.wall_ns > 0);
        assert!(st.events_per_sec() > 0.0);
    }

    #[test]
    fn reference_rates_option_reaches_same_totals() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let inc = run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        let refr = run_with(GpuSpec::rtx2060(), &wl, &mut Sequential::new(),
                            RunOpts { reference_rates: true, trace: false });
        assert_eq!(inc.completed_critical(), refr.completed_critical());
        assert_eq!(inc.completed_normal(), refr.completed_normal());
        assert_eq!(inc.events, refr.events);
    }

    #[test]
    fn trace_opt_captures_a_trace_and_default_does_not() {
        let wl = mdtb::mdtb_a(50_000.0).build();
        let plain = run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(plain.trace.is_none());
        let traced = run_with(GpuSpec::rtx2060(), &wl, &mut Sequential::new(),
                              RunOpts { reference_rates: false, trace: true });
        let tr = traced.trace.as_ref().expect("trace requested");
        assert!(!tr.is_empty());
        // One submit and one completion event per timeline launch.
        let submits = tr.count_of(crate::gpu::trace::TraceEventKind::Submit);
        let completes =
            tr.count_of(crate::gpu::trace::TraceEventKind::Complete);
        assert_eq!(submits, traced.timeline.len());
        assert_eq!(completes, traced.timeline.len());
        // Recording is observation-only: results match the plain run.
        assert_eq!(plain.events, traced.events);
        assert_eq!(plain.completed_critical(), traced.completed_critical());
        assert_eq!(plain.completed_normal(), traced.completed_normal());
    }

    #[test]
    fn impossible_deadlines_are_counted_as_misses() {
        use std::sync::Arc;

        use crate::workloads::mdtb::{Source, Workload};
        use crate::workloads::models;
        use crate::workloads::Arrival;

        let wl = Workload {
            name: "deadline-test".into(),
            sources: vec![
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::Uniform { rate_hz: 100.0 },
                    criticality: Criticality::Critical,
                    // 1us end-to-end is unachievable: every completion
                    // must be scored as a miss.
                    deadline_us: Some(1.0),
                },
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::ClosedLoop { clients: 1 },
                    criticality: Criticality::Normal,
                    deadline_us: None,
                },
            ],
            duration_us: 50_000.0,
            seed: 3,
        };
        let st = run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(st.completed_critical() > 0);
        assert_eq!(st.deadline_misses_critical as usize,
                   st.completed_critical());
        // The normal source carries no deadline: never scored.
        assert_eq!(st.deadline_misses_normal, 0);
    }
}
