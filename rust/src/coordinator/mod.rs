//! Runtime kernel coordination (paper §7) and the evaluation baselines.
//!
//! * [`scheduler`] — the policy interface.
//! * [`driver`] — arrival/event loop gluing workloads, policies and the
//!   GPU simulator; produces [`stats::RunStats`].
//! * [`shaded_tree`] — dynamic shard formation (Fig. 7).
//! * [`miriam`] — the Miriam coordinator (elastic padding).
//! * [`baselines`] — Sequential, Multi-stream+Priority, Inter-stream
//!   Barrier.
//! * [`isolation`] — the MPS-style hard-isolation scheduler family
//!   (disjoint SM partitions per criticality class, ISSUE 9).
//! * [`sweep`] — parallel deterministic sweep runner over the
//!   scenario × scheduler × seed grid (ISSUE 3).
//! * [`admission`] — online admission control (token buckets,
//!   deadline-feasibility envelopes, burst shedding) in front of the
//!   coordinator (ISSUE 4); driven by `crate::server::online`.

pub mod admission;
pub mod baselines;
pub mod driver;
pub mod isolation;
pub mod miriam;
pub mod scheduler;
pub mod shaded_tree;
pub mod stats;
pub mod sweep;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionPolicy};
pub use baselines::{InterStreamBarrier, MultiStream, Sequential};
pub use isolation::{Isolation, IsolationConfig};
pub use miriam::Miriam;
pub use scheduler::{Req, Scheduler};
pub use stats::RunStats;

use crate::gpu::kernel::Criticality;
use crate::workloads::mdtb::Workload;
use crate::workloads::models::ModelRef;

/// Build a scheduler by name, wired for `workload` (Miriam needs the
/// critical model set for its offline shrink). Besides the four paper
/// schedulers, `"miriam-ref"` builds Miriam on its retained pre-change
/// decision plumbing ([`Miriam::with_reference_path`]) — identical
/// trajectories, pre-ISSUE-3 cost profile; the coordinator-in-the-loop
/// bench's "before" leg — and the hard-isolation family (ISSUE 9):
/// `"isolation"` (the default 70/30 strict split) or
/// `"isolation:A/B[+spill]"` with an explicit split per
/// [`IsolationConfig::parse`].
pub fn scheduler_for(name: &str, workload: &Workload) -> Option<Box<dyn Scheduler>> {
    let miriam_crits = || -> Vec<ModelRef> {
        workload
            .sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .map(|s| s.model.clone())
            .collect()
    };
    if let Some(split) = name.strip_prefix("isolation:") {
        let cfg = IsolationConfig::parse(split).ok()?;
        return Some(Box::new(Isolation::new(cfg)));
    }
    match name {
        "sequential" => Some(Box::new(Sequential::new())),
        "multistream" => Some(Box::new(MultiStream::new())),
        "ib" => Some(Box::new(InterStreamBarrier::new())),
        "miriam" => Some(Box::new(Miriam::new(&miriam_crits()))),
        "miriam-ref" => {
            Some(Box::new(Miriam::new(&miriam_crits()).with_reference_path(true)))
        }
        "isolation" => Some(Box::new(Isolation::new(IsolationConfig::default()))),
        _ => None,
    }
}

/// All scheduler names, in the paper's presentation order.
///
/// Deliberately *excludes* the aliases and parameterized families that
/// [`scheduler_for`] also resolves (`miriam-ref`, `isolation`,
/// `isolation:A/B[+spill]`): grid runners and goldens iterate this list,
/// and those entries are opt-in columns. Use [`is_scheduler_name`] to
/// validate user input.
pub const SCHEDULERS: [&str; 4] = ["sequential", "multistream", "ib", "miriam"];

/// Whether `name` resolves to a scheduler — everything
/// [`scheduler_for`] accepts, including `miriam-ref` and the isolation
/// family with a well-formed split.
pub fn is_scheduler_name(name: &str) -> bool {
    if let Some(split) = name.strip_prefix("isolation:") {
        return IsolationConfig::parse(split).is_ok();
    }
    matches!(name, "miriam-ref" | "isolation")
        || SCHEDULERS.contains(&name)
}
