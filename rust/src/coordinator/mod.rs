//! Runtime kernel coordination (paper §7) and the evaluation baselines.
//!
//! * [`scheduler`] — the policy interface.
//! * [`driver`] — arrival/event loop gluing workloads, policies and the
//!   GPU simulator; produces [`stats::RunStats`].
//! * [`shaded_tree`] — dynamic shard formation (Fig. 7).
//! * [`miriam`] — the Miriam coordinator (elastic padding).
//! * [`baselines`] — Sequential, Multi-stream+Priority, Inter-stream
//!   Barrier.
//! * [`sweep`] — parallel deterministic sweep runner over the
//!   scenario × scheduler × seed grid (ISSUE 3).
//! * [`admission`] — online admission control (token buckets,
//!   deadline-feasibility envelopes, burst shedding) in front of the
//!   coordinator (ISSUE 4); driven by `crate::server::online`.

pub mod admission;
pub mod baselines;
pub mod driver;
pub mod miriam;
pub mod scheduler;
pub mod shaded_tree;
pub mod stats;
pub mod sweep;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionPolicy};
pub use baselines::{InterStreamBarrier, MultiStream, Sequential};
pub use miriam::Miriam;
pub use scheduler::{Req, Scheduler};
pub use stats::RunStats;

use crate::gpu::kernel::Criticality;
use crate::workloads::mdtb::Workload;
use crate::workloads::models::ModelRef;

/// Build a scheduler by name, wired for `workload` (Miriam needs the
/// critical model set for its offline shrink). Besides the four paper
/// schedulers, `"miriam-ref"` builds Miriam on its retained pre-change
/// decision plumbing ([`Miriam::with_reference_path`]) — identical
/// trajectories, pre-ISSUE-3 cost profile; the coordinator-in-the-loop
/// bench's "before" leg.
pub fn scheduler_for(name: &str, workload: &Workload) -> Option<Box<dyn Scheduler>> {
    let miriam_crits = || -> Vec<ModelRef> {
        workload
            .sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .map(|s| s.model.clone())
            .collect()
    };
    match name {
        "sequential" => Some(Box::new(Sequential::new())),
        "multistream" => Some(Box::new(MultiStream::new())),
        "ib" => Some(Box::new(InterStreamBarrier::new())),
        "miriam" => Some(Box::new(Miriam::new(&miriam_crits()))),
        "miriam-ref" => {
            Some(Box::new(Miriam::new(&miriam_crits()).with_reference_path(true)))
        }
        _ => None,
    }
}

/// All scheduler names, in the paper's presentation order.
pub const SCHEDULERS: [&str; 4] = ["sequential", "multistream", "ib", "miriam"];
