//! Parallel deterministic sweep runner (ISSUE 3 tentpole).
//!
//! Miriam's evaluation story is a *grid* — scenarios × schedulers × seeds
//! — and every cell is an independent simulation. [`run_sweep`] fans a
//! [`SweepSpec`] across a scoped worker pool: workers pull cell indexes
//! from an atomic counter, each cell runs its own engine + scheduler, and
//! results land in per-cell slots — so every *simulated* per-cell result
//! (events, completions, latencies, canonical traces) is **byte-identical
//! for any thread count**, a contract pinned by
//! `rust/tests/sweep_determinism.rs`. Host-timing fields (`wall_s`,
//! per-cell `wall_ns`/events-per-sec) necessarily vary run-to-run.
//! Wall-clock scales with cores because cells share nothing.
//!
//! Seed derivation rule: replica 0 of a cell keeps the scenario's pinned
//! seed (so sweep cells subsume the conformance/golden cells); replica
//! `r > 0` uses `splitmix64(scenario_seed XOR r * GOLDEN_GAMMA)` — a
//! stateless mix, so any cell can be re-run in isolation without walking
//! an RNG stream ([`derive_seed`]).
//!
//! The same executor ([`run_cells`]) backs golden-trace recording
//! (`driver::record_golden_traces`) and the engine-throughput bench, and
//! `miriam sweep --threads N` (see `config/cli.rs` / `main.rs`) writes the
//! aggregate report as `BENCH_sweep.json` (schema in EXPERIMENTS.md
//! §Sweep).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::driver::{self, RunOpts};
use crate::coordinator::scheduler_for;
use crate::coordinator::stats::RunStats;
use crate::gpu::spec::GpuSpec;
use crate::runtime::json::Json;
use crate::workloads::scenario::ScenarioSpec;

/// A sweep: the cartesian grid (scenarios × schedulers × seed replicas).
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// GPU preset name (resolved through `GpuSpec::by_name`).
    pub platform: String,
    /// Simulated window per cell (us) — metadata; the scenarios carry
    /// their own duration.
    pub duration_us: f64,
    /// The scenarios spanning the grid's first axis.
    pub scenarios: Vec<ScenarioSpec>,
    /// Scheduler names spanning the second axis.
    pub schedulers: Vec<String>,
    /// Seed replicas per (scenario, scheduler) cell; replica seeds come
    /// from [`derive_seed`].
    pub seeds: u32,
    /// Record per-cell canonical engine traces into
    /// [`CellResult::trace_json`] (the determinism suite turns this on;
    /// `BENCH_sweep.json` never embeds traces).
    pub trace: bool,
    /// Run every cell on the retained full-recompute rate oracle instead
    /// of the incremental engine path (the bench "before" leg).
    pub reference_rates: bool,
}

/// One completed grid cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Scenario name.
    pub scenario: String,
    /// Scheduler name.
    pub scheduler: String,
    /// Seed-replica index within the cell's (scenario, scheduler) pair.
    pub replica: u32,
    /// The derived workload seed the cell actually ran with.
    pub seed: u64,
    /// Completed critical tasks.
    pub completed_critical: usize,
    /// Completed normal tasks.
    pub completed_normal: usize,
    /// Kernel launches recorded on the timeline.
    pub launches: usize,
    /// Median critical-task latency (us; NaN when none completed).
    pub crit_p50_us: f64,
    /// p99 critical-task latency (us; NaN when none completed).
    pub crit_p99_us: f64,
    /// Mean critical-task latency (us; NaN when none completed).
    pub crit_mean_us: f64,
    /// Median normal-task latency (us; NaN when none completed).
    pub normal_p50_us: f64,
    /// Overall completed requests per second of simulated span.
    pub throughput_rps: f64,
    /// Critical completions past their deadline.
    pub deadline_misses_critical: u64,
    /// Normal completions past their deadline.
    pub deadline_misses_normal: u64,
    /// Average achieved occupancy over active SM time, [0, 1].
    pub achieved_occupancy: f64,
    /// Simulator events processed.
    pub events: u64,
    /// Host wall time of this cell's run (ns) — measured inside the run,
    /// so it is meaningful per cell even under parallel execution.
    pub wall_ns: u64,
    /// Canonical trace when `SweepSpec::trace` was set.
    pub trace_json: Option<String>,
}

impl CellResult {
    fn from_stats(scenario: &str, scheduler: &str, replica: u32, seed: u64,
                  mut st: RunStats) -> Self {
        let trace_json = st.trace.take().map(|t| t.to_canonical_json());
        CellResult {
            scenario: scenario.to_string(),
            scheduler: scheduler.to_string(),
            replica,
            seed,
            completed_critical: st.completed_critical(),
            completed_normal: st.completed_normal(),
            launches: st.timeline.len(),
            crit_p50_us: st.critical_latency_quantile_us(0.5),
            crit_p99_us: st.critical_latency_p99_us(),
            crit_mean_us: st.critical_latency_mean_us(),
            normal_p50_us: st.normal_latency_quantile_us(0.5),
            throughput_rps: st.throughput_rps(),
            deadline_misses_critical: st.deadline_misses_critical,
            deadline_misses_normal: st.deadline_misses_normal,
            achieved_occupancy: st.achieved_occupancy,
            events: st.events,
            wall_ns: st.wall_ns,
            trace_json,
        }
    }

    /// Simulator events per host second of this cell's own run.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 / (self.wall_ns as f64 / 1e9)
    }
}

/// Per-(scenario, scheduler) aggregate across seed replicas.
#[derive(Debug, Clone)]
pub struct Aggregate {
    /// Scenario name.
    pub scenario: String,
    /// Scheduler name.
    pub scheduler: String,
    /// Number of replicas aggregated.
    pub replicas: u32,
    /// Means over replicas with a finite value (NaN when none had one,
    /// e.g. zero critical completions everywhere).
    pub mean_crit_p50_us: f64,
    /// Mean p99 critical latency over replicas with a finite value.
    pub mean_crit_p99_us: f64,
    /// Mean throughput over replicas with a finite value.
    pub mean_throughput_rps: f64,
    /// Critical deadline misses summed over replicas.
    pub deadline_misses_critical: u64,
    /// Normal deadline misses summed over replicas.
    pub deadline_misses_normal: u64,
    /// Simulator events summed over replicas.
    pub events: u64,
    /// Per-cell wall time summed over replicas (ns).
    pub wall_ns: u64,
}

impl Aggregate {
    /// Events per second over the aggregate's summed wall time.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 / (self.wall_ns as f64 / 1e9)
    }
}

/// A completed sweep.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// GPU preset name.
    pub platform: String,
    /// Simulated window per cell (us).
    pub duration_us: f64,
    /// Worker threads the sweep ran on.
    pub threads: usize,
    /// Seed replicas per (scenario, scheduler) cell.
    pub seeds: u32,
    /// Scenario names, in grid order.
    pub scenarios: Vec<String>,
    /// Scheduler names, in grid order.
    pub schedulers: Vec<String>,
    /// Cells in deterministic grid order (scenario-major, then scheduler,
    /// then replica) — independent of worker interleaving.
    pub cells: Vec<CellResult>,
    /// Whole-sweep host wall time (seconds). Host timing (this and the
    /// per-cell `wall_ns`) varies run-to-run; every simulated field and
    /// trace is deterministic.
    pub wall_s: f64,
}

impl SweepReport {
    /// Simulator events summed over all cells.
    pub fn total_events(&self) -> u64 {
        self.cells.iter().map(|c| c.events).sum()
    }

    /// Aggregate simulation throughput: total events over summed per-cell
    /// wall time (not sweep wall time, which shrinks with threads).
    pub fn events_per_sec(&self) -> f64 {
        let wall: u64 = self.cells.iter().map(|c| c.wall_ns).sum();
        if wall == 0 {
            return 0.0;
        }
        self.total_events() as f64 / (wall as f64 / 1e9)
    }

    /// Events/sec over the cells of one scheduler (the coordinator bench
    /// leg compares `miriam` against `miriam-ref` with this).
    pub fn events_per_sec_for(&self, scheduler: &str) -> f64 {
        let (ev, wall) = self
            .cells
            .iter()
            .filter(|c| c.scheduler == scheduler)
            .fold((0u64, 0u64), |(e, w), c| (e + c.events, w + c.wall_ns));
        if wall == 0 {
            return 0.0;
        }
        ev as f64 / (wall as f64 / 1e9)
    }

    /// Per-(scenario, scheduler) aggregates in grid order.
    pub fn aggregates(&self) -> Vec<Aggregate> {
        let mut out = Vec::new();
        for sc in &self.scenarios {
            for sched in &self.schedulers {
                let cells: Vec<&CellResult> = self
                    .cells
                    .iter()
                    .filter(|c| &c.scenario == sc && &c.scheduler == sched)
                    .collect();
                if cells.is_empty() {
                    continue;
                }
                let finite_mean = |f: &dyn Fn(&CellResult) -> f64| {
                    let v: Vec<f64> =
                        cells.iter().map(|c| f(c)).filter(|x| x.is_finite())
                            .collect();
                    if v.is_empty() {
                        f64::NAN
                    } else {
                        v.iter().sum::<f64>() / v.len() as f64
                    }
                };
                out.push(Aggregate {
                    scenario: sc.clone(),
                    scheduler: sched.clone(),
                    replicas: cells.len() as u32,
                    mean_crit_p50_us: finite_mean(&|c| c.crit_p50_us),
                    mean_crit_p99_us: finite_mean(&|c| c.crit_p99_us),
                    mean_throughput_rps: finite_mean(&|c| c.throughput_rps),
                    deadline_misses_critical: cells
                        .iter()
                        .map(|c| c.deadline_misses_critical)
                        .sum(),
                    deadline_misses_normal: cells
                        .iter()
                        .map(|c| c.deadline_misses_normal)
                        .sum(),
                    events: cells.iter().map(|c| c.events).sum(),
                    wall_ns: cells.iter().map(|c| c.wall_ns).sum(),
                })
            }
        }
        out
    }

    /// The `BENCH_sweep.json` document (canonical key order, traces
    /// excluded; schema in EXPERIMENTS.md §Sweep). When both `miriam` and
    /// `miriam-ref` ran, a `coordinator_bench` section reports the
    /// zero-clone fast path's events/sec improvement over the retained
    /// pre-change path. When an isolation scheduler ran, an `isolation`
    /// section reports per-scenario isolation-vs-miriam comparison rows
    /// (EXPERIMENTS.md §Isolation); both sections are omitted otherwise,
    /// keeping pre-ISSUE-9 documents bitwise stable.
    pub fn to_json(&self) -> String {
        let num = |x: f64| Json::Num(x);
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("sweep".into()));
        obj.insert("platform".into(), Json::Str(self.platform.clone()));
        obj.insert("duration_us".into(), num(self.duration_us));
        obj.insert("threads".into(), num(self.threads as f64));
        obj.insert("seeds".into(), num(f64::from(self.seeds)));
        obj.insert("wall_s".into(), num(self.wall_s));
        obj.insert("total_events".into(), num(self.total_events() as f64));
        obj.insert("events_per_sec".into(), num(self.events_per_sec()));
        obj.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "schedulers".into(),
            Json::Arr(self.schedulers.iter().cloned().map(Json::Str).collect()),
        );
        let has = |s: &str| self.schedulers.iter().any(|x| x == s);
        if has("miriam") && has("miriam-ref") {
            let fast = self.events_per_sec_for("miriam");
            let refp = self.events_per_sec_for("miriam-ref");
            let mut cb = BTreeMap::new();
            cb.insert("events_per_sec_fast".into(), num(fast));
            cb.insert("events_per_sec_ref".into(), num(refp));
            cb.insert(
                "improvement".into(),
                num(if refp > 0.0 { fast / refp - 1.0 } else { f64::NAN }),
            );
            obj.insert("coordinator_bench".into(), Json::Obj(cb));
        }
        // Isolation-vs-elasticity comparison cells (ISSUE 9): one row per
        // (scenario, isolation scheduler) with the miriam ratios alongside
        // when miriam ran. Emitted only when an isolation scheduler is in
        // the grid, so mask-free sweeps stay bitwise identical to the
        // PR 8 document.
        let aggs = self.aggregates();
        if self.schedulers.iter().any(|s| s.starts_with("isolation")) {
            let mut rows = Vec::new();
            for a in &aggs {
                if !a.scheduler.starts_with("isolation") {
                    continue;
                }
                let miriam = aggs.iter().find(|m| {
                    m.scenario == a.scenario && m.scheduler == "miriam"
                });
                let mut m = BTreeMap::new();
                m.insert("scenario".into(), Json::Str(a.scenario.clone()));
                m.insert("scheduler".into(), Json::Str(a.scheduler.clone()));
                m.insert("mean_crit_p99_us".into(), num(a.mean_crit_p99_us));
                m.insert("mean_throughput_rps".into(),
                         num(a.mean_throughput_rps));
                if let Some(mi) = miriam {
                    m.insert("miriam_crit_p99_us".into(),
                             num(mi.mean_crit_p99_us));
                    m.insert("miriam_throughput_rps".into(),
                             num(mi.mean_throughput_rps));
                    // > 1: isolation's criticals are slower than miriam's.
                    m.insert("crit_p99_vs_miriam".into(),
                             num(a.mean_crit_p99_us / mi.mean_crit_p99_us));
                    // < 1: isolation completes less work than miriam.
                    m.insert("throughput_vs_miriam".into(),
                             num(a.mean_throughput_rps
                                 / mi.mean_throughput_rps));
                }
                rows.push(Json::Obj(m));
            }
            obj.insert("isolation".into(), Json::Arr(rows));
        }
        obj.insert(
            "aggregates".into(),
            Json::Arr(
                aggs.iter()
                    .map(|a| {
                        let mut m = BTreeMap::new();
                        m.insert("scenario".into(),
                                 Json::Str(a.scenario.clone()));
                        m.insert("scheduler".into(),
                                 Json::Str(a.scheduler.clone()));
                        m.insert("replicas".into(),
                                 num(f64::from(a.replicas)));
                        m.insert("mean_crit_p50_us".into(),
                                 num(a.mean_crit_p50_us));
                        m.insert("mean_crit_p99_us".into(),
                                 num(a.mean_crit_p99_us));
                        m.insert("mean_throughput_rps".into(),
                                 num(a.mean_throughput_rps));
                        m.insert("deadline_misses_critical".into(),
                                 num(a.deadline_misses_critical as f64));
                        m.insert("deadline_misses_normal".into(),
                                 num(a.deadline_misses_normal as f64));
                        m.insert("events".into(), num(a.events as f64));
                        m.insert("events_per_sec".into(),
                                 num(a.events_per_sec()));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        obj.insert(
            "cells".into(),
            Json::Arr(
                self.cells
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("scenario".into(),
                                 Json::Str(c.scenario.clone()));
                        m.insert("scheduler".into(),
                                 Json::Str(c.scheduler.clone()));
                        m.insert("replica".into(), num(f64::from(c.replica)));
                        m.insert("seed".into(), num(c.seed as f64));
                        m.insert("completed_critical".into(),
                                 num(c.completed_critical as f64));
                        m.insert("completed_normal".into(),
                                 num(c.completed_normal as f64));
                        m.insert("launches".into(), num(c.launches as f64));
                        m.insert("crit_p50_us".into(), num(c.crit_p50_us));
                        m.insert("crit_p99_us".into(), num(c.crit_p99_us));
                        m.insert("crit_mean_us".into(), num(c.crit_mean_us));
                        m.insert("normal_p50_us".into(),
                                 num(c.normal_p50_us));
                        m.insert("throughput_rps".into(),
                                 num(c.throughput_rps));
                        m.insert("deadline_misses_critical".into(),
                                 num(c.deadline_misses_critical as f64));
                        m.insert("deadline_misses_normal".into(),
                                 num(c.deadline_misses_normal as f64));
                        m.insert("achieved_occupancy".into(),
                                 num(c.achieved_occupancy));
                        m.insert("events".into(), num(c.events as f64));
                        m.insert("wall_ns".into(), num(c.wall_ns as f64));
                        m.insert("events_per_sec".into(),
                                 num(c.events_per_sec()));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}

/// The per-replica workload seed (see module docs for the rule). Replica 0
/// keeps the scenario's pinned seed; higher replicas decorrelate through a
/// stateless splitmix64 finalizer, so cell seeds never depend on sweep
/// shape, enumeration order, or thread count.
pub fn derive_seed(scenario_seed: u64, replica: u32) -> u64 {
    if replica == 0 {
        return scenario_seed;
    }
    let mut z = scenario_seed
        ^ (replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Run `run_one(i)` for every `i in 0..n` across a scoped worker pool of
/// at most `threads` workers pulling indexes off an atomic counter — the
/// concurrency skeleton shared by [`run_cells`] and the fleet grid
/// runner (`crate::fleet::run_fleet_grid`). Callers own per-index result
/// slots, so results stay position-stable regardless of worker
/// interleaving (the any-thread-count determinism contract).
pub(crate) fn run_indexed(n: usize, threads: usize,
                          run_one: impl Fn(usize) + Sync) {
    let workers = threads.max(1).min(n.max(1));
    if workers <= 1 {
        for i in 0..n {
            run_one(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                run_one(i);
            });
        }
    });
}

/// Run explicit (scenario, scheduler) cells across a scoped worker pool,
/// returning per-cell [`RunStats`] **in cell order** regardless of worker
/// interleaving. The shared executor behind [`run_sweep`], golden-trace
/// recording, and the engine-throughput bench. Panics on an unknown
/// scheduler name (callers validate first).
pub fn run_cells(gpu: &GpuSpec, cells: &[(ScenarioSpec, String)],
                 opts: RunOpts, threads: usize) -> Vec<RunStats> {
    let n = cells.len();
    let results: Vec<Mutex<Option<RunStats>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    run_indexed(n, threads, |i| {
        let (sc, sched) = &cells[i];
        let wl = sc.build();
        let mut s = scheduler_for(sched, &wl)
            .unwrap_or_else(|| panic!("unknown scheduler {sched}"));
        let st = driver::run_with(gpu.clone(), &wl, s.as_mut(), opts);
        *results[i].lock().unwrap() = Some(st);
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("cell ran"))
        .collect()
}

/// Run the whole grid. Deterministic for a given spec: the report's cells
/// (and traces, when enabled) are byte-identical across `threads` values.
pub fn run_sweep(spec: &SweepSpec, threads: usize)
                 -> Result<SweepReport, String> {
    let gpu = GpuSpec::by_name(&spec.platform)
        .ok_or_else(|| format!("unknown platform {}", spec.platform))?;
    if spec.scenarios.is_empty() {
        return Err("sweep needs at least one scenario".into());
    }
    if spec.schedulers.is_empty() {
        return Err("sweep needs at least one scheduler".into());
    }
    if spec.seeds == 0 {
        return Err("sweep needs seeds >= 1".into());
    }
    let probe = spec.scenarios[0].build();
    for s in &spec.schedulers {
        if scheduler_for(s, &probe).is_none() {
            return Err(format!("unknown scheduler {s}"));
        }
    }
    let mut keys: Vec<(usize, usize, u32)> = Vec::new();
    let mut cells: Vec<(ScenarioSpec, String)> = Vec::new();
    for (si, sc) in spec.scenarios.iter().enumerate() {
        for (ki, sched) in spec.schedulers.iter().enumerate() {
            for rep in 0..spec.seeds {
                let mut c = sc.clone();
                c.seed = derive_seed(sc.seed, rep);
                keys.push((si, ki, rep));
                cells.push((c, sched.clone()));
            }
        }
    }
    let opts = RunOpts { reference_rates: spec.reference_rates,
                         trace: spec.trace };
    let t0 = Instant::now();
    let stats = run_cells(&gpu, &cells, opts, threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let results = keys
        .iter()
        .zip(cells.iter())
        .zip(stats)
        .map(|((&(si, ki, rep), (c, _)), st)| {
            CellResult::from_stats(&spec.scenarios[si].name,
                                   &spec.schedulers[ki], rep, c.seed, st)
        })
        .collect();
    Ok(SweepReport {
        platform: spec.platform.clone(),
        duration_us: spec.duration_us,
        threads: threads.max(1),
        seeds: spec.seeds,
        scenarios: spec.scenarios.iter().map(|s| s.name.clone()).collect(),
        schedulers: spec.schedulers.clone(),
        cells: results,
        wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::scenario;

    #[test]
    fn derive_seed_rule() {
        // Replica 0 is the identity (sweep cells subsume conformance
        // cells); higher replicas are stable, distinct, decorrelated.
        assert_eq!(derive_seed(0x2B1, 0), 0x2B1);
        let a: Vec<u64> = (0..32).map(|r| derive_seed(0x2B1, r)).collect();
        let b: Vec<u64> = (0..32).map(|r| derive_seed(0x2B1, r)).collect();
        assert_eq!(a, b);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), a.len(), "replica seeds collide");
        assert_ne!(derive_seed(1, 1), derive_seed(2, 1));
    }

    #[test]
    fn rejects_bad_specs() {
        let base = SweepSpec {
            platform: "rtx2060".into(),
            duration_us: 10_000.0,
            scenarios: scenario::family(10_000.0).into_iter().take(1).collect(),
            schedulers: vec!["sequential".into()],
            seeds: 1,
            trace: false,
            reference_rates: false,
        };
        let mut bad = base.clone();
        bad.platform = "h100".into();
        assert!(run_sweep(&bad, 1).is_err());
        let mut bad = base.clone();
        bad.schedulers = vec!["fifo".into()];
        assert!(run_sweep(&bad, 1).is_err());
        let mut bad = base.clone();
        bad.seeds = 0;
        assert!(run_sweep(&bad, 1).is_err());
        let mut bad = base.clone();
        bad.scenarios.clear();
        assert!(run_sweep(&bad, 1).is_err());
    }

    #[test]
    fn report_shape_and_json() {
        let spec = SweepSpec {
            platform: "rtx2060".into(),
            duration_us: 8_000.0,
            scenarios: scenario::mdtb_scenarios(8_000.0)
                .into_iter()
                .take(1)
                .collect(),
            schedulers: vec!["sequential".into(), "multistream".into()],
            seeds: 2,
            trace: false,
            reference_rates: false,
        };
        let r = run_sweep(&spec, 2).unwrap();
        assert_eq!(r.cells.len(), 4);
        // Grid order: scenario-major, scheduler, replica.
        assert_eq!(r.cells[0].scheduler, "sequential");
        assert_eq!(r.cells[0].replica, 0);
        assert_eq!(r.cells[1].replica, 1);
        assert_eq!(r.cells[2].scheduler, "multistream");
        assert!(r.cells.iter().all(|c| c.events > 0 && c.wall_ns > 0));
        assert!(r.cells.iter().all(|c| c.trace_json.is_none()));
        assert!(r.total_events() > 0);
        let aggs = r.aggregates();
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].replicas, 2);
        let j = r.to_json();
        let doc = crate::runtime::json::parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("sweep"));
        assert_eq!(
            doc.get("cells").and_then(Json::as_arr).map(|a| a.len()),
            Some(4)
        );
        assert!(doc.get("coordinator_bench").is_none());
        // No isolation scheduler in the grid: the comparison section is
        // omitted, keeping the document bitwise stable vs PR 8.
        assert!(doc.get("isolation").is_none());
    }

    #[test]
    fn isolation_grid_emits_comparison_rows() {
        let spec = SweepSpec {
            platform: "rtx2060".into(),
            duration_us: 8_000.0,
            scenarios: scenario::family(8_000.0).into_iter().take(1).collect(),
            schedulers: vec![
                "miriam".into(),
                "isolation:70/30".into(),
                "isolation:70/30+spill".into(),
            ],
            seeds: 1,
            trace: false,
            reference_rates: false,
        };
        let r = run_sweep(&spec, 2).unwrap();
        assert_eq!(r.cells.len(), 3);
        let j = r.to_json();
        let doc = crate::runtime::json::parse(&j).expect("valid JSON");
        let rows = doc.get("isolation").and_then(Json::as_arr)
            .expect("isolation section present");
        assert_eq!(rows.len(), 2, "one row per isolation scheduler");
        for row in rows {
            assert!(row.get("scheduler").and_then(Json::as_str).unwrap()
                        .starts_with("isolation:"));
            assert!(row.get("crit_p99_vs_miriam").is_some());
            assert!(row.get("throughput_vs_miriam").is_some());
        }
        // Determinism across thread counts extends to the new columns.
        let r1 = run_sweep(&spec, 1).unwrap();
        let strip = |s: &str| {
            // wall_s / wall_ns / events_per_sec are host timing; cells and
            // aggregates containing them differ run to run. Compare the
            // deterministic isolation section only.
            let d = crate::runtime::json::parse(s).unwrap();
            let mut v = Vec::new();
            for row in d.get("isolation").and_then(Json::as_arr).unwrap() {
                v.push(format!("{row:?}"));
            }
            v
        };
        assert_eq!(strip(&j), strip(&r1.to_json()));
    }
}
