//! **Sequential** baseline (paper §8.1.3): one model at a time; the
//! running task owns the whole GPU. The critical queue is always served
//! first (the paper: "critical tasks run independently, occupy the GPU
//! resources, and can have optimal end-to-end latency"), normal tasks fill
//! the gaps — so critical latency is near-solo (plus the residual of a
//! non-preemptible normal task) and throughput is lowest.

use std::collections::VecDeque;

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::{Criticality, LaunchConfig};
use crate::gpu::stream::{LaunchTag, StreamId};

/// The Sequential baseline scheduler: one task on the GPU at a time,
/// critical queue always served first.
pub struct Sequential {
    stream: StreamId,
    critical: VecDeque<Req>,
    normal: VecDeque<Req>,
    /// (req id, last kernel tag) of the task currently on the GPU.
    running: Option<(u64, LaunchTag)>,
}

impl Sequential {
    /// A fresh Sequential scheduler (call `init` before use).
    pub fn new() -> Self {
        Sequential {
            stream: 0,
            critical: VecDeque::new(),
            normal: VecDeque::new(),
            running: None,
        }
    }

    fn start_next(&mut self, eng: &mut Engine) {
        if self.running.is_some() {
            return;
        }
        // Critical queue first; normal tasks only when it is empty.
        let req = self.critical.pop_front().or_else(|| self.normal.pop_front());
        let Some(req) = req else { return };
        let mut last = 0;
        for k in &req.model.kernels {
            last = eng.submit(self.stream, LaunchConfig::from_kernel(k),
                              req.criticality);
        }
        self.running = Some((req.id, last));
    }
}

impl Default for Sequential {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Sequential {
    fn name(&self) -> &str {
        "sequential"
    }

    fn init(&mut self, eng: &mut Engine) {
        self.stream = eng.add_stream(0);
    }

    fn on_request(&mut self, req: Req, eng: &mut Engine) {
        match req.criticality {
            Criticality::Critical => self.critical.push_back(req),
            Criticality::Normal => self.normal.push_back(req),
        }
        self.start_next(eng);
    }

    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine,
                     finished: &mut Vec<u64>) {
        if let Some((id, last)) = self.running {
            if comp.tag == last {
                finished.push(id);
                self.running = None;
                self.start_next(eng);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::coordinator::driver;
    use crate::gpu::spec::GpuSpec;
    use crate::workloads::arrival::Arrival;
    use crate::workloads::mdtb::{Source, Workload};
    use crate::workloads::models;

    #[test]
    fn tasks_never_overlap() {
        let wl = Workload {
            name: "t".into(),
            sources: vec![
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::ClosedLoop { clients: 1 },
                    criticality: Criticality::Critical,
                    deadline_us: None,
                },
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::ClosedLoop { clients: 1 },
                    criticality: Criticality::Normal,
                    deadline_us: None,
                },
            ],
            duration_us: 30_000.0,
            seed: 1,
        };
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        // Consecutive records in a single-stream FIFO cannot overlap.
        let mut recs = stats.timeline.clone();
        // NaN-safe (ISSUE 8 bugfix): total_cmp, like sorted_quantile —
        // the old partial_cmp(..).unwrap() panicked on any NaN start.
        recs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        for w in recs.windows(2) {
            assert!(w[1].start_us >= w[0].end_us - 1e-6,
                    "{} overlaps {}", w[1].name, w[0].name);
        }
    }

    #[test]
    fn critical_served_first() {
        // A 10Hz critical source against a closed-loop normal source:
        // both make progress, and the critical task's latency stays within
        // solo-exec + one normal-task residual.
        let wl = Workload {
            name: "t".into(),
            sources: vec![
                Source {
                    model: Arc::new(models::gru()),
                    arrival: Arrival::Uniform { rate_hz: 10.0 },
                    criticality: Criticality::Critical,
                    deadline_us: None,
                },
                Source {
                    model: Arc::new(models::cifarnet()),
                    arrival: Arrival::ClosedLoop { clients: 1 },
                    criticality: Criticality::Normal,
                    deadline_us: None,
                },
            ],
            duration_us: 400_000.0,
            seed: 1,
        };
        let stats = driver::run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(stats.completed_critical() > 0);
        assert!(stats.completed_normal() > 0);
    }
}
