//! **GPU Multi-stream with Priority** baseline (paper §8.1.3, the NVIDIA
//! Triton approach): kernels from both task classes are enqueued
//! immediately on separate streams; the critical stream has dispatch
//! priority but resident normal blocks are never evicted — so critical
//! kernels suffer the full intra-/inter-SM contention of whatever is
//! already on the GPU.

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::{Criticality, LaunchConfig};
use crate::gpu::stream::{LaunchTag, StreamId};

/// The Multi-stream + Priority baseline scheduler.
pub struct MultiStream {
    critical_stream: StreamId,
    /// Normal tasks round-robin across several streams (one per
    /// closed-loop client), so they overlap each other as well as the
    /// critical stream — the Triton-style free-for-all.
    normal_streams: Vec<StreamId>,
    next_normal: usize,
    /// (request id, last kernel tag) for every in-flight task.
    open: Vec<(u64, LaunchTag)>,
}

impl MultiStream {
    /// A fresh Multi-stream scheduler (call `init` before use).
    pub fn new() -> Self {
        MultiStream {
            critical_stream: 0,
            normal_streams: Vec::new(),
            next_normal: 0,
            open: Vec::new(),
        }
    }
}

impl Default for MultiStream {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for MultiStream {
    fn name(&self) -> &str {
        "multistream"
    }

    fn init(&mut self, eng: &mut Engine) {
        self.critical_stream = eng.add_stream(10);
        for _ in 0..3 {
            self.normal_streams.push(eng.add_stream(0));
        }
    }

    fn on_request(&mut self, req: Req, eng: &mut Engine) {
        let stream = match req.criticality {
            Criticality::Critical => self.critical_stream,
            Criticality::Normal => {
                let s = self.normal_streams[self.next_normal
                    % self.normal_streams.len()];
                self.next_normal += 1;
                s
            }
        };
        let mut last = 0;
        for k in &req.model.kernels {
            last = eng.submit(stream, LaunchConfig::from_kernel(k),
                              req.criticality);
        }
        self.open.push((req.id, last));
    }

    fn on_completion(&mut self, comp: &Completion, _eng: &mut Engine,
                     finished: &mut Vec<u64>) {
        if let Some(pos) = self.open.iter().position(|(_, t)| *t == comp.tag) {
            finished.push(self.open.swap_remove(pos).0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baselines::sequential::Sequential;
    use crate::coordinator::driver;
    use crate::gpu::spec::GpuSpec;
    use crate::workloads::mdtb;

    #[test]
    fn overlaps_and_outperforms_sequential_throughput() {
        let wl = mdtb::mdtb_a(100_000.0).build();
        let ms = driver::run(GpuSpec::rtx2060(), &wl, &mut MultiStream::new());
        let sq = driver::run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(ms.throughput_rps() > sq.throughput_rps(),
                "multistream {} <= sequential {}",
                ms.throughput_rps(), sq.throughput_rps());
    }

    #[test]
    fn critical_latency_degrades_vs_sequential() {
        // The paper's core motivation (Fig. 2 / Fig. 8): co-running
        // inflates critical latency under plain multi-stream.
        let wl = mdtb::mdtb_a(100_000.0).build();
        let ms = driver::run(GpuSpec::rtx2060(), &wl, &mut MultiStream::new());
        let sq = driver::run(GpuSpec::rtx2060(), &wl, &mut Sequential::new());
        assert!(ms.critical_latency_mean_us() > sq.critical_latency_mean_us(),
                "expected degradation: ms {} vs sq {}",
                ms.critical_latency_mean_us(), sq.critical_latency_mean_us());
    }
}
