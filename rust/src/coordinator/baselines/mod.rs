//! The paper's three comparison baselines (§8.1.3).

pub mod ib;
pub mod multistream;
pub mod sequential;

pub use ib::InterStreamBarrier;
pub use multistream::MultiStream;
pub use sequential::Sequential;
