//! **Inter-stream Barrier (IB)** baseline (paper §8.1.3, after Yu et al.
//! [39]): multi-stream execution where *normal-kernel dispatch* is
//! manually synchronized against the critical stream with inter-stream
//! barriers.
//!
//! Critical tasks run exactly as in Multi-stream (all kernels enqueued on
//! a priority stream at arrival). Normal kernels, however, are released
//! one at a time and only at critical-kernel *boundaries*: a normal kernel
//! may launch only when no critical kernel is mid-flight, and each release
//! pays a fixed barrier synchronization cost on top of the launch
//! overhead. Bounding concurrency this way protects the critical task
//! better than free-running Multi-stream, but the barriers serialize the
//! normal stream and add overhead — with frequently-launching critical
//! tasks the normal side starves and total throughput can fall below even
//! Sequential (the paper's MDTB-A observation, §8.2).

use std::collections::VecDeque;

use crate::coordinator::scheduler::{Req, Scheduler};
use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::{Criticality, LaunchConfig};
use crate::gpu::stream::{LaunchTag, StreamId};

/// Per-request kernel cursor for normal tasks.
struct TaskState {
    req_id: u64,
    model: crate::workloads::models::ModelRef,
    next_kernel: usize,
}

/// The Inter-stream Barrier baseline scheduler.
pub struct InterStreamBarrier {
    critical_stream: StreamId,
    normal_stream: StreamId,
    /// Critical tasks in flight: (req id, last kernel tag).
    critical_open: Vec<(u64, LaunchTag)>,
    /// Number of critical *kernels* currently in flight (submitted, not
    /// completed) — the barrier predicate.
    critical_kernels_inflight: usize,
    normal: VecDeque<TaskState>,
    /// The one outstanding normal kernel, if any: (tag, req id).
    normal_inflight: Option<(LaunchTag, u64)>,
    /// Barrier synchronization cost per normal-kernel release (us).
    pub barrier_us: f64,
}

impl InterStreamBarrier {
    /// A fresh IB scheduler with the default barrier cost (call `init`
    /// before use).
    pub fn new() -> Self {
        InterStreamBarrier {
            critical_stream: 0,
            normal_stream: 0,
            critical_open: Vec::new(),
            critical_kernels_inflight: 0,
            normal: VecDeque::new(),
            normal_inflight: None,
            barrier_us: 15.0,
        }
    }

    /// Release the next normal kernel if the barrier predicate holds:
    /// nothing critical mid-flight and no normal kernel outstanding.
    fn release_normal(&mut self, eng: &mut Engine) {
        if self.normal_inflight.is_some() || self.critical_kernels_inflight > 0 {
            return;
        }
        let Some(task) = self.normal.front_mut() else { return };
        let k = &task.model.kernels[task.next_kernel];
        let tag = eng.submit_delayed(self.normal_stream,
                                     LaunchConfig::from_kernel(k),
                                     Criticality::Normal, self.barrier_us);
        task.next_kernel += 1;
        self.normal_inflight = Some((tag, task.req_id));
    }
}

impl Default for InterStreamBarrier {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for InterStreamBarrier {
    fn name(&self) -> &str {
        "ib"
    }

    fn init(&mut self, eng: &mut Engine) {
        self.critical_stream = eng.add_stream(10);
        self.normal_stream = eng.add_stream(0);
    }

    fn on_request(&mut self, req: Req, eng: &mut Engine) {
        match req.criticality {
            Criticality::Critical => {
                // Free-running critical stream, but each kernel pays the
                // barrier cost needed to coordinate with the normal stream
                // (the "more synchronization barriers ... significant
                // overhead" effect of §8.2).
                let mut last = 0;
                for k in &req.model.kernels {
                    last = eng.submit_delayed(self.critical_stream,
                                              LaunchConfig::from_kernel(k),
                                              Criticality::Critical,
                                              self.barrier_us);
                    self.critical_kernels_inflight += 1;
                }
                self.critical_open.push((req.id, last));
            }
            Criticality::Normal => {
                self.normal.push_back(TaskState {
                    req_id: req.id,
                    model: req.model.clone(),
                    next_kernel: 0,
                });
                self.release_normal(eng);
            }
        }
    }

    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine,
                     finished: &mut Vec<u64>) {
        match comp.record.criticality {
            Criticality::Critical => {
                self.critical_kernels_inflight -= 1;
                if let Some(pos) = self
                    .critical_open
                    .iter()
                    .position(|(_, t)| *t == comp.tag)
                {
                    finished.push(self.critical_open.swap_remove(pos).0);
                }
            }
            Criticality::Normal => {
                if let Some((tag, req_id)) = self.normal_inflight {
                    if tag == comp.tag {
                        self.normal_inflight = None;
                        // Retire the task if that was its last kernel.
                        if let Some(front) = self.normal.front() {
                            if front.req_id == req_id
                                && front.next_kernel >= front.model.kernels.len()
                            {
                                finished.push(req_id);
                                self.normal.pop_front();
                            }
                        }
                    }
                }
            }
        }
        self.release_normal(eng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baselines::multistream::MultiStream;
    use crate::coordinator::driver;
    use crate::gpu::spec::GpuSpec;
    use crate::workloads::mdtb;

    #[test]
    fn completes_work() {
        let wl = mdtb::mdtb_a(100_000.0).build();
        let st = driver::run(GpuSpec::rtx2060(), &wl,
                             &mut InterStreamBarrier::new());
        assert!(st.completed_critical() > 0);
        assert!(st.completed_normal() > 0);
    }

    #[test]
    fn critical_latency_better_than_multistream() {
        // IB's whole point: bounded co-running protects the critical task
        // relative to unrestricted multi-stream.
        let wl = mdtb::mdtb_a(300_000.0).build();
        let ib = driver::run(GpuSpec::rtx2060(), &wl,
                             &mut InterStreamBarrier::new());
        let ms = driver::run(GpuSpec::rtx2060(), &wl, &mut MultiStream::new());
        assert!(
            ib.critical_latency_mean_us() < ms.critical_latency_mean_us(),
            "ib {} >= ms {}",
            ib.critical_latency_mean_us(),
            ms.critical_latency_mean_us()
        );
    }

    #[test]
    fn at_most_one_normal_kernel_inflight() {
        let wl = mdtb::mdtb_b(200_000.0).build();
        let st = driver::run(GpuSpec::rtx2060(), &wl,
                             &mut InterStreamBarrier::new());
        // Sweep the timeline: normal launches never overlap each other.
        let mut normals: Vec<_> = st
            .timeline
            .iter()
            .filter(|r| r.criticality == Criticality::Normal)
            .collect();
        // NaN-safe (ISSUE 8 bugfix): total_cmp, like sorted_quantile —
        // the old partial_cmp(..).unwrap() panicked on any NaN start.
        normals.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        for w in normals.windows(2) {
            assert!(w[1].start_us >= w[0].end_us - 1e-6,
                    "normal kernels overlapped");
        }
    }

    #[test]
    fn nan_start_sorts_instead_of_panicking() {
        // ISSUE 8 satellite: mirrors the sorted_quantile NaN regression
        // test for the timeline sorts here and in sequential.rs — a NaN
        // start lands last (total_cmp orders NaN after +inf) instead of
        // panicking the whole sweep.
        use crate::gpu::metrics::LaunchRecord;
        let rec = |start_us: f64| LaunchRecord {
            tag: 0,
            name: "k".into(),
            stream: 0,
            criticality: Criticality::Normal,
            submit_us: 0.0,
            start_us,
            end_us: start_us,
        };
        let mut recs = vec![rec(3.0), rec(f64::NAN), rec(1.0)];
        recs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        assert_eq!(recs[0].start_us, 1.0);
        assert_eq!(recs[1].start_us, 3.0);
        assert!(recs[2].start_us.is_nan());
    }
}
