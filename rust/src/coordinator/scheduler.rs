//! The scheduler interface all coordination policies implement.
//!
//! The driver ([`crate::coordinator::driver`]) owns the arrival process and
//! the simulator; a [`Scheduler`] decides *what to submit to which stream
//! and when* — exactly the degrees of freedom the paper's baselines and
//! Miriam differ in.

use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::Criticality;
use crate::workloads::models::ModelRef;

/// One inference request flowing through the system.
#[derive(Debug, Clone)]
pub struct Req {
    pub id: u64,
    /// Index of the originating source in the workload.
    pub source: usize,
    pub model: ModelRef,
    pub criticality: Criticality,
    pub arrival_us: f64,
}

/// Coordination policy.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Create streams, pre-generate elastic kernels, etc.
    fn init(&mut self, eng: &mut Engine);

    /// A request arrived (engine time == req.arrival_us).
    fn on_request(&mut self, req: Req, eng: &mut Engine);

    /// A launch completed. Returns ids of requests that finished with it.
    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine) -> Vec<u64>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use crate::workloads::models;

    #[test]
    fn req_is_cloneable_and_carries_model() {
        let r = Req {
            id: 1,
            source: 0,
            model: Arc::new(models::cifarnet()),
            criticality: Criticality::Normal,
            arrival_us: 0.0,
        };
        let r2 = r.clone();
        assert_eq!(r2.model.name, "cifarnet");
    }
}
