//! The scheduler interface all coordination policies implement.
//!
//! The driver ([`crate::coordinator::driver`]) owns the arrival process and
//! the simulator; a [`Scheduler`] decides *what to submit to which stream
//! and when* — exactly the degrees of freedom the paper's baselines and
//! Miriam differ in.

use std::sync::Arc;

use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::Criticality;
use crate::workloads::models::ModelRef;

/// One inference request flowing through the system.
#[derive(Debug, Clone)]
pub struct Req {
    /// Driver-assigned request id, unique within a run.
    pub id: u64,
    /// Index of the originating source in the workload.
    pub source: usize,
    /// The model this request runs (shared, never deep-cloned per request).
    pub model: ModelRef,
    /// Interned engine name id of each kernel in `model.kernels` (parallel
    /// vector), interned once per run by the driver at workload load — so
    /// per-request scheduling never hashes a kernel-name `String` (ISSUE 3
    /// zero-clone fast path). Valid for the engine of the current run only.
    pub name_ids: Arc<Vec<u32>>,
    /// Task class (critical tasks get the high-priority treatment).
    pub criticality: Criticality,
    /// Simulated arrival time (us).
    pub arrival_us: f64,
}

/// Coordination policy.
pub trait Scheduler {
    /// Stable scheduler name (CLI / report key). Parameterized schedulers
    /// (the isolation family: `isolation:70/30`, `isolation:70/30+spill`)
    /// build the name from their config, hence `&str` not `&'static str`.
    fn name(&self) -> &str;

    /// Create streams, pre-generate elastic kernels, etc.
    fn init(&mut self, eng: &mut Engine);

    /// A request arrived (engine time == req.arrival_us).
    fn on_request(&mut self, req: Req, eng: &mut Engine);

    /// A launch completed. Ids of requests that finished with it are
    /// *appended* to `finished` — a scratch buffer the driver clears and
    /// reuses across calls, so the steady-state completion path performs
    /// no per-event allocation (ISSUE 3 satellite).
    fn on_completion(&mut self, comp: &Completion, eng: &mut Engine,
                     finished: &mut Vec<u64>);

    /// Number of best-effort requests currently queued inside the policy,
    /// when the policy tracks one (`None` otherwise — the baselines keep
    /// per-class queues with different semantics). The online serving
    /// loop ([`crate::server::online`]) samples this after each arrival
    /// batch to report the peak best-effort queue depth per run.
    fn pending_normal(&self) -> Option<usize> {
        None
    }

    /// Best-effort cancellation of request `req_id` (ISSUE 8 recovery
    /// layer). Returns `true` when the policy removed every queued
    /// launch of the request and will never report it finished —
    /// already-dispatched work cannot be recalled (no preemption), so a
    /// request with resident launches is not cancellable. The default
    /// declines: baselines run every admitted request to completion.
    fn cancel(&mut self, _req_id: u64, _eng: &mut Engine) -> bool {
        false
    }

    /// Toggle brownout mode (ISSUE 8): while on, policies that shape
    /// best-effort work (Miriam's elastic shards) should degrade
    /// best-effort quality/latency instead of shedding. No-op for
    /// policies without that lever.
    fn set_brownout(&mut self, _on: bool) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::models;

    #[test]
    fn req_is_cloneable_and_carries_model_and_ids() {
        let model: ModelRef = Arc::new(models::cifarnet());
        let n = model.kernels.len();
        let r = Req {
            id: 1,
            source: 0,
            model,
            name_ids: Arc::new((0..n as u32).collect()),
            criticality: Criticality::Normal,
            arrival_us: 0.0,
        };
        let r2 = r.clone();
        assert_eq!(r2.model.name, "cifarnet");
        assert_eq!(r2.name_ids.len(), r2.model.kernels.len());
        // Cloning a request clones Arcs, not the underlying vectors.
        assert!(Arc::ptr_eq(&r.name_ids, &r2.name_ids));
    }
}
