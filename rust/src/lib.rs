//! **miriam** — a reproduction of *"Miriam: Exploiting Elastic Kernels for
//! Real-time Multi-DNN Inference on Edge GPU"* (Zhao et al., 2023) as a
//! Rust + JAX + Pallas three-layer stack.
//!
//! Layer map (DESIGN.md has the full inventory):
//!
//! * [`gpu`] — discrete-event edge-GPU simulator (the hardware substrate;
//!   this environment has no physical GPU).
//! * [`elastic`] — the paper's offline contribution: elastic-kernel
//!   generation (elastic grid Eq. 1, elastic block §6.1), design-space
//!   shrinking (Eq. 2, WIScore Eq. 4, OScore Eq. 5), and the
//!   source-to-source transform metadata (§6.4).
//! * [`coordinator`] — the paper's online contribution: the shaded-binary-
//!   tree shard former and greedy padding scheduler (§7), plus the three
//!   evaluation baselines (Sequential, Multi-stream, Inter-stream Barrier).
//! * [`workloads`] — the MDTB benchmark (Table 2), model kernel
//!   descriptors, arrival processes, and the LGSVL case-study trace.
//! * [`runtime`] — PJRT CPU client executing the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) — real model compute on the serving
//!   path, Python never involved at runtime.
//! * [`server`] — std-thread serving loop binding the coordinator to the
//!   runtime, plus the online admission-controlled serving pipeline
//!   ([`server::online`], `miriam serve-sim`).
//! * [`fleet`] — heterogeneous multi-GPU fleet serving: mixed `GpuSpec`
//!   presets, pluggable request routers, one admission controller in
//!   front of per-device coordinators (`miriam fleet-sim`).
//! * [`config`] — run configuration.
//!
//! ARCHITECTURE.md (repo root) walks one request's life through these
//! layers and maps where to add a new scheduler, arrival process, or
//! admission policy; README.md covers every CLI subcommand.

// Documentation is enforced: every public item carries rustdoc, and CI
// runs `cargo doc --no-deps` with `RUSTDOCFLAGS="-D warnings"` (ISSUE 4).
#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod elastic;
pub mod fleet;
pub mod gpu;
pub mod runtime;
pub mod server;
pub mod workloads;
