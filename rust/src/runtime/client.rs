//! PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times from the serving path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! The real implementation needs the `xla` crate (xla-rs bindings), which
//! the offline build does not carry, so it is gated behind the `pjrt`
//! feature. Without the feature a stub with the same API compiles in;
//! `Runtime::new` then always errors, `Server::start` propagates that
//! error, and the runtime integration tests skip themselves on
//! non-`pjrt` builds.

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;

    use anyhow::{anyhow, Context, Result};

    use crate::runtime::artifacts::{ArtifactEntry, Manifest};

    /// A compiled artifact ready to execute.
    pub struct ModelRuntime {
        /// Artifact name.
        pub name: String,
        /// Declared input shapes, in argument order.
        pub input_shapes: Vec<Vec<usize>>,
        /// Declared (first) output shape.
        pub output_shape: Vec<usize>,
        exe: xla::PjRtLoadedExecutable,
    }

    impl ModelRuntime {
        /// Execute on f32 inputs (one flat buffer per declared input).
        /// Returns the flattened first output.
        pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
            if inputs.len() != self.input_shapes.len() {
                return Err(anyhow!("{}: expected {} inputs, got {}", self.name,
                                 self.input_shapes.len(), inputs.len()));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (buf, shape) in inputs.iter().zip(&self.input_shapes) {
                let elems: usize = shape.iter().product();
                if buf.len() != elems {
                    return Err(anyhow!("{}: input len {} != shape {:?}",
                                     self.name, buf.len(), shape));
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .context("reshaping input literal")?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .context("fetching result buffer")?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = lit.to_tuple1().context("unwrapping result tuple")?;
            out.to_vec::<f32>().context("reading f32 result")
        }
    }

    /// The PJRT runtime: a CPU client plus compiled executables by name.
    pub struct Runtime {
        client: xla::PjRtClient,
        /// The artifact registry this runtime serves from.
        pub manifest: Manifest,
        compiled: HashMap<String, ModelRuntime>,
    }

    impl Runtime {
        /// Create a CPU PJRT client over the given artifact directory.
        pub fn new(manifest: Manifest) -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, manifest, compiled: HashMap::new() })
        }

        /// PJRT platform name (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch the cached) executable for a manifest entry.
        pub fn load(&mut self, name: &str) -> Result<&ModelRuntime> {
            if !self.compiled.contains_key(name) {
                let entry = self.manifest.entry(name)?.clone();
                let rt = self.compile_entry(&entry)?;
                self.compiled.insert(name.to_string(), rt);
            }
            Ok(&self.compiled[name])
        }

        fn compile_entry(&self, entry: &ArtifactEntry) -> Result<ModelRuntime> {
            let path = self.manifest.hlo_path(entry)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            Ok(ModelRuntime {
                name: entry.name.clone(),
                input_shapes: entry.inputs.iter().map(|t| t.shape.clone()).collect(),
                output_shape: entry
                    .outputs
                    .first()
                    .map(|t| t.shape.clone())
                    .unwrap_or_default(),
                exe,
            })
        }

        /// Names of all loadable model artifacts.
        pub fn model_names(&self) -> Vec<String> {
            self.manifest.of_kind("model").map(|e| e.name.clone()).collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use anyhow::{anyhow, Result};

    use crate::runtime::artifacts::Manifest;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: miriam was built \
        without the `pjrt` feature (the offline build carries no xla crate); \
        rebuild with `--features pjrt` and the xla dependency vendored";

    /// Stub with the real [`ModelRuntime`] API; never constructible because
    /// [`Runtime::new`] always errors in this build.
    pub struct ModelRuntime {
        /// Artifact name.
        pub name: String,
        /// Declared input shapes, in argument order.
        pub input_shapes: Vec<Vec<usize>>,
        /// Declared (first) output shape.
        pub output_shape: Vec<usize>,
    }

    impl ModelRuntime {
        /// Always errors: the `pjrt` feature is off in this build.
        pub fn run_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
            Err(anyhow!(UNAVAILABLE))
        }
    }

    /// Stub runtime: same surface as the PJRT-backed one, unavailable at
    /// run time.
    pub struct Runtime {
        /// The artifact registry (kept for API parity with the real one).
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Always errors: the `pjrt` feature is off in this build.
        pub fn new(_manifest: Manifest) -> Result<Self> {
            Err(anyhow!(UNAVAILABLE))
        }

        /// Reports "unavailable" (no PJRT client in this build).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Always errors: the `pjrt` feature is off in this build.
        pub fn load(&mut self, _name: &str) -> Result<&ModelRuntime> {
            Err(anyhow!(UNAVAILABLE))
        }

        /// Names of all loadable model artifacts.
        pub fn model_names(&self) -> Vec<String> {
            self.manifest.of_kind("model").map(|e| e.name.clone()).collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{ModelRuntime, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::{ModelRuntime, Runtime};
