//! Minimal JSON parser and canonical writer.
//!
//! The build environment is fully offline and `serde_json` is not in the
//! vendored crate set, so the manifest (a small, machine-generated file)
//! is parsed with this self-contained recursive-descent parser. It
//! supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (the manifest is ASCII).
//!
//! [`Json::to_canonical_string`] is the inverse direction, used by the
//! engine trace recorder (`gpu::trace`): object keys in sorted
//! (`BTreeMap`) order, no whitespace, and shortest-round-trip number
//! formatting — equal values always serialize to byte-identical strings,
//! the property the golden-trace conformance suite relies on.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` so canonical serialization sorts keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object member by key (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to u64, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Canonical serialization: sorted object keys, no whitespace,
    /// shortest-round-trip float formatting (Rust's `Display` for `f64`,
    /// which round-trips exactly through [`parse`]). Non-finite numbers
    /// have no JSON representation and serialize as `null`.
    pub fn to_canonical_string(&self) -> String {
        let mut out = String::new();
        self.write_canonical(&mut out);
        out
    }

    fn write_canonical(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_canonical(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_canonical(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub offset: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.i, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        self.skip_ws();
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        self.skip_ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            m.insert(key, self.value()?);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.b.get(self.i) {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or(JsonError {
                                    offset: self.i,
                                    msg: "bad \\u escape".into(),
                                })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| JsonError {
                                    offset: self.i,
                                    msg: "bad \\u escape".into(),
                                })?,
                                16,
                            )
                            .map_err(|_| JsonError {
                                offset: self.i,
                                msg: "bad \\u escape".into(),
                            })?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    let chunk = self.b.get(start..start + len).ok_or(JsonError {
                        offset: start,
                        msg: "truncated utf8".into(),
                    })?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|_| JsonError {
                        offset: start,
                        msg: "invalid utf8".into(),
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self
            .b
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError { offset: start, msg: e.to_string() })
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes() {
        assert_eq!(parse(r#""a\nb\t\"c\" A""#).unwrap(),
                   Json::Str("a\nb\t\"c\" A".into()));
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse("\"héllo → ∞\"").unwrap(),
                   Json::Str("héllo → ∞".into()));
    }

    #[test]
    fn errors_have_offsets() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("  [ ]  ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn canonical_writer_sorts_keys_and_round_trips() {
        let v = parse(r#"{"b": [1, 2.5, true, null], "a": {"y": "s", "x": -3}}"#)
            .unwrap();
        let s = v.to_canonical_string();
        assert_eq!(s, r#"{"a":{"x":-3,"y":"s"},"b":[1,2.5,true,null]}"#);
        // Round trip is exact and idempotent.
        let v2 = parse(&s).unwrap();
        assert_eq!(v2, v);
        assert_eq!(v2.to_canonical_string(), s);
    }

    #[test]
    fn canonical_writer_escapes_strings() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_canonical_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn canonical_writer_number_forms() {
        assert_eq!(Json::Num(5.0).to_canonical_string(), "5");
        assert_eq!(Json::Num(-0.25).to_canonical_string(), "-0.25");
        assert_eq!(Json::Num(f64::NAN).to_canonical_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_canonical_string(), "null");
        // Shortest-repr round trip: parse(write(x)) == x bit-for-bit.
        for x in [1.0 / 3.0, 1e-9, 123_456_789.123_456_79, 2.5e17] {
            let s = Json::Num(x).to_canonical_string();
            assert_eq!(parse(&s).unwrap(), Json::Num(x), "{s}");
        }
    }

    #[test]
    fn manifest_shaped_document() {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "cifarnet", "file": "cifarnet.hlo.txt", "kind": "model",
             "inputs": [{"shape": [32, 32, 3], "dtype": "f32"}],
             "outputs": [{"shape": [10], "dtype": "f32"}],
             "golden": {"input_seed": 42, "input_sha": "ab", "output": [0.5, -1.25]}}
        ]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|j| j.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![32, 32, 3]);
    }
}
