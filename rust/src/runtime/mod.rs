//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from JAX/Pallas) and executes them on
//! the XLA CPU client. Python is never on this path.
//!
//! * [`artifacts`] — manifest parsing + artifact registry.
//! * [`client`] — PJRT client wrapper (compile once, execute many).

pub mod artifacts;
pub mod json;
pub mod client;

pub use artifacts::{ArtifactEntry, Manifest};
pub use client::{ModelRuntime, Runtime};
