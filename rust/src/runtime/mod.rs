//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from JAX/Pallas) and executes them on
//! the XLA CPU client. Python is never on this path.
//!
//! * [`artifacts`] — manifest parsing + artifact registry.
//! * [`client`] — PJRT client wrapper (compile once, execute many).
//! * [`timewheel`] — the hierarchical timing wheel behind every arrival
//!   queue (ISSUE 7): O(1)-amortized event dispatch at 100k-tenant scale.

pub mod artifacts;
pub mod json;
pub mod client;
pub mod timewheel;

pub use artifacts::{ArtifactEntry, Manifest};
pub use client::{ModelRuntime, Runtime};
