//! AOT artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and locates the HLO-text files.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::runtime::json::{self, Json};

/// Tensor spec in the manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element dtype name (the manifest uses "f32").
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// Golden input/output vector for a model artifact.
#[derive(Debug, Clone)]
pub struct Golden {
    /// numpy RandomState seed that generated the golden input.
    pub input_seed: u64,
    /// SHA of the golden input buffer (integrity check).
    pub input_sha: String,
    /// Expected output vector.
    pub output: Vec<f32>,
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Artifact name (e.g. "cifarnet").
    pub name: String,
    /// HLO-text file name relative to the manifest directory.
    pub file: Option<String>,
    /// Artifact kind ("model", "matmul_shard", "golden").
    pub kind: String,
    /// Declared input tensors.
    pub inputs: Vec<TensorSpec>,
    /// Declared output tensors.
    pub outputs: Vec<TensorSpec>,
    /// Golden input/output pair, when recorded.
    pub golden: Option<Golden>,
    /// Matmul-shard extra: elastic sharding degree.
    pub degree: Option<u32>,
    /// Matmul-shard extra: rows covered per shard.
    pub rows: Option<u32>,
    /// Matmul golden extra: M dimension.
    pub m: Option<usize>,
    /// Matmul golden extra: K dimension.
    pub k: Option<usize>,
    /// Matmul golden extra: N dimension.
    pub n: Option<usize>,
    /// Matmul golden extra: input seed.
    pub x_seed: Option<u64>,
    /// Matmul golden extra: weight seed.
    pub w_seed: Option<u64>,
    /// Matmul golden extra: first 8 expected outputs.
    pub output_first8: Option<Vec<f32>>,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing name"))?
            .to_string();
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let golden = match j.get("golden") {
            Some(g) => Some(Golden {
                input_seed: g
                    .get("input_seed")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("golden missing input_seed"))?,
                input_sha: g
                    .get("input_sha")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                output: f32_vec(g.get("output")),
            }),
            None => None,
        };
        Ok(ArtifactEntry {
            name,
            file: j.get("file").and_then(Json::as_str).map(str::to_string),
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("model")
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            golden,
            degree: j.get("degree").and_then(Json::as_u64).map(|v| v as u32),
            rows: j.get("rows").and_then(Json::as_u64).map(|v| v as u32),
            m: j.get("m").and_then(Json::as_usize),
            k: j.get("k").and_then(Json::as_usize),
            n: j.get("n").and_then(Json::as_usize),
            x_seed: j.get("x_seed").and_then(Json::as_u64),
            w_seed: j.get("w_seed").and_then(Json::as_u64),
            output_first8: j.get("output_first8").map(|v| f32_vec(Some(v))),
        })
    }
}

fn f32_vec(j: Option<&Json>) -> Vec<f32> {
    j.and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
        .unwrap_or_default()
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version.
    pub version: u64,
    /// All artifact entries, in manifest order.
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (HLO paths resolve here).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let version = root
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        let artifacts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { version, artifacts, dir })
    }

    /// Default artifact directory: `$MIRIAM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("MIRIAM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// The entry named `name`, or an error listing the miss.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    /// Entries of a kind ("model", "matmul_shard", "golden").
    pub fn of_kind<'a>(&'a self, kind: &'a str)
                       -> impl Iterator<Item = &'a ArtifactEntry> + 'a {
        self.artifacts.iter().filter(move |e| e.kind == kind)
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> Result<PathBuf> {
        let f = entry
            .file
            .as_ref()
            .ok_or_else(|| anyhow!("artifact {} has no file", entry.name))?;
        Ok(self.dir.join(f))
    }
}

/// numpy-compatible random generation: the manifest's golden inputs are
/// `numpy.random.RandomState(seed).randn(*shape)`; this module regenerates
/// them bit-identically on the Rust side so the runtime integration tests
/// can verify artifact numerics end to end without Python.
pub mod npy_rand {
    /// Minimal MT19937 (numpy-compatible) generator.
    pub struct Mt19937 {
        mt: [u32; 624],
        idx: usize,
    }

    impl Mt19937 {
        /// Seeded exactly like `numpy.random.RandomState(seed)`.
        pub fn new(seed: u32) -> Self {
            let mut mt = [0u32; 624];
            mt[0] = seed;
            for i in 1..624 {
                mt[i] = 1812433253u32
                    .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                    .wrapping_add(i as u32);
            }
            Mt19937 { mt, idx: 624 }
        }

        fn generate(&mut self) {
            for i in 0..624 {
                let y = (self.mt[i] & 0x8000_0000)
                    | (self.mt[(i + 1) % 624] & 0x7fff_ffff);
                let mut next = y >> 1;
                if y & 1 != 0 {
                    next ^= 0x9908_b0df;
                }
                self.mt[i] = self.mt[(i + 397) % 624] ^ next;
            }
            self.idx = 0;
        }

        /// Next tempered 32-bit draw.
        pub fn next_u32(&mut self) -> u32 {
            if self.idx >= 624 {
                self.generate();
            }
            let mut y = self.mt[self.idx];
            self.idx += 1;
            y ^= y >> 11;
            y ^= (y << 7) & 0x9d2c_5680;
            y ^= (y << 15) & 0xefc6_0000;
            y ^ (y >> 18)
        }

        /// numpy's random_double: 53-bit resolution in [0, 1).
        pub fn next_f64(&mut self) -> f64 {
            let a = (self.next_u32() >> 5) as f64; // 27 bits
            let b = (self.next_u32() >> 6) as f64; // 26 bits
            (a * 67108864.0 + b) / 9007199254740992.0
        }
    }

    /// numpy `RandomState(seed).randn(n)` (float64 gauss via the polar
    /// method, f*x2 returned before the cached f*x1), cast to f32 —
    /// byte-identical to what `aot.py` hashed.
    pub fn randn(seed: u32, n: usize) -> Vec<f32> {
        let mut mt = Mt19937::new(seed);
        let mut out = Vec::with_capacity(n);
        let mut cached: Option<f64> = None;
        while out.len() < n {
            if let Some(g) = cached.take() {
                out.push(g as f32);
                continue;
            }
            loop {
                let x1 = 2.0 * mt.next_f64() - 1.0;
                let x2 = 2.0 * mt.next_f64() - 1.0;
                let r2 = x1 * x1 + x2 * x2;
                if r2 < 1.0 && r2 != 0.0 {
                    let f = (-2.0 * r2.ln() / r2).sqrt();
                    cached = Some(f * x1);
                    out.push((f * x2) as f32);
                    break;
                }
            }
        }
        out.truncate(n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_loads_when_built() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert_eq!(m.version, 1);
        assert!(m.of_kind("model").count() >= 6);
        assert!(m.of_kind("matmul_shard").count() >= 4);
        let cn = m.entry("cifarnet").unwrap();
        assert_eq!(cn.inputs[0].shape, vec![32, 32, 3]);
        assert!(m.hlo_path(cn).unwrap().exists());
        assert!(cn.golden.as_ref().is_some_and(|g| g.output.len() == 10));
    }

    #[test]
    fn missing_entry_is_error() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.entry("nonexistent").is_err());
    }

    #[test]
    fn mt19937_matches_numpy_first_draw() {
        // numpy.random.RandomState(42).random_sample() == 0.3745401188473625
        let mut mt = npy_rand::Mt19937::new(42);
        let v = mt.next_f64();
        assert!((v - 0.3745401188473625).abs() < 1e-15, "{v}");
    }

    #[test]
    fn randn_matches_numpy_first_values() {
        // numpy.random.RandomState(42).randn(4) ==
        // [ 0.49671415, -0.1382643 ,  0.64768854,  1.52302986]
        let v = npy_rand::randn(42, 4);
        let want = [0.49671415f32, -0.1382643, 0.64768854, 1.52302986];
        for (a, b) in v.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
