//! Hierarchical timing wheel: the arrival queue behind the batch driver,
//! the online serving loop, and the fleet loop (ISSUE 7).
//!
//! Replaces the old `BinaryHeap<Reverse<(TimeKey, usize)>>` arrival
//! machinery. A binary heap pays O(log n) per push/pop, which at
//! 100k-tenant scale puts the comparator on the hottest path in the
//! simulator. The wheel pays O(1) amortized per event: each entry is
//! bucketed by its arrival tick into one of [`LEVELS`] × [`SLOTS`]
//! slots, a per-level 64-bit occupancy bitmap finds the next non-empty
//! slot with a single `trailing_zeros`, and higher-level slots cascade
//! lazily (each entry cascades at most `LEVELS - 1` times over its whole
//! lifetime).
//!
//! # Ordering contract (load-bearing)
//!
//! [`TimingWheel::pop`] yields entries in exactly the order the old heap
//! did: ascending `(time, source index)` with [`f64::total_cmp`] on the
//! time — ties on time break by source index, so every golden trace and
//! committed `BENCH_*.json` byte is unchanged by the swap. The
//! wheel-vs-heap differential test (`rust/tests/wheel_vs_heap.rs`) pins
//! this over a million mixed arrivals, ties included.
//!
//! Entries pushed *behind* the wheel's read cursor (a closed-loop client
//! regenerating "now", a shed retry landing inside the batch currently
//! being drained) are merge-inserted into the sorted ready buffer, which
//! preserves the heap's semantics exactly: ordering is only ever defined
//! over the entries still queued.
//!
//! # Resolution
//!
//! Ticks are whole microseconds (`t as u64`); entries sharing a tick are
//! ordered by their exact `f64` time when their slot drains. Ten levels
//! of 64 slots cover 2^60 µs (~36k years of simulated time) with no
//! overflow list.
//!
//! # Allocation
//!
//! The warm wheel allocates nothing: slot buffers are recycled through
//! the ready buffer by pointer swap, and the cascade scratch buffer is
//! reused. `rust/tests/alloc_steady_state.rs` pins the steady-state
//! push/pop cycle at zero allocations.

/// Total-ordered `f64` time key, shared by the wheel's ready-buffer sort
/// and the wheel-vs-heap differential oracle (it lived in
/// `coordinator::driver` before ISSUE 7).
///
/// Ordering is [`f64::total_cmp`] — NaN sorts after +∞ instead of
/// comparing `Equal` to everything (the ISSUE 7 bugfix: the old
/// `partial_cmp(..).unwrap_or(Equal)` silently corrupted heap order in
/// release builds, where the `debug_assert!(t.is_finite())` guards
/// compile out). On the arrival path NaN is additionally rejected
/// loudly: [`TimingWheel::push`] asserts finiteness in release builds
/// too. `total_cmp` orders `-0.0 < +0.0`, which `partial_cmp` does not —
/// irrelevant here because arrival times are non-negative and never
/// produced as `-0.0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimeKey(
    /// The time in microseconds.
    pub f64,
);
impl Eq for TimeKey {}
impl PartialOrd for TimeKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimeKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// log2 of the slot count per level.
const SLOT_BITS: u32 = 6;
/// Slots per wheel level (64: one occupancy bit per `u64` bitmap bit).
pub const SLOTS: usize = 1 << SLOT_BITS;
/// Wheel levels. Level `i` spans 64^(i+1) ticks; ten levels cover
/// 2^60 µs of simulated time with no overflow list.
pub const LEVELS: usize = 10;
/// Largest representable tick (exclusive): one tick per microsecond.
const MAX_TICK: u64 = 1 << (SLOT_BITS * LEVELS as u32);

/// One wheel level: 64 entry buckets plus an occupancy bitmap (bit `s`
/// set ⇔ `slots[s]` is non-empty).
#[derive(Debug, Default)]
struct Level {
    occupied: u64,
    slots: Vec<Vec<(f64, usize)>>,
}

/// The hierarchical timing wheel. See the [module docs](self) for the
/// ordering and allocation contracts.
#[derive(Debug)]
pub struct TimingWheel {
    levels: Vec<Level>,
    /// Drained entries awaiting pop, sorted **descending** by
    /// `(TimeKey, src)` so [`pop`](Self::pop) is a `Vec::pop` from the
    /// back.
    ready: Vec<(f64, usize)>,
    /// All ticks `< cursor` have been drained into `ready` (or popped).
    cursor: u64,
    /// Cascade redistribution scratch (reused; see module docs).
    scratch: Vec<(f64, usize)>,
    len: usize,
}

impl Default for TimingWheel {
    fn default() -> Self {
        Self::new()
    }
}

impl TimingWheel {
    /// An empty wheel with its cursor at tick 0.
    pub fn new() -> Self {
        TimingWheel {
            levels: (0..LEVELS)
                .map(|_| Level {
                    occupied: 0,
                    slots: (0..SLOTS).map(|_| Vec::new()).collect(),
                })
                .collect(),
            ready: Vec::new(),
            cursor: 0,
            scratch: Vec::new(),
            len: 0,
        }
    }

    /// Number of queued entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entries are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queue `(t, src)`. `t` is in microseconds.
    ///
    /// # Panics
    ///
    /// In **all** build profiles when `t` is non-finite or negative — a
    /// NaN here used to corrupt the heap ordering silently in release
    /// builds (ISSUE 7 bugfix; regression-tested below), so the finite
    /// check is a release-mode error, not a `debug_assert!`.
    pub fn push(&mut self, t: f64, src: usize) {
        assert!(t.is_finite() && t >= 0.0,
                "arrival time must be finite and non-negative, got {t}");
        let tick = t as u64;
        assert!(tick < MAX_TICK, "arrival time {t} overflows the wheel");
        if tick < self.cursor {
            // Behind the read cursor: merge into the sorted (descending)
            // ready buffer. Equal keys insert *before* their twins, i.e.
            // pop *after* them — twins are bit-identical `(t, src)`
            // pairs, so the order among them is unobservable.
            let key = (TimeKey(t), src);
            let at = self
                .ready
                .partition_point(|&(rt, rs)| (TimeKey(rt), rs) > key);
            self.ready.insert(at, (t, src));
        } else {
            self.insert_wheel(tick, t, src);
        }
        self.len += 1;
    }

    /// The next entry in ascending `(time, src)` order, without removing
    /// it. `&mut` because the wheel advances its cursor lazily here.
    pub fn peek(&mut self) -> Option<(f64, usize)> {
        if self.ready.is_empty() {
            self.refill();
        }
        self.ready.last().copied()
    }

    /// Remove and return the next entry in ascending `(time, src)` order.
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        self.peek()?;
        let e = self.ready.pop();
        debug_assert!(e.is_some());
        self.len -= 1;
        e
    }

    /// Bucket `(t, src)` at the lowest level whose current block
    /// contains `tick` (callers guarantee `tick >= self.cursor`).
    fn insert_wheel(&mut self, tick: u64, t: f64, src: usize) {
        debug_assert!(tick >= self.cursor);
        let mut level = 0usize;
        while level + 1 < LEVELS
            && (tick >> (SLOT_BITS * (level as u32 + 1)))
                != (self.cursor >> (SLOT_BITS * (level as u32 + 1)))
        {
            level += 1;
        }
        let slot = ((tick >> (SLOT_BITS * level as u32)) & 63) as usize;
        self.levels[level].slots[slot].push((t, src));
        self.levels[level].occupied |= 1 << slot;
    }

    /// Drain the next non-empty level-0 slot into `ready` (sorted
    /// descending), cascading higher levels down as needed. No-op when
    /// the wheel holds no bucketed entries.
    fn refill(&mut self) {
        debug_assert!(self.ready.is_empty());
        if self.len == 0 {
            return;
        }
        loop {
            // Entries bucketed at a higher level before the cursor
            // entered their block sit in the slot *covering* the cursor;
            // cascade those down first or a fresher level-0 entry could
            // be drained ahead of them.
            self.normalize();
            // Next occupied level-0 slot at or after the cursor within
            // the cursor's current 64-tick block.
            let idx = (self.cursor & 63) as u32;
            let bits = self.levels[0].occupied & (!0u64 << idx);
            if bits != 0 {
                let slot = bits.trailing_zeros() as usize;
                self.levels[0].occupied &= !(1u64 << slot);
                let base = self.cursor >> SLOT_BITS;
                self.cursor = ((base << SLOT_BITS) | slot as u64) + 1;
                // Pointer-swap the slot's buffer out (the slot inherits
                // the empty ready buffer's capacity — buffers recycle,
                // the warm path allocates nothing).
                std::mem::swap(&mut self.ready,
                               &mut self.levels[0].slots[slot]);
                self.ready.sort_unstable_by(|a, b| {
                    (TimeKey(b.0), b.1).cmp(&(TimeKey(a.0), a.1))
                });
                return;
            }
            self.cascade();
        }
    }

    /// Cascade down every occupied slot that covers the cursor's current
    /// position (the slot at the cursor's own index, per level, top
    /// down). Freshly bucketed entries never land in a covering slot
    /// (bucketing picks the lowest level whose block differs, so the
    /// slot index is always strictly above the cursor's), so coverings
    /// only appear when the cursor crosses a block boundary — and are
    /// cleared here before any scan at the new position.
    fn normalize(&mut self) {
        for level in (1..LEVELS).rev() {
            let shift = SLOT_BITS * level as u32;
            let idx = ((self.cursor >> shift) & 63) as usize;
            if self.levels[level].occupied & (1u64 << idx) != 0 {
                self.redistribute(level, idx);
            }
        }
    }

    /// Redistribute the next occupied strictly-future higher-level slot
    /// down the wheel and jump the cursor to the start of its tick
    /// range. Covering slots are empty when this runs
    /// ([`normalize`](Self::normalize)), so the strictly-above scan
    /// cannot skip anything; every redistributed entry lands at a
    /// strictly lower level, so cascading terminates.
    fn cascade(&mut self) {
        for level in 1..LEVELS {
            let shift = SLOT_BITS * level as u32;
            let idx = ((self.cursor >> shift) & 63) as u32;
            let mask = if idx >= 63 { 0 } else { !0u64 << (idx + 1) };
            let bits = self.levels[level].occupied & mask;
            if bits == 0 {
                continue;
            }
            let slot = bits.trailing_zeros() as usize;
            let block = self.cursor >> (shift + SLOT_BITS);
            self.cursor = ((block << SLOT_BITS) | slot as u64) << shift;
            self.redistribute(level, slot);
            return;
        }
        unreachable!("timewheel: len > 0 but no occupied slot");
    }

    /// Re-bucket every entry of `levels[level].slots[slot]` relative to
    /// the current cursor, through the reused scratch buffer.
    fn redistribute(&mut self, level: usize, slot: usize) {
        self.levels[level].occupied &= !(1u64 << slot);
        let mut tmp = std::mem::take(&mut self.scratch);
        std::mem::swap(&mut tmp, &mut self.levels[level].slots[slot]);
        for &(t, src) in tmp.iter() {
            self.insert_wheel(t as u64, t, src);
        }
        tmp.clear();
        self.scratch = tmp;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(w: &mut TimingWheel) -> Vec<(f64, usize)> {
        let mut out = Vec::new();
        while let Some(e) = w.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_time_then_source_order() {
        let mut w = TimingWheel::new();
        for &(t, s) in
            &[(5.0, 2), (5.0, 1), (0.25, 9), (4_100.0, 0), (5.5, 1),
              (300_000.7, 3), (0.25, 4)]
        {
            w.push(t, s);
        }
        assert_eq!(w.len(), 7);
        assert_eq!(drain(&mut w),
                   vec![(0.25, 4), (0.25, 9), (5.0, 1), (5.0, 2), (5.5, 1),
                        (4_100.0, 0), (300_000.7, 3)]);
        assert!(w.is_empty());
    }

    #[test]
    fn same_tick_different_fraction_orders_by_exact_time() {
        let mut w = TimingWheel::new();
        w.push(7.9, 0);
        w.push(7.1, 1);
        w.push(7.5, 2);
        assert_eq!(drain(&mut w), vec![(7.1, 1), (7.5, 2), (7.9, 0)]);
    }

    #[test]
    fn push_behind_cursor_merges_into_ready_order() {
        let mut w = TimingWheel::new();
        w.push(10.0, 0);
        w.push(10.0, 2);
        w.push(50.0, 1);
        assert_eq!(w.peek(), Some((10.0, 0)));
        // Cursor is now past tick 10; these land behind it.
        w.push(10.0, 1);
        w.push(3.0, 7);
        assert_eq!(w.pop(), Some((3.0, 7)));
        assert_eq!(w.pop(), Some((10.0, 0)));
        assert_eq!(w.pop(), Some((10.0, 1)));
        assert_eq!(w.pop(), Some((10.0, 2)));
        assert_eq!(w.pop(), Some((50.0, 1)));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn cascades_across_level_boundaries() {
        let mut w = TimingWheel::new();
        // One entry per level reach: 64^1, 64^2, ... plus near neighbors.
        let times = [1.0, 63.0, 64.0, 4095.0, 4096.0, 262_144.0,
                     16_777_216.0, 1.5e9, 9.0e12];
        for (s, &t) in times.iter().enumerate() {
            w.push(t, s);
        }
        let got = drain(&mut w);
        let want: Vec<(f64, usize)> =
            times.iter().enumerate().map(|(s, &t)| (t, s)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn covering_slot_drains_before_fresher_level0_entries() {
        let mut w = TimingWheel::new();
        w.push(63.0, 0);
        w.push(70.0, 1); // buckets at level 1 (cursor still in block 0)
        assert_eq!(w.pop(), Some((63.0, 0))); // cursor crosses to tick 64
        w.push(100.0, 2); // lands at level 0 of the cursor's new block
        // 70.0 sits in the level-1 slot covering the cursor; it must
        // still drain before the fresher level-0 entry.
        assert_eq!(w.pop(), Some((70.0, 1)));
        assert_eq!(w.pop(), Some((100.0, 2)));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn interleaved_pop_push_closed_loop_style() {
        let mut w = TimingWheel::new();
        for s in 0..8 {
            w.push(s as f64, s);
        }
        let mut last = -1.0f64;
        for step in 0..10_000 {
            let (t, src) = w.pop().expect("population is constant");
            assert!(t >= last, "time went backwards at step {step}");
            last = t;
            w.push(t + 1.0 + (src as f64) * 0.13, src);
        }
    }

    #[test]
    fn timekey_totally_orders_nan() {
        use std::cmp::Ordering;
        // The ISSUE 7 regression: NaN used to compare Equal to
        // everything, silently corrupting heap order.
        assert_eq!(TimeKey(f64::NAN).cmp(&TimeKey(1.0)), Ordering::Greater);
        assert_eq!(TimeKey(1.0).cmp(&TimeKey(f64::NAN)), Ordering::Less);
        assert_eq!(TimeKey(f64::NAN).cmp(&TimeKey(f64::INFINITY)),
                   Ordering::Greater);
        assert_eq!(TimeKey(2.0).cmp(&TimeKey(2.0)), Ordering::Equal);
        assert_eq!(TimeKey(1.0).cmp(&TimeKey(2.0)), Ordering::Less);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn nan_arrival_is_rejected_loudly() {
        // Release-mode error, not a debug_assert: feeding a NaN arrival
        // must panic in every build profile.
        TimingWheel::new().push(f64::NAN, 0);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn infinite_arrival_is_rejected_loudly() {
        TimingWheel::new().push(f64::INFINITY, 0);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn negative_arrival_is_rejected_loudly() {
        TimingWheel::new().push(-1.0, 0);
    }
}
