//! The contention/rate model: how fast each resident block progresses.
//!
//! This encodes the paper's §4 taxonomy directly:
//!
//! * **intra-SM contention** — blocks co-resident on one SM compete for
//!   issue bandwidth/execution units. A block's standalone compute demand
//!   is `cap * min(1, (threads/max_threads) * latency_hiding)`: with enough
//!   warps (1/latency_hiding of the SM's thread slots) a DNN block can
//!   saturate the SM's FP units alone. When co-residents' demands
//!   oversubscribe the SM, everyone is scaled down proportionally.
//!   Additionally, blocks from *different kernels* sharing an SM interfere
//!   beyond slot arithmetic (L1/texture/shared-memory bank conflicts,
//!   divergent instruction mixes): each block pays a penalty scaling with
//!   the *thread share foreign kernels hold on its SM* — which is exactly
//!   the quantity Miriam's elastic blocks shrink (§6.1).
//! * **inter-SM contention** — all resident blocks on *all* SMs share DRAM
//!   bandwidth. Each block needs `bytes/flops * compute_rate` of bandwidth
//!   to keep pace (balanced roofline); when total demand exceeds the
//!   spec's bandwidth, memory-bound progress scales down globally.
//!
//! Between simulator events the rates are constant, so block completion
//! times are exact.
//!
//! Two evaluation paths implement the same model (EXPERIMENTS.md §Perf
//! change #4):
//!
//! * [`block_rates`] — the full-recompute reference: rebuilds every per-SM
//!   aggregate from the complete residency set on each call. O(resident)
//!   with allocations; retained as the differential-testing oracle and the
//!   engine's `reference_rates` mode.
//! * the O(1) helpers ([`standalone_demand`], [`intra_sm_scale`],
//!   [`foreign_penalty`], [`bandwidth_scale`]) — consume aggregates the
//!   engine maintains incrementally in [`SmState`] on block admit/release,
//!   so a steady-state event only touches the SMs that changed.
//!   [`block_rates_indexed`] wires them together over a `BlockWork` slice
//!   so property tests can pin both paths to each other.

use crate::gpu::sm::{BlockDemand, SmState};
use crate::gpu::spec::GpuSpec;

/// Tunable model parameters (calibration recorded in EXPERIMENTS.md §Calib).
#[derive(Debug, Clone)]
pub struct ContentionParams {
    /// How over-subscribable SM compute is w.r.t. thread share: a block
    /// with `max_threads/latency_hiding` threads can saturate the SM alone.
    pub latency_hiding: f64,
    /// Strength of cross-kernel intra-SM interference: a block whose SM is
    /// fraction `f` occupied by *foreign-kernel* threads runs at
    /// `1 / (1 + alpha * f)` of its entitled rate.
    pub foreign_interference: f64,
}

impl Default for ContentionParams {
    fn default() -> Self {
        ContentionParams { latency_hiding: 3.0, foreign_interference: 3.0 }
    }
}

/// Per-block inputs to the rate computation.
#[derive(Debug, Clone, Copy)]
pub struct BlockWork {
    /// SM the block is resident on.
    pub sm: u32,
    /// Threads in the block.
    pub threads: u32,
    /// FLOPs per block (total for the block).
    pub flops: f64,
    /// DRAM bytes per block.
    pub bytes: f64,
    /// Distinguishes which kernel the block belongs to (for the foreign-
    /// interference term); typically the launch tag.
    pub kernel: u64,
}

/// Compute the instantaneous progress rate (FLOP/us of the block's own
/// work) for every resident block. Output order matches input order.
pub fn block_rates(spec: &GpuSpec, params: &ContentionParams,
                   blocks: &[BlockWork]) -> Vec<f64> {
    let n_sms = spec.num_sms as usize;
    // Pass 1: per-SM compute-demand sums and per-(SM, kernel) thread sums.
    let mut sm_demand = vec![0.0f64; n_sms];
    let mut sm_threads = vec![0u32; n_sms];
    // (sm, kernel) -> threads; small linear maps (few kernels per SM).
    let mut sm_kernel_threads: Vec<Vec<(u64, u32)>> = vec![Vec::new(); n_sms];
    let mut demands = Vec::with_capacity(blocks.len());
    for b in blocks {
        let share = (b.threads as f64 / spec.max_threads_per_sm as f64)
            * params.latency_hiding;
        let demand = spec.flops_per_sm_us * share.min(1.0);
        demands.push(demand);
        let s = b.sm as usize;
        sm_demand[s] += demand;
        sm_threads[s] += b.threads;
        match sm_kernel_threads[s].iter_mut().find(|(k, _)| *k == b.kernel) {
            Some((_, t)) => *t += b.threads,
            None => sm_kernel_threads[s].push((b.kernel, b.threads)),
        }
    }
    // Pass 2: intra-SM scaling + foreign-interference -> compute rate.
    let mut compute_rate = Vec::with_capacity(blocks.len());
    for (b, demand) in blocks.iter().zip(&demands) {
        let s = b.sm as usize;
        let scale = if sm_demand[s] > spec.flops_per_sm_us {
            spec.flops_per_sm_us / sm_demand[s]
        } else {
            1.0
        };
        let own: u32 = sm_kernel_threads[s]
            .iter()
            .find(|(k, _)| *k == b.kernel)
            .map(|(_, t)| *t)
            .unwrap_or(0);
        let foreign_frac = (sm_threads[s] - own) as f64
            / spec.max_threads_per_sm as f64;
        let penalty = 1.0 / (1.0 + params.foreign_interference * foreign_frac);
        compute_rate.push(demand * scale * penalty);
    }
    // Pass 3: global bandwidth demand (inter-SM contention).
    let mut total_bw_demand = 0.0;
    for (b, cr) in blocks.iter().zip(&compute_rate) {
        if b.bytes > 0.0 && b.flops > 0.0 {
            total_bw_demand += cr * b.bytes / b.flops;
        }
    }
    let bw_scale = if total_bw_demand > spec.dram_bw_bytes_us {
        spec.dram_bw_bytes_us / total_bw_demand
    } else {
        1.0
    };
    // Pass 4: final progress rate. Memory-bound blocks are scaled by the
    // global factor; pure-compute blocks are not.
    blocks
        .iter()
        .zip(&compute_rate)
        .map(|(b, cr)| {
            if b.bytes > 0.0 && b.flops > 0.0 {
                cr * bw_scale
            } else {
                *cr
            }
        })
        .collect()
}

/// Standalone compute demand (FLOP/us) of a block with `threads` threads:
/// what the block would draw from its SM running alone.
pub fn standalone_demand(spec: &GpuSpec, params: &ContentionParams,
                         threads: u32) -> f64 {
    let share = (threads as f64 / spec.max_threads_per_sm as f64)
        * params.latency_hiding;
    spec.flops_per_sm_us * share.min(1.0)
}

/// Intra-SM oversubscription scale given the SM's summed standalone demand.
pub fn intra_sm_scale(spec: &GpuSpec, sm_demand: f64) -> f64 {
    if sm_demand > spec.flops_per_sm_us {
        spec.flops_per_sm_us / sm_demand
    } else {
        1.0
    }
}

/// Cross-kernel interference penalty for a block whose SM holds
/// `sm_threads` resident threads, `own_threads` of them from the block's
/// own kernel.
pub fn foreign_penalty(spec: &GpuSpec, params: &ContentionParams,
                       sm_threads: u32, own_threads: u32) -> f64 {
    let foreign_frac = (sm_threads - own_threads) as f64
        / spec.max_threads_per_sm as f64;
    1.0 / (1.0 + params.foreign_interference * foreign_frac)
}

/// Global DRAM-bandwidth scale applied to memory-coupled blocks given the
/// total bandwidth demand at current compute rates.
pub fn bandwidth_scale(spec: &GpuSpec, total_bw_demand: f64) -> f64 {
    if total_bw_demand > spec.dram_bw_bytes_us {
        spec.dram_bw_bytes_us / total_bw_demand
    } else {
        1.0
    }
}

/// Aggregate-indexed equivalent of [`block_rates`]: builds the per-SM
/// aggregates through [`SmState::admit`] (exactly how the engine maintains
/// them) and evaluates every block through the O(1) helpers. Property
/// tests compare this against the reference to pin both paths together.
pub fn block_rates_indexed(spec: &GpuSpec, params: &ContentionParams,
                           blocks: &[BlockWork]) -> Vec<f64> {
    let mut sms: Vec<SmState> =
        (0..spec.num_sms as usize).map(|_| SmState::empty()).collect();
    for b in blocks {
        let d = BlockDemand { threads: b.threads, smem: 0, regs: 0 };
        sms[b.sm as usize].admit(&d, b.kernel,
                                 standalone_demand(spec, params, b.threads));
    }
    let mut rates: Vec<f64> = blocks
        .iter()
        .map(|b| {
            let sm = &sms[b.sm as usize];
            standalone_demand(spec, params, b.threads)
                * intra_sm_scale(spec, sm.compute_demand)
                * foreign_penalty(spec, params, sm.threads_used,
                                  sm.own_threads(b.kernel))
        })
        .collect();
    let total_bw: f64 = blocks
        .iter()
        .zip(&rates)
        .filter(|(b, _)| b.bytes > 0.0 && b.flops > 0.0)
        .map(|(b, cr)| cr * b.bytes / b.flops)
        .sum();
    let bw = bandwidth_scale(spec, total_bw);
    for (b, r) in blocks.iter().zip(rates.iter_mut()) {
        if b.bytes > 0.0 && b.flops > 0.0 {
            *r *= bw;
        }
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GpuSpec {
        GpuSpec::rtx2060()
    }

    fn blk(sm: u32, threads: u32, flops: f64, bytes: f64, kernel: u64) -> BlockWork {
        BlockWork { sm, threads, flops, bytes, kernel }
    }

    fn no_foreign() -> ContentionParams {
        ContentionParams { foreign_interference: 0.0, ..Default::default() }
    }

    #[test]
    fn solo_small_block_rate_is_thread_share() {
        let s = spec();
        // 128/1024 threads * 3.0 hiding = 0.375 of SM peak.
        let r = block_rates(&s, &no_foreign(), &[blk(0, 128, 1e6, 0.0, 1)]);
        assert!((r[0] - s.flops_per_sm_us * 0.375).abs() < 1e-6);
    }

    #[test]
    fn solo_large_block_saturates_sm() {
        let s = spec();
        // 512/1024 * 3 = 1.5 -> clamped at 1.0.
        let r = block_rates(&s, &no_foreign(), &[blk(0, 512, 1e6, 0.0, 1)]);
        assert!((r[0] - s.flops_per_sm_us).abs() < 1e-6);
    }

    #[test]
    fn intra_sm_oversubscription_scales_down() {
        let s = spec();
        // Two 512-thread blocks of the same kernel: demands 1.0 + 1.0 ->
        // each gets 0.5, no foreign penalty.
        let p = ContentionParams::default();
        let r = block_rates(&s, &p, &[
            blk(0, 512, 1e6, 0.0, 1),
            blk(0, 512, 1e6, 0.0, 1),
        ]);
        assert!((r[0] - s.flops_per_sm_us * 0.5).abs() < 1e-6);
        assert!((r[1] - s.flops_per_sm_us * 0.5).abs() < 1e-6);
    }

    #[test]
    fn different_sms_do_not_compute_contend() {
        let s = spec();
        let r = block_rates(&s, &ContentionParams::default(), &[
            blk(0, 512, 1e6, 0.0, 1),
            blk(1, 512, 1e6, 0.0, 2),
        ]);
        assert!((r[0] - s.flops_per_sm_us).abs() < 1e-6);
        assert!((r[1] - s.flops_per_sm_us).abs() < 1e-6);
    }

    #[test]
    fn foreign_threads_penalize_both_kernels() {
        let s = spec();
        let p = ContentionParams { latency_hiding: 3.0, foreign_interference: 2.0 };
        // Same-kernel pair: pure slot sharing.
        let same = block_rates(&s, &p, &[
            blk(0, 512, 1e6, 0.0, 1),
            blk(0, 512, 1e6, 0.0, 1),
        ]);
        // Cross-kernel pair: extra interference, foreign frac = 0.5 each.
        let diff = block_rates(&s, &p, &[
            blk(0, 512, 1e6, 0.0, 1),
            blk(0, 512, 1e6, 0.0, 2),
        ]);
        assert!(diff[0] < same[0]);
        // penalty = 1/(1 + 2.0 * 512/1024) = 0.5
        assert!((same[0] / diff[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn smaller_foreign_blocks_interfere_less() {
        // The heart of the elastic-block mechanism: shrinking the padded
        // kernel's block threads reduces the critical block's penalty.
        let s = spec();
        let p = ContentionParams::default();
        let with_big = block_rates(&s, &p, &[
            blk(0, 512, 1e6, 0.0, 1), // critical
            blk(0, 512, 1e6, 0.0, 2), // fat normal block
        ]);
        let with_small = block_rates(&s, &p, &[
            blk(0, 512, 1e6, 0.0, 1), // critical
            blk(0, 128, 1e6, 0.0, 2), // elastic normal block
        ]);
        assert!(with_small[0] > with_big[0],
                "critical rate should improve with smaller co-resident: {} vs {}",
                with_small[0], with_big[0]);
    }

    #[test]
    fn bandwidth_oversubscription_slows_memory_bound_blocks() {
        let s = spec();
        // Very memory-hungry blocks on different SMs: intensity 0.1 FLOP/B.
        let blocks: Vec<_> = (0..4)
            .map(|i| blk(i, 512, 1e5, 1e6, i as u64 + 1))
            .collect();
        let r = block_rates(&s, &no_foreign(), &blocks);
        let solo = block_rates(&s, &no_foreign(), &blocks[..1]);
        assert!(r[0] < solo[0]);
        // Total consumed bandwidth equals the spec's bandwidth.
        let total_bw: f64 = r.iter().map(|cr| cr * 1e6 / 1e5).sum();
        assert!((total_bw - s.dram_bw_bytes_us).abs() / s.dram_bw_bytes_us < 1e-9);
    }

    #[test]
    fn pure_compute_blocks_ignore_bandwidth_pressure() {
        let s = spec();
        let r = block_rates(&s, &no_foreign(), &[
            blk(0, 512, 1e5, 1e7, 1), // bw hog
            blk(1, 512, 1e6, 0.0, 2), // pure compute
        ]);
        assert!((r[1] - s.flops_per_sm_us).abs() < 1e-6);
        assert!(r[0] < s.flops_per_sm_us);
    }

    #[test]
    fn indexed_path_matches_reference_exactly_here() {
        // Same input order -> same FP operation order -> bitwise equality.
        let s = spec();
        let p = ContentionParams::default();
        let blocks: Vec<_> = (0..48)
            .map(|i| blk(i % s.num_sms, 32 + 16 * (i % 20),
                         1e4 + i as f64 * 3.0e5,
                         if i % 3 == 0 { 0.0 } else { i as f64 * 1e4 },
                         (i % 5) as u64))
            .collect();
        let reference = block_rates(&s, &p, &blocks);
        let indexed = block_rates_indexed(&s, &p, &blocks);
        assert_eq!(reference.len(), indexed.len());
        for (a, b) in reference.iter().zip(&indexed) {
            assert!((a - b).abs() <= a.abs() * 1e-12,
                    "indexed {b} diverged from reference {a}");
        }
    }

    #[test]
    fn helper_factors_reassemble_reference_rate() {
        let s = spec();
        let p = ContentionParams::default();
        let blocks = [blk(0, 512, 1e6, 0.0, 1), blk(0, 384, 1e6, 0.0, 2)];
        let reference = block_rates(&s, &p, &blocks);
        let d0 = standalone_demand(&s, &p, 512);
        let d1 = standalone_demand(&s, &p, 384);
        let scale = intra_sm_scale(&s, d0 + d1);
        let r0 = d0 * scale * foreign_penalty(&s, &p, 896, 512);
        assert!((r0 - reference[0]).abs() < 1e-9, "{r0} vs {}", reference[0]);
    }

    #[test]
    fn bandwidth_scale_clamps_only_when_oversubscribed() {
        let s = spec();
        assert_eq!(bandwidth_scale(&s, 0.0), 1.0);
        assert_eq!(bandwidth_scale(&s, s.dram_bw_bytes_us * 0.5), 1.0);
        let over = bandwidth_scale(&s, s.dram_bw_bytes_us * 2.0);
        assert!((over - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rates_always_positive() {
        let s = spec();
        let p = ContentionParams::default();
        let blocks: Vec<_> = (0..64)
            .map(|i| blk(i % s.num_sms, 1 + (i % 512), 1.0 + i as f64, i as f64, i as u64))
            .collect();
        for r in block_rates(&s, &p, &blocks) {
            assert!(r > 0.0);
        }
    }
}
