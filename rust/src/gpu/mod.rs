//! The edge-GPU simulator substrate.
//!
//! The paper evaluates on physical CUDA devices (RTX 2060, Jetson AGX
//! Xavier); this environment has none, so the whole CUDA execution model
//! the paper relies on — SMs with thread/smem/register/block-slot budgets,
//! a priority block dispatcher, FIFO streams, intra-SM issue contention and
//! inter-SM DRAM-bandwidth contention — is implemented here as a
//! discrete-event simulator (see DESIGN.md "Hardware substitution").
//!
//! * [`spec`] — hardware presets (RTX 2060 / Xavier / TX2).
//! * [`kernel`] — kernel descriptors and launch configurations.
//! * [`sm`] — per-SM resource ledger (dispatch admission + contention
//!   aggregates).
//! * [`stream`] — FIFO priority streams.
//! * [`contention`] — the intra-/inter-SM rate model (reference and
//!   aggregate-indexed paths).
//! * [`names`] — kernel-name interning for the hot path.
//! * [`engine`] — the event loop.
//! * [`metrics`] — achieved occupancy, timelines.
//! * [`trace`] — optional event recorder + canonical trace serialization
//!   and trace diffing (the conformance-suite observation surface).

pub mod contention;
pub mod engine;
pub mod kernel;
pub mod metrics;
pub mod names;
pub mod sm;
pub mod spec;
pub mod stream;
pub mod trace;

pub use engine::{Completion, Engine, GpuSnapshot};
pub use kernel::{Criticality, KernelDesc, LaunchConfig};
pub use metrics::{LaunchRecord, SimMetrics};
pub use names::NameTable;
pub use spec::GpuSpec;
pub use stream::{LaunchTag, StreamId};
pub use trace::{Divergence, Trace, TraceEvent, TraceEventKind};
