//! Per-SM resource ledger.
//!
//! Tracks the four budgets that decide whether a thread block can be
//! dispatched to an SM (paper §3 "Kernel Execution on GPU"): thread slots,
//! shared memory, registers, and block slots. Exhaustion of any budget
//! forces the block to queue — the *inter-SM* wait component of kernel
//! latency (§4).
//!
//! Besides the admission budgets, the ledger maintains the contention
//! model's per-SM aggregates incrementally (EXPERIMENTS.md §Perf change
//! #4): the summed standalone compute demand of resident blocks and the
//! per-kernel resident thread totals. `admit`/`release` keep them current
//! so the rate refresh never rebuilds them from the full residency.

use crate::gpu::spec::GpuSpec;

/// A set of SMs a stream is allowed to place blocks on — the
/// hard-isolation placement constraint (ISSUE 9). One `u64` bit per SM;
/// every GPU preset has far fewer than 64 SMs, and the isolation
/// scheduler fails fast on any device the mask cannot address.
///
/// [`SmMask::ALL`] is the *sentinel* "no constraint": the engine keeps
/// the heap-based placement path for it, so mask-free dispatch is
/// bitwise unchanged. An explicit mask — even one covering every SM of
/// the device — takes the linear masked path, whose selection order is
/// pinned to match the heap's (see `Engine::pick_sm_masked`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SmMask(u64);

impl SmMask {
    /// The unconstrained sentinel: every stream starts here, and the
    /// engine dispatches it through the unmasked heap path.
    pub const ALL: SmMask = SmMask(u64::MAX);

    /// The SMs `start..end` (end exclusive; both at most 64). An empty
    /// range is a legal (empty) mask — a stream holding one must simply
    /// never be submitted to, since its blocks could never place.
    pub fn range(start: u32, end: u32) -> SmMask {
        assert!(start <= end && end <= 64,
                "SM range {start}..{end} outside [0, 64]");
        if start == end {
            return SmMask(0);
        }
        let hi = if end == 64 { u64::MAX } else { (1u64 << end) - 1 };
        let lo = (1u64 << start) - 1;
        SmMask(hi & !lo)
    }

    /// Whether `sm` is in the set.
    pub fn contains(self, sm: u32) -> bool {
        sm < 64 && self.0 & (1u64 << sm) != 0
    }

    /// Whether this is the unconstrained sentinel ([`SmMask::ALL`]).
    pub fn is_all(self) -> bool {
        self.0 == u64::MAX
    }

    /// Whether the set holds no SMs at all.
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Number of SMs in the set (64 for the sentinel).
    pub fn count(self) -> u32 {
        self.0.count_ones()
    }

    /// The union of two masks.
    pub fn union(self, other: SmMask) -> SmMask {
        SmMask(self.0 | other.0)
    }
}

/// Resource demand of one thread block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockDemand {
    /// Threads the block occupies.
    pub threads: u32,
    /// Shared memory the block occupies, bytes.
    pub smem: u32,
    /// Total registers the block occupies (= regs_per_thread * threads).
    pub regs: u32,
}

/// Mutable occupancy state of one SM.
#[derive(Debug, Clone)]
pub struct SmState {
    /// Thread slots in use.
    pub threads_used: u32,
    /// Shared memory in use, bytes.
    pub smem_used: u32,
    /// Registers in use.
    pub regs_used: u32,
    /// Thread blocks currently resident.
    pub blocks_resident: u32,
    /// Sum of resident blocks' standalone compute demand (FLOP/us) — the
    /// intra-SM oversubscription denominator of the rate model.
    pub compute_demand: f64,
    /// Resident thread totals per kernel (keyed by launch tag) — the
    /// foreign-interference numerator. A small linear map: at most
    /// `max_blocks_per_sm` kernels can share an SM.
    pub kernel_threads: Vec<(u64, u32)>,
}

impl SmState {
    /// A fully idle SM.
    pub fn empty() -> Self {
        SmState {
            threads_used: 0,
            smem_used: 0,
            regs_used: 0,
            blocks_resident: 0,
            compute_demand: 0.0,
            kernel_threads: Vec::new(),
        }
    }

    /// Can `d` be dispatched here under `spec`'s budgets?
    pub fn fits(&self, d: &BlockDemand, spec: &GpuSpec) -> bool {
        self.threads_used + d.threads <= spec.max_threads_per_sm
            && self.smem_used + d.smem <= spec.smem_per_sm
            && self.regs_used + d.regs <= spec.regs_per_sm
            && self.blocks_resident + 1 <= spec.max_blocks_per_sm
    }

    /// Admit a block of `kernel` with standalone compute demand `demand`
    /// (caller must have checked `fits`).
    pub fn admit(&mut self, d: &BlockDemand, kernel: u64, demand: f64) {
        self.threads_used += d.threads;
        self.smem_used += d.smem;
        self.regs_used += d.regs;
        self.blocks_resident += 1;
        self.compute_demand += demand;
        match self.kernel_threads.iter_mut().find(|(k, _)| *k == kernel) {
            Some((_, t)) => *t += d.threads,
            None => self.kernel_threads.push((kernel, d.threads)),
        }
    }

    /// Release a completed block's resources. `kernel` and `demand` must
    /// match the values passed to `admit`.
    pub fn release(&mut self, d: &BlockDemand, kernel: u64, demand: f64) {
        debug_assert!(self.threads_used >= d.threads);
        debug_assert!(self.smem_used >= d.smem);
        debug_assert!(self.regs_used >= d.regs);
        debug_assert!(self.blocks_resident >= 1);
        self.threads_used -= d.threads;
        self.smem_used -= d.smem;
        self.regs_used -= d.regs;
        self.blocks_resident -= 1;
        self.compute_demand -= demand;
        if let Some(pos) = self
            .kernel_threads
            .iter()
            .position(|(k, _)| *k == kernel)
        {
            debug_assert!(self.kernel_threads[pos].1 >= d.threads);
            self.kernel_threads[pos].1 -= d.threads;
            if self.kernel_threads[pos].1 == 0 {
                self.kernel_threads.swap_remove(pos);
            }
        }
        if self.blocks_resident == 0 {
            // Exact reset: the incremental f64 sum cannot drift across
            // idle periods (additions are not exactly reversible in FP).
            self.compute_demand = 0.0;
            self.kernel_threads.clear();
        }
    }

    /// Resident threads belonging to `kernel` (0 when absent).
    pub fn own_threads(&self, kernel: u64) -> u32 {
        self.kernel_threads
            .iter()
            .find(|(k, _)| *k == kernel)
            .map(|(_, t)| *t)
            .unwrap_or(0)
    }

    /// Free thread slots.
    pub fn free_threads(&self, spec: &GpuSpec) -> u32 {
        spec.max_threads_per_sm - self.threads_used
    }

    /// Resident warps (ceil of threads / warp size), the occupancy numerator.
    pub fn active_warps(&self, spec: &GpuSpec) -> u32 {
        self.threads_used.div_ceil(spec.warp_size)
    }

    /// Whether no blocks are resident.
    pub fn is_idle(&self) -> bool {
        self.blocks_resident == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(threads: u32, smem: u32) -> BlockDemand {
        BlockDemand { threads, smem, regs: threads * 32 }
    }

    #[test]
    fn sm_mask_range_membership() {
        let m = SmMask::range(4, 12);
        assert_eq!(m.count(), 8);
        assert!(!m.contains(3));
        assert!(m.contains(4));
        assert!(m.contains(11));
        assert!(!m.contains(12));
        assert!(!m.is_all());
        assert!(!m.is_empty());
    }

    #[test]
    fn sm_mask_edges() {
        assert!(SmMask::range(5, 5).is_empty());
        assert_eq!(SmMask::range(0, 64).count(), 64);
        assert!(SmMask::range(0, 64).is_all());
        assert!(SmMask::ALL.is_all());
        assert!(SmMask::ALL.contains(63));
        assert!(!SmMask::ALL.contains(64));
        let full = SmMask::range(0, 30).union(SmMask::range(21, 30));
        assert_eq!(full, SmMask::range(0, 30));
    }

    #[test]
    fn sm_mask_partition_is_disjoint() {
        let crit = SmMask::range(0, 21);
        let norm = SmMask::range(21, 30);
        for sm in 0..30 {
            assert!(crit.contains(sm) != norm.contains(sm));
        }
        assert_eq!(crit.union(norm), SmMask::range(0, 30));
    }

    #[test]
    #[should_panic]
    fn sm_mask_range_rejects_past_64() {
        let _ = SmMask::range(0, 65);
    }

    #[test]
    fn admit_release_round_trip() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        let b = d(256, 8192);
        assert!(sm.fits(&b, &spec));
        sm.admit(&b, 1, 100.0);
        assert_eq!(sm.threads_used, 256);
        assert_eq!(sm.blocks_resident, 1);
        assert_eq!(sm.free_threads(&spec), 768);
        assert_eq!(sm.own_threads(1), 256);
        assert!((sm.compute_demand - 100.0).abs() < 1e-12);
        sm.release(&b, 1, 100.0);
        assert!(sm.is_idle());
        assert_eq!(sm.threads_used, 0);
        assert_eq!(sm.own_threads(1), 0);
        assert_eq!(sm.compute_demand, 0.0);
    }

    #[test]
    fn thread_slot_exhaustion_blocks_admission() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        for _ in 0..4 {
            let b = d(256, 0);
            assert!(sm.fits(&b, &spec));
            sm.admit(&b, 1, 0.0);
        }
        // 1024/1024 threads used: a 1-thread block must queue.
        assert!(!sm.fits(&d(1, 0), &spec));
    }

    #[test]
    fn smem_exhaustion_blocks_admission() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        sm.admit(&d(32, 48 * 1024), 1, 0.0);
        assert!(!sm.fits(&d(32, 32 * 1024), &spec));
        assert!(sm.fits(&d(32, 16 * 1024), &spec));
    }

    #[test]
    fn block_slot_exhaustion() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        for _ in 0..spec.max_blocks_per_sm {
            sm.admit(&d(1, 0), 1, 0.0);
        }
        assert!(!sm.fits(&d(1, 0), &spec));
    }

    #[test]
    fn register_exhaustion() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        // 512 threads * 64 regs = 32768; two fit (65536), third does not.
        let b = BlockDemand { threads: 512, smem: 0, regs: 512 * 64 };
        sm.admit(&b, 1, 0.0);
        assert!(sm.fits(&BlockDemand { threads: 256, smem: 0, regs: 256 * 64 }, &spec));
        sm.admit(&BlockDemand { threads: 256, smem: 0, regs: 256 * 64 }, 1, 0.0);
        assert!(!sm.fits(&BlockDemand { threads: 256, smem: 0, regs: 256 * 128 }, &spec));
    }

    #[test]
    fn warp_rounding() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        sm.admit(&d(33, 0), 1, 0.0); // 33 threads -> 2 warps
        assert_eq!(sm.active_warps(&spec), 2);
    }

    #[test]
    fn kernel_threads_tracks_per_kernel_totals() {
        let mut sm = SmState::empty();
        sm.admit(&d(128, 0), 7, 10.0);
        sm.admit(&d(128, 0), 7, 10.0);
        sm.admit(&d(64, 0), 9, 5.0);
        assert_eq!(sm.own_threads(7), 256);
        assert_eq!(sm.own_threads(9), 64);
        assert_eq!(sm.own_threads(4), 0);
        assert!((sm.compute_demand - 25.0).abs() < 1e-12);
        sm.release(&d(128, 0), 7, 10.0);
        assert_eq!(sm.own_threads(7), 128);
        sm.release(&d(128, 0), 7, 10.0);
        assert_eq!(sm.own_threads(7), 0);
        // Kernel 9 still resident: entry for 7 removed, 9 intact.
        assert_eq!(sm.kernel_threads.len(), 1);
        sm.release(&d(64, 0), 9, 5.0);
        assert!(sm.is_idle());
        assert!(sm.kernel_threads.is_empty());
        assert_eq!(sm.compute_demand, 0.0);
    }
}
