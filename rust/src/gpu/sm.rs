//! Per-SM resource ledger.
//!
//! Tracks the four budgets that decide whether a thread block can be
//! dispatched to an SM (paper §3 "Kernel Execution on GPU"): thread slots,
//! shared memory, registers, and block slots. Exhaustion of any budget
//! forces the block to queue — the *inter-SM* wait component of kernel
//! latency (§4).

use crate::gpu::spec::GpuSpec;

/// Resource demand of one thread block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockDemand {
    pub threads: u32,
    pub smem: u32,
    pub regs: u32, // total registers = regs_per_thread * threads
}

/// Mutable occupancy state of one SM.
#[derive(Debug, Clone)]
pub struct SmState {
    pub threads_used: u32,
    pub smem_used: u32,
    pub regs_used: u32,
    pub blocks_resident: u32,
}

impl SmState {
    pub fn empty() -> Self {
        SmState { threads_used: 0, smem_used: 0, regs_used: 0, blocks_resident: 0 }
    }

    /// Can `d` be dispatched here under `spec`'s budgets?
    pub fn fits(&self, d: &BlockDemand, spec: &GpuSpec) -> bool {
        self.threads_used + d.threads <= spec.max_threads_per_sm
            && self.smem_used + d.smem <= spec.smem_per_sm
            && self.regs_used + d.regs <= spec.regs_per_sm
            && self.blocks_resident + 1 <= spec.max_blocks_per_sm
    }

    /// Admit a block (caller must have checked `fits`).
    pub fn admit(&mut self, d: &BlockDemand) {
        self.threads_used += d.threads;
        self.smem_used += d.smem;
        self.regs_used += d.regs;
        self.blocks_resident += 1;
    }

    /// Release a completed block's resources.
    pub fn release(&mut self, d: &BlockDemand) {
        debug_assert!(self.threads_used >= d.threads);
        debug_assert!(self.smem_used >= d.smem);
        debug_assert!(self.regs_used >= d.regs);
        debug_assert!(self.blocks_resident >= 1);
        self.threads_used -= d.threads;
        self.smem_used -= d.smem;
        self.regs_used -= d.regs;
        self.blocks_resident -= 1;
    }

    /// Free thread slots.
    pub fn free_threads(&self, spec: &GpuSpec) -> u32 {
        spec.max_threads_per_sm - self.threads_used
    }

    /// Resident warps (ceil of threads / warp size), the occupancy numerator.
    pub fn active_warps(&self, spec: &GpuSpec) -> u32 {
        self.threads_used.div_ceil(spec.warp_size)
    }

    pub fn is_idle(&self) -> bool {
        self.blocks_resident == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(threads: u32, smem: u32) -> BlockDemand {
        BlockDemand { threads, smem, regs: threads * 32 }
    }

    #[test]
    fn admit_release_round_trip() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        let b = d(256, 8192);
        assert!(sm.fits(&b, &spec));
        sm.admit(&b);
        assert_eq!(sm.threads_used, 256);
        assert_eq!(sm.blocks_resident, 1);
        assert_eq!(sm.free_threads(&spec), 768);
        sm.release(&b);
        assert!(sm.is_idle());
        assert_eq!(sm.threads_used, 0);
    }

    #[test]
    fn thread_slot_exhaustion_blocks_admission() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        for _ in 0..4 {
            let b = d(256, 0);
            assert!(sm.fits(&b, &spec));
            sm.admit(&b);
        }
        // 1024/1024 threads used: a 1-thread block must queue.
        assert!(!sm.fits(&d(1, 0), &spec));
    }

    #[test]
    fn smem_exhaustion_blocks_admission() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        sm.admit(&d(32, 48 * 1024));
        assert!(!sm.fits(&d(32, 32 * 1024), &spec));
        assert!(sm.fits(&d(32, 16 * 1024), &spec));
    }

    #[test]
    fn block_slot_exhaustion() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        for _ in 0..spec.max_blocks_per_sm {
            sm.admit(&d(1, 0));
        }
        assert!(!sm.fits(&d(1, 0), &spec));
    }

    #[test]
    fn register_exhaustion() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        // 512 threads * 64 regs = 32768; two fit (65536), third does not.
        let b = BlockDemand { threads: 512, smem: 0, regs: 512 * 64 };
        sm.admit(&b);
        assert!(sm.fits(&BlockDemand { threads: 256, smem: 0, regs: 256 * 64 }, &spec));
        sm.admit(&BlockDemand { threads: 256, smem: 0, regs: 256 * 64 });
        assert!(!sm.fits(&BlockDemand { threads: 256, smem: 0, regs: 256 * 128 }, &spec));
    }

    #[test]
    fn warp_rounding() {
        let spec = GpuSpec::rtx2060();
        let mut sm = SmState::empty();
        sm.admit(&d(33, 0)); // 33 threads -> 2 warps
        assert_eq!(sm.active_warps(&spec), 2);
    }
}
