//! Engine trace recording: an optional observation surface over the
//! event loop (ISSUE 2 tentpole).
//!
//! When enabled ([`crate::gpu::engine::Engine::with_trace`]) the engine
//! appends one compact [`TraceEvent`] per submit, launch activation,
//! block placement, and launch completion — interned name id, stream/SM
//! id, and timestamp; no strings or allocations beyond the event vector
//! push, so recording stays off the critical path and costs nothing at
//! all when disabled (a single `Option` branch per hook).
//!
//! A finished [`Trace`] serializes canonically through
//! [`crate::runtime::json`] (sorted keys, shortest-round-trip floats):
//! two runs are behaviourally identical iff their canonical strings are
//! byte-identical, which is exactly the determinism contract the
//! conformance suite (`rust/tests/conformance_traces.rs`) pins. For
//! cross-implementation comparison (incremental vs reference rate paths,
//! golden files recorded on another host) [`Trace::diff`] compares
//! structurally with a relative time tolerance and reports
//! [`Divergence`]s instead of a bare bool.

use std::collections::BTreeMap;
use std::fmt;

use crate::gpu::names::NameTable;
use crate::runtime::json::{self, Json};

/// What happened at a trace event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEventKind {
    /// A launch entered a stream queue.
    Submit,
    /// A queued launch became its stream's active head (launch overhead
    /// starts running).
    Activate,
    /// One thread block of the active launch landed on an SM.
    BlockPlace,
    /// The launch's last block retired.
    Complete,
}

impl TraceEventKind {
    /// One-letter code used in the canonical serialization.
    pub fn code(self) -> &'static str {
        match self {
            TraceEventKind::Submit => "S",
            TraceEventKind::Activate => "A",
            TraceEventKind::BlockPlace => "P",
            TraceEventKind::Complete => "C",
        }
    }

    /// Inverse of [`TraceEventKind::code`].
    pub fn from_code(code: &str) -> Option<Self> {
        match code {
            "S" => Some(TraceEventKind::Submit),
            "A" => Some(TraceEventKind::Activate),
            "P" => Some(TraceEventKind::BlockPlace),
            "C" => Some(TraceEventKind::Complete),
            _ => None,
        }
    }
}

/// One recorded engine event, compact form: 8-byte time, launch tag,
/// interned name id, and a location that is the stream id for
/// submit/activate/complete and the SM id for block placements.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// What happened.
    pub kind: TraceEventKind,
    /// Simulated time of the event (us).
    pub t_us: f64,
    /// Launch tag the event belongs to.
    pub tag: u64,
    /// Interned kernel-name id (resolved through [`Trace::names`]).
    pub name_id: u32,
    /// Stream id for submit/activate/complete, SM id for block placements.
    pub loc: u32,
}

/// The engine-side accumulator (lives inside the engine; strings are
/// resolved only when the trace is taken).
#[derive(Debug, Default)]
pub struct TraceRecorder {
    events: Vec<TraceEvent>,
}

impl TraceRecorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one event (called from the engine's lifecycle hooks).
    #[inline]
    pub fn record(
        &mut self,
        kind: TraceEventKind,
        t_us: f64,
        tag: u64,
        name_id: u32,
        loc: u32,
    ) {
        self.events.push(TraceEvent { kind, t_us, tag, name_id, loc });
    }

    /// Number of events recorded so far.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether nothing was recorded yet.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Freeze into a [`Trace`], snapshotting the engine's name table so
    /// interned ids resolve without the engine.
    pub fn into_trace(self, names: &NameTable) -> Trace {
        Trace {
            names: names.iter().map(|(_, n)| n.to_string()).collect(),
            events: self.events,
        }
    }
}

/// A complete recorded run: the event list plus the interned-name table
/// snapshot (index = name id).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Interned-name table snapshot (index = name id).
    pub names: Vec<String>,
    /// The recorded events, in emission order.
    pub events: Vec<TraceEvent>,
}

/// One point where two traces disagree.
#[derive(Debug, Clone, PartialEq)]
pub struct Divergence {
    /// Event index (or the shorter trace's length for a length mismatch).
    pub index: usize,
    /// Which event field disagreed (`kind`/`tag`/`name`/`loc`/`t_us`/
    /// `length`).
    pub field: &'static str,
    /// The expected side's value, rendered.
    pub expected: String,
    /// The actual side's value, rendered.
    pub actual: String,
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "event {}: {} expected {}, got {}",
            self.index, self.field, self.expected, self.actual
        )
    }
}

/// Divergences reported per diff are capped here; beyond the cap the two
/// traces have materially different trajectories and more rows add noise.
const MAX_DIVERGENCES: usize = 64;

impl Trace {
    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the trace holds no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Resolved kernel name of an event ("?" for an id outside the table).
    pub fn name_of(&self, ev: &TraceEvent) -> &str {
        self.names
            .get(ev.name_id as usize)
            .map(String::as_str)
            .unwrap_or("?")
    }

    /// Events of one kind.
    pub fn count_of(&self, kind: TraceEventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Canonical serialization: `{"events":[[code,t,tag,name,loc],...],
    /// "names":[...],"version":1}` with sorted object keys and
    /// shortest-round-trip number formatting — byte-stable for identical
    /// runs, machine-readable through [`json::parse`].
    pub fn to_canonical_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("version".to_string(), Json::Num(1.0));
        obj.insert(
            "names".to_string(),
            Json::Arr(self.names.iter().map(|n| Json::Str(n.clone())).collect()),
        );
        obj.insert(
            "events".to_string(),
            Json::Arr(
                self.events
                    .iter()
                    .map(|e| {
                        Json::Arr(vec![
                            Json::Str(e.kind.code().to_string()),
                            Json::Num(e.t_us),
                            Json::Num(e.tag as f64),
                            Json::Num(e.name_id as f64),
                            Json::Num(e.loc as f64),
                        ])
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj).to_canonical_string()
    }

    /// Parse a canonical (or any schema-compatible) trace document.
    pub fn from_json_str(text: &str) -> Result<Trace, String> {
        let doc = json::parse(text).map_err(|e| e.to_string())?;
        let version = doc
            .get("version")
            .and_then(Json::as_u64)
            .ok_or("missing version")?;
        if version != 1 {
            return Err(format!("unsupported trace version {version}"));
        }
        let names = doc
            .get("names")
            .and_then(Json::as_arr)
            .ok_or("missing names")?
            .iter()
            .map(|n| n.as_str().map(str::to_string).ok_or("non-string name"))
            .collect::<Result<Vec<_>, _>>()?;
        let mut events = Vec::new();
        for (i, row) in doc
            .get("events")
            .and_then(Json::as_arr)
            .ok_or("missing events")?
            .iter()
            .enumerate()
        {
            let row = row.as_arr().ok_or_else(|| format!("event {i}: not an array"))?;
            if row.len() != 5 {
                return Err(format!("event {i}: expected 5 fields, got {}", row.len()));
            }
            let kind = row[0]
                .as_str()
                .and_then(TraceEventKind::from_code)
                .ok_or_else(|| format!("event {i}: bad kind"))?;
            let num = |j: usize| {
                row[j]
                    .as_f64()
                    .ok_or_else(|| format!("event {i}: field {j} not a number"))
            };
            events.push(TraceEvent {
                kind,
                t_us: num(1)?,
                tag: num(2)? as u64,
                name_id: num(3)? as u32,
                loc: num(4)? as u32,
            });
        }
        Ok(Trace { names, events })
    }

    /// Compare against another trace at the default tolerance (1e-9
    /// relative on timestamps — the bound the differential engine tests
    /// already hold the two rate paths to). Empty result = conformant.
    pub fn diff(&self, other: &Trace) -> Vec<Divergence> {
        self.diff_with_tolerance(other, 1e-9)
    }

    /// Structural comparison: event kinds, tags, resolved kernel names and
    /// locations must match exactly in sequence; timestamps may differ by
    /// `rel_tol * max(1, |t|)`. `other` is the expected side.
    pub fn diff_with_tolerance(
        &self,
        other: &Trace,
        rel_tol: f64,
    ) -> Vec<Divergence> {
        let mut out = Vec::new();
        if self.events.len() != other.events.len() {
            out.push(Divergence {
                index: self.events.len().min(other.events.len()),
                field: "length",
                expected: other.events.len().to_string(),
                actual: self.events.len().to_string(),
            });
        }
        for (i, (a, b)) in self.events.iter().zip(&other.events).enumerate() {
            if out.len() >= MAX_DIVERGENCES {
                break;
            }
            if a.kind != b.kind {
                out.push(Divergence {
                    index: i,
                    field: "kind",
                    expected: b.kind.code().to_string(),
                    actual: a.kind.code().to_string(),
                });
                continue;
            }
            if a.tag != b.tag {
                out.push(Divergence {
                    index: i,
                    field: "tag",
                    expected: b.tag.to_string(),
                    actual: a.tag.to_string(),
                });
                continue;
            }
            // Names compare resolved, not by id, so a benign interning
            // renumber is not flagged as drift.
            if self.name_of(a) != other.name_of(b) {
                out.push(Divergence {
                    index: i,
                    field: "name",
                    expected: other.name_of(b).to_string(),
                    actual: self.name_of(a).to_string(),
                });
                continue;
            }
            if a.loc != b.loc {
                out.push(Divergence {
                    index: i,
                    field: "loc",
                    expected: b.loc.to_string(),
                    actual: a.loc.to_string(),
                });
                continue;
            }
            let bound = rel_tol * b.t_us.abs().max(1.0);
            if (a.t_us - b.t_us).abs() > bound {
                out.push(Divergence {
                    index: i,
                    field: "t_us",
                    expected: b.t_us.to_string(),
                    actual: a.t_us.to_string(),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            names: vec!["m/conv1".into(), "m/fc1".into()],
            events: vec![
                TraceEvent {
                    kind: TraceEventKind::Submit,
                    t_us: 0.0,
                    tag: 1,
                    name_id: 0,
                    loc: 0,
                },
                TraceEvent {
                    kind: TraceEventKind::Activate,
                    t_us: 0.0,
                    tag: 1,
                    name_id: 0,
                    loc: 0,
                },
                TraceEvent {
                    kind: TraceEventKind::BlockPlace,
                    t_us: 5.0,
                    tag: 1,
                    name_id: 0,
                    loc: 17,
                },
                TraceEvent {
                    kind: TraceEventKind::Complete,
                    t_us: 6.25,
                    tag: 1,
                    name_id: 0,
                    loc: 0,
                },
            ],
        }
    }

    #[test]
    fn canonical_json_round_trips_byte_identically() {
        let t = sample();
        let s1 = t.to_canonical_json();
        let parsed = Trace::from_json_str(&s1).unwrap();
        assert_eq!(parsed, t);
        assert_eq!(parsed.to_canonical_json(), s1);
        // Keys come out sorted (BTreeMap order).
        let ev = s1.find("\"events\"").unwrap();
        let na = s1.find("\"names\"").unwrap();
        let ve = s1.find("\"version\"").unwrap();
        assert!(ev < na && na < ve, "{s1}");
    }

    #[test]
    fn identical_traces_have_no_diff() {
        assert!(sample().diff(&sample()).is_empty());
    }

    #[test]
    fn diff_flags_structural_changes() {
        let t = sample();
        let mut other = sample();
        other.events[2].loc = 3;
        let d = t.diff(&other);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].field, "loc");
        assert_eq!(d[0].index, 2);

        let mut shorter = sample();
        shorter.events.pop();
        let d = t.diff(&shorter);
        assert!(d.iter().any(|x| x.field == "length"), "{d:?}");

        let mut renamed = sample();
        renamed.names[0] = "other/conv1".into();
        assert!(t.diff(&renamed).iter().any(|x| x.field == "name"));
    }

    #[test]
    fn diff_tolerates_tiny_time_skew_only() {
        let t = sample();
        let mut close = sample();
        close.events[3].t_us += 1e-11;
        assert!(t.diff(&close).is_empty());
        let mut far = sample();
        far.events[3].t_us += 1e-3;
        let d = t.diff(&far);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].field, "t_us");
        // ...unless the tolerance is widened explicitly.
        assert!(t.diff_with_tolerance(&far, 1e-2).is_empty());
    }

    #[test]
    fn from_json_rejects_malformed_documents() {
        assert!(Trace::from_json_str("not json").is_err());
        assert!(Trace::from_json_str("{}").is_err());
        assert!(Trace::from_json_str(
            r#"{"events":[],"names":[],"version":2}"#
        )
        .is_err());
        assert!(Trace::from_json_str(
            r#"{"events":[["X",0,1,0,0]],"names":[],"version":1}"#
        )
        .is_err());
        assert!(Trace::from_json_str(
            r#"{"events":[["S",0,1]],"names":[],"version":1}"#
        )
        .is_err());
    }

    #[test]
    fn divergence_display_is_informative() {
        let mut other = sample();
        other.events[0].tag = 9;
        let d = sample().diff(&other);
        let msg = d[0].to_string();
        assert!(msg.contains("tag"), "{msg}");
        assert!(msg.contains('9'), "{msg}");
    }

    #[test]
    fn diff_caps_reported_divergences() {
        let t = sample();
        let mut other = sample();
        // Completely different trajectory.
        for e in &mut other.events {
            e.tag += 100;
        }
        let mut many_events = Vec::new();
        let mut wide_events = Vec::new();
        for _ in 0..50 {
            many_events.extend(other.events.clone());
            wide_events.extend(t.events.clone());
        }
        let many = Trace { names: t.names.clone(), events: many_events };
        let wide = Trace { names: t.names.clone(), events: wide_events };
        let d = wide.diff(&many);
        assert!(d.len() <= MAX_DIVERGENCES + 1, "{}", d.len());
    }
}
