//! Simulation metrics: achieved occupancy, timelines, per-kernel stats.

use std::collections::HashMap;

use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::gpu::stream::{LaunchTag, StreamId};

/// Completed-launch record (one row of the Fig. 9 timeline).
#[derive(Debug, Clone)]
pub struct LaunchRecord {
    /// The launch's engine-assigned tag.
    pub tag: LaunchTag,
    /// Resolved kernel name (shards carry their `#esN` suffix).
    pub name: String,
    /// Stream the launch ran on.
    pub stream: StreamId,
    /// Task class of the submitting request.
    pub criticality: Criticality,
    /// Submission time (us).
    pub submit_us: f64,
    /// First block dispatched (us).
    pub start_us: f64,
    /// Last block completed (us).
    pub end_us: f64,
}

impl LaunchRecord {
    /// Queueing + execution latency of the launch.
    pub fn latency_us(&self) -> f64 {
        self.end_us - self.submit_us
    }
}

/// Occupancy accounting (paper §8.1.4):
/// `achieved = (active_warp·time / active_time) / max_warps_per_sm`
/// where `active_time` sums over SM-time with >= 1 resident block.
#[derive(Debug, Clone, Default)]
pub struct OccupancyAccum {
    /// Integral over time of total active warps (warp·us across all SMs).
    pub warp_time: f64,
    /// Integral over time of number of active SMs (SM·us).
    pub active_sm_time: f64,
    /// Per-kernel-name warp·us attribution (Fig. 9 layer-wise occupancy).
    pub per_name_warp_time: HashMap<String, f64>,
    /// Per-kernel-name active window (us of sim time the name had >= 1
    /// resident block).
    pub per_name_active_time: HashMap<String, f64>,
}

impl OccupancyAccum {
    /// Average achieved occupancy over the active window.
    pub fn achieved(&self, spec: &GpuSpec) -> f64 {
        if self.active_sm_time <= 0.0 {
            return 0.0;
        }
        (self.warp_time / self.active_sm_time) / spec.max_warps_per_sm() as f64
    }

    /// Achieved occupancy attributed to a single kernel name: the average
    /// fraction of the *whole GPU's* warp budget this kernel's blocks held
    /// while the kernel was live (warp·time spans all SMs, so the
    /// denominator is `max_warps_per_sm * num_sms`).
    pub fn achieved_for(&self, spec: &GpuSpec, name: &str) -> f64 {
        let wt = self.per_name_warp_time.get(name).copied().unwrap_or(0.0);
        let at = self.per_name_active_time.get(name).copied().unwrap_or(0.0);
        if at <= 0.0 {
            return 0.0;
        }
        (wt / at)
            / (spec.max_warps_per_sm() as f64 * spec.num_sms as f64)
    }
}

/// Everything a simulation run reports.
#[derive(Debug, Clone, Default)]
pub struct SimMetrics {
    /// Completed launches in completion order.
    pub records: Vec<LaunchRecord>,
    /// Occupancy integrals (paper §8.1.4).
    pub occupancy: OccupancyAccum,
    /// Total simulated time (us).
    pub sim_time_us: f64,
    /// Number of block-level events processed (perf counter).
    pub events: u64,
}

impl SimMetrics {
    /// Completed launches of one task class.
    pub fn records_for(&self, crit: Criticality) -> impl Iterator<Item = &LaunchRecord> {
        self.records.iter().filter(move |r| r.criticality == crit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_zero_when_never_active() {
        let acc = OccupancyAccum::default();
        assert_eq!(acc.achieved(&GpuSpec::rtx2060()), 0.0);
        assert_eq!(acc.achieved_for(&GpuSpec::rtx2060(), "x"), 0.0);
    }

    #[test]
    fn occupancy_full() {
        let spec = GpuSpec::rtx2060();
        let mut acc = OccupancyAccum::default();
        // All 30 SMs active for 10us, each holding max warps.
        acc.active_sm_time = 300.0;
        acc.warp_time = 300.0 * spec.max_warps_per_sm() as f64;
        assert!((acc.achieved(&spec) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn latency_is_end_minus_submit() {
        let r = LaunchRecord {
            tag: 1,
            name: "k".into(),
            stream: 0,
            criticality: Criticality::Critical,
            submit_us: 10.0,
            start_us: 15.0,
            end_us: 42.0,
        };
        assert!((r.latency_us() - 32.0).abs() < 1e-12);
    }
}
