//! Kernel-name interning.
//!
//! The engine's hot path attributes occupancy per kernel name on every
//! event. Interning names into dense `u32` ids at submit time turns that
//! attribution into flat-`Vec` indexing (EXPERIMENTS.md §Perf change #4);
//! strings are resolved back only when records and metrics are assembled.

use std::collections::HashMap;

/// Bidirectional string ⇄ id table. Ids are dense and start at 0, so they
/// can index parallel `Vec` accumulators directly.
#[derive(Debug, Default)]
pub struct NameTable {
    names: Vec<String>,
    index: HashMap<String, u32>,
}

impl NameTable {
    /// An empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Id for `name`, allocating one on first sight.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.index.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), id);
        id
    }

    /// The string for `id`. Panics on an id this table never issued.
    pub fn resolve(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    /// Id for `name` if it was interned before.
    pub fn lookup(&self, name: &str) -> Option<u32> {
        self.index.get(name).copied()
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// Whether no names were interned yet.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All (id, name) pairs in id order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &str)> {
        self.names
            .iter()
            .enumerate()
            .map(|(i, s)| (i as u32, s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent_and_dense() {
        let mut t = NameTable::new();
        let a = t.intern("alexnet/conv1");
        let b = t.intern("alexnet/conv2");
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        assert_eq!(t.intern("alexnet/conv1"), a);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn resolve_round_trips() {
        let mut t = NameTable::new();
        let id = t.intern("k#es0");
        assert_eq!(t.resolve(id), "k#es0");
        assert_eq!(t.lookup("k#es0"), Some(id));
        assert_eq!(t.lookup("missing"), None);
    }

    #[test]
    fn iter_in_id_order() {
        let mut t = NameTable::new();
        t.intern("a");
        t.intern("b");
        let v: Vec<_> = t.iter().collect();
        assert_eq!(v, vec![(0, "a"), (1, "b")]);
    }
}
