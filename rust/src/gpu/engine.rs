//! The discrete-event edge-GPU simulator.
//!
//! Executes [`LaunchConfig`]s submitted to priority streams under the
//! resource model of [`crate::gpu::sm`] and the contention/rate model of
//! [`crate::gpu::contention`]. Between events every resident block
//! progresses at a constant rate, so completions are exact — no time
//! quantization.
//!
//! The engine is *mechanism only*: it implements CUDA-like semantics
//! (FIFO within a stream, priority block dispatch across streams, greedy
//! fill of SMs) and knows nothing about criticality policies. Schedulers
//! (Sequential / Multi-stream / IB / Miriam, `crate::coordinator`) decide
//! what to submit and when.

use std::collections::HashMap;

use crate::gpu::contention::{block_rates, BlockWork, ContentionParams};
use crate::gpu::kernel::{Criticality, LaunchConfig};
use crate::gpu::metrics::{LaunchRecord, SimMetrics};
use crate::gpu::sm::{BlockDemand, SmState};
use crate::gpu::spec::GpuSpec;
use crate::gpu::stream::{LaunchTag, QueuedLaunch, Stream, StreamId};

/// A launch whose blocks are being dispatched / executed.
#[derive(Debug)]
struct ActiveLaunch {
    tag: LaunchTag,
    stream: StreamId,
    config: LaunchConfig,
    criticality: Criticality,
    submit_us: f64,
    /// Time the launch became eligible to dispatch (post launch overhead).
    ready_us: f64,
    /// First-block dispatch time (None until a block lands).
    start_us: Option<f64>,
    /// Blocks not yet dispatched.
    blocks_pending: u32,
    /// Blocks dispatched and still executing.
    blocks_running: u32,
    /// Blocks completed.
    blocks_done: u32,
}

impl ActiveLaunch {
    fn demand(&self) -> BlockDemand {
        BlockDemand {
            threads: self.config.block_threads,
            smem: self.config.smem_per_block,
            regs: self.config.regs_per_thread * self.config.block_threads,
        }
    }
    fn finished(&self) -> bool {
        self.blocks_pending == 0 && self.blocks_running == 0
    }
}

/// One resident (executing) thread block.
///
/// Launch statics (threads/flops/bytes/warps) are cached here at dispatch
/// time so the per-event rate refresh never touches the launch HashMap —
/// the event loop's hottest path (EXPERIMENTS.md §Perf, change #1).
#[derive(Debug)]
struct ResidentBlock {
    tag: LaunchTag,
    sm: u32,
    /// Remaining work in FLOPs.
    remaining: f64,
    /// Current progress rate (FLOP/us), refreshed on every event.
    rate: f64,
    /// The rate this block would get alone on its SM with free bandwidth —
    /// the denominator of the productive-occupancy weight (a warp stalled
    /// by contention does not count as active, matching the profiler
    /// semantics of the paper's achieved-occupancy metric, §8.1.4).
    entitled: f64,
    /// Cached launch statics.
    threads: u32,
    warps: f64,
    flops_per_block: f64,
    bytes_per_block: f64,
}

/// Completion event the engine reports to the driver.
#[derive(Debug, Clone)]
pub struct Completion {
    pub tag: LaunchTag,
    pub record: LaunchRecord,
}

/// Read-only snapshot of GPU residency used by scheduling policies
/// (Miriam's coordinator reads leftover resources from this; paper §7).
#[derive(Debug, Clone)]
pub struct GpuSnapshot {
    pub now_us: f64,
    /// Per-SM (threads_used, blocks_resident).
    pub sm_threads_used: Vec<u32>,
    pub sm_blocks: Vec<u32>,
    /// Resident critical blocks count (total) and their block size.
    pub critical_blocks: u32,
    pub critical_block_threads: u32,
    /// Pending (undispatched) critical blocks across streams.
    pub critical_pending: u32,
    /// Resident normal blocks count.
    pub normal_blocks: u32,
}

/// The simulator.
pub struct Engine {
    pub spec: GpuSpec,
    pub params: ContentionParams,
    now_us: f64,
    streams: Vec<Stream>,
    sms: Vec<SmState>,
    active: HashMap<LaunchTag, ActiveLaunch>,
    resident: Vec<ResidentBlock>,
    metrics: SimMetrics,
    next_tag: LaunchTag,
    rates_dirty: bool,
    /// Memoized absolute time of the next internal event. Finish times are
    /// absolute, so advancing the clock does not invalidate the cache —
    /// only rate changes and new timers do (§Perf change #2).
    event_cache: Option<f64>,
}

impl Engine {
    pub fn new(spec: GpuSpec) -> Self {
        Self::with_params(spec, ContentionParams::default())
    }

    pub fn with_params(spec: GpuSpec, params: ContentionParams) -> Self {
        let sms = (0..spec.num_sms).map(|_| SmState::empty()).collect();
        Engine {
            spec,
            params,
            now_us: 0.0,
            streams: Vec::new(),
            sms,
            active: HashMap::new(),
            resident: Vec::new(),
            metrics: SimMetrics::default(),
            next_tag: 1,
            rates_dirty: true,
            event_cache: None,
        }
    }

    /// Create a stream with the given dispatch priority (higher wins).
    pub fn add_stream(&mut self, priority: i32) -> StreamId {
        let id = self.streams.len() as StreamId;
        self.streams.push(Stream::new(id, priority));
        id
    }

    pub fn now_us(&self) -> f64 {
        self.now_us
    }

    pub fn metrics(&self) -> &SimMetrics {
        &self.metrics
    }

    pub fn into_metrics(mut self) -> SimMetrics {
        self.metrics.sim_time_us = self.now_us;
        self.metrics
    }

    /// Submit a launch to a stream. Returns its tag.
    pub fn submit(&mut self, stream: StreamId, config: LaunchConfig,
                  criticality: Criticality) -> LaunchTag {
        self.submit_delayed(stream, config, criticality, 0.0)
    }

    /// Submit with an extra pre-dispatch delay (models scheduler-imposed
    /// synchronization cost, e.g. IB barriers).
    pub fn submit_delayed(&mut self, stream: StreamId, config: LaunchConfig,
                          criticality: Criticality, extra_delay_us: f64)
                          -> LaunchTag {
        assert!(config.grid > 0, "launch {} has empty grid", config.name);
        assert!(config.block_threads > 0
                    && config.block_threads <= self.spec.max_threads_per_sm,
                "launch {} block size {} outside (0, {}]",
                config.name, config.block_threads, self.spec.max_threads_per_sm);
        assert!(config.flops > 0.0, "launch {} needs flops > 0", config.name);
        let tag = self.next_tag;
        self.next_tag += 1;
        self.streams[stream as usize].push(QueuedLaunch {
            tag,
            config,
            criticality,
            extra_delay_us,
            submit_us: self.now_us,
        });
        self.activate_stream_heads();
        self.try_dispatch();
        tag
    }

    /// True when nothing is queued, dispatching, or executing.
    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.streams.iter().all(|s| s.is_empty())
    }

    /// Number of launches not yet completed.
    pub fn inflight(&self) -> usize {
        self.active.len()
            + self.streams.iter().map(|s| s.depth()).sum::<usize>()
            - self
                .streams
                .iter()
                .filter(|s| s.head_active)
                .count()
    }

    /// Promote stream heads whose turn has come into `active`.
    fn activate_stream_heads(&mut self) {
        for s in 0..self.streams.len() {
            if self.streams[s].head_active || self.streams[s].is_empty() {
                continue;
            }
            let q = self.streams[s].queue.front().unwrap();
            let ready = self.now_us + self.spec.kernel_launch_us + q.extra_delay_us;
            let q = self.streams[s].queue.front().unwrap().clone();
            self.streams[s].head_active = true;
            self.event_cache = None; // new launch-overhead timer
            self.active.insert(q.tag, ActiveLaunch {
                tag: q.tag,
                stream: s as StreamId,
                config: q.config.clone(),
                criticality: q.criticality,
                submit_us: q.submit_us,
                ready_us: ready,
                start_us: None,
                blocks_pending: q.config.grid,
                blocks_running: 0,
                blocks_done: 0,
            });
        }
    }

    /// Greedy block dispatcher: streams in priority order (FIFO within a
    /// stream — only the head launch dispatches); for each, place pending
    /// blocks on the least-loaded SM that fits. Lower-priority blocks may
    /// fill around a higher-priority launch that does not fit (hardware
    /// work-distributor behaviour per Gilman et al. [9]).
    fn try_dispatch(&mut self) {
        // Streams sorted by (priority desc, id asc).
        let mut order: Vec<usize> = (0..self.streams.len()).collect();
        order.sort_by_key(|&i| (-self.streams[i].priority, i));
        for si in order {
            if !self.streams[si].head_active {
                continue;
            }
            let tag = match self.streams[si].queue.front() {
                Some(q) => q.tag,
                None => continue,
            };
            let launch = self.active.get_mut(&tag).unwrap();
            if launch.ready_us > self.now_us {
                continue; // still in launch overhead
            }
            let demand = launch.demand();
            while launch.blocks_pending > 0 {
                // Least-loaded (by threads) SM that fits.
                let mut best: Option<(usize, u32)> = None;
                for (i, sm) in self.sms.iter().enumerate() {
                    if sm.fits(&demand, &self.spec) {
                        let used = sm.threads_used;
                        if best.map_or(true, |(_, u)| used < u) {
                            best = Some((i, used));
                        }
                    }
                }
                let Some((sm_idx, _)) = best else { break };
                self.sms[sm_idx].admit(&demand);
                launch.blocks_pending -= 1;
                launch.blocks_running += 1;
                if launch.start_us.is_none() {
                    launch.start_us = Some(self.now_us);
                }
                let share = (launch.config.block_threads as f64
                    / self.spec.max_threads_per_sm as f64)
                    * self.params.latency_hiding;
                self.resident.push(ResidentBlock {
                    tag,
                    sm: sm_idx as u32,
                    remaining: launch.config.flops_per_block(),
                    rate: 0.0,
                    entitled: self.spec.flops_per_sm_us * share.min(1.0),
                    threads: launch.config.block_threads,
                    warps: launch.config.block_threads
                        .div_ceil(self.spec.warp_size) as f64,
                    flops_per_block: launch.config.flops_per_block(),
                    bytes_per_block: launch.config.bytes_per_block(),
                });
                self.rates_dirty = true;
                self.event_cache = None;
            }
        }
    }

    fn refresh_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        let works: Vec<BlockWork> = self
            .resident
            .iter()
            .map(|b| BlockWork {
                sm: b.sm,
                threads: b.threads,
                flops: b.flops_per_block,
                bytes: b.bytes_per_block,
                kernel: b.tag,
            })
            .collect();
        let rates = block_rates(&self.spec, &self.params, &works);
        for (b, r) in self.resident.iter_mut().zip(rates) {
            b.rate = r;
        }
        self.rates_dirty = false;
    }

    /// Time of the next internal event (block completion or launch-overhead
    /// expiry), if any.
    pub fn next_event_time(&mut self) -> Option<f64> {
        self.refresh_rates();
        if let Some(t) = self.event_cache {
            return if t.is_finite() { Some(t) } else { None };
        }
        let mut t = f64::INFINITY;
        for b in &self.resident {
            if b.rate > 0.0 {
                t = t.min(self.now_us + b.remaining / b.rate);
            }
        }
        for l in self.active.values() {
            // A launch waiting out its overhead (with pending blocks and a
            // head position) wakes the engine at ready_us.
            if l.blocks_pending > 0 && l.ready_us > self.now_us {
                t = t.min(l.ready_us);
            }
        }
        self.event_cache = Some(t);
        if t.is_finite() {
            Some(t)
        } else {
            None
        }
    }

    /// Advance simulated time to `t` (must be <= next_event_time), accruing
    /// occupancy integrals. No completions may occur inside the window.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now_us - 1e-9, "time went backwards");
        let dt = (t - self.now_us).max(0.0);
        if dt > 0.0 {
            self.refresh_rates();
            // Occupancy integrals (productivity-weighted warps; see the
            // per-name attribution comment below).
            let mut active_sms = 0.0;
            for sm in &self.sms {
                if !sm.is_idle() {
                    active_sms += 1.0;
                }
            }
            let mut warp_time = 0.0;
            for b in &self.resident {
                let weight = if b.entitled > 0.0 {
                    (b.rate / b.entitled).min(1.0)
                } else {
                    1.0
                };
                warp_time += b.warps * weight;
            }
            self.metrics.occupancy.warp_time += warp_time * dt;
            self.metrics.occupancy.active_sm_time += active_sms * dt;
            // Per-kernel-name attribution, productivity-weighted: a warp
            // making `rate/entitled` of its solo progress counts as that
            // fraction of an active warp.
            let mut name_warps: HashMap<&str, f64> = HashMap::new();
            for b in &self.resident {
                let l = &self.active[&b.tag];
                let weight = if b.entitled > 0.0 {
                    (b.rate / b.entitled).min(1.0)
                } else {
                    1.0
                };
                *name_warps.entry(l.config.name.as_str()).or_default() +=
                    b.warps * weight;
            }
            for (name, w) in name_warps {
                *self
                    .metrics
                    .occupancy
                    .per_name_warp_time
                    .entry(name.to_string())
                    .or_default() += w * dt;
                *self
                    .metrics
                    .occupancy
                    .per_name_active_time
                    .entry(name.to_string())
                    .or_default() += dt;
            }
            // Progress.
            for b in &mut self.resident {
                b.remaining -= b.rate * dt;
            }
        }
        self.now_us = t;
    }

    /// Process the next internal event. Returns completions that fired.
    /// The caller must have advanced to (or past) the event time via
    /// `advance_to(next_event_time())`; `step()` combines both.
    pub fn step(&mut self) -> Vec<Completion> {
        let Some(t) = self.next_event_time() else {
            return Vec::new();
        };
        self.advance_to(t);
        self.metrics.events += 1;
        // The event at `t` is being consumed (completion or timer expiry):
        // the cached next-event time is spent either way.
        self.event_cache = None;
        let mut completions = Vec::new();
        // Collect finished blocks. The threshold is *time*-relative: a block
        // whose remaining work amounts to less simulated time than f64 can
        // resolve at `now` must complete now, or `now + remaining/rate`
        // rounds back to `now` and the event loop livelocks (dt == 0, work
        // never decreases). `slack` is ~1000 ULPs of `now` plus a picosecond
        // floor — nanoseconds at most, far below kernel timescales.
        let slack = self.now_us.abs() * 1e-12 + 1e-6;
        let mut i = 0;
        while i < self.resident.len() {
            if self.resident[i].remaining <= self.resident[i].rate * slack {
                let blk = self.resident.swap_remove(i);
                let launch = self.active.get_mut(&blk.tag).unwrap();
                let demand = launch.demand();
                self.sms[blk.sm as usize].release(&demand);
                launch.blocks_running -= 1;
                launch.blocks_done += 1;
                self.rates_dirty = true;
                self.event_cache = None;
                if launch.finished() {
                    let l = self.active.remove(&blk.tag).unwrap();
                    let record = LaunchRecord {
                        tag: l.tag,
                        name: l.config.name.clone(),
                        stream: l.stream,
                        criticality: l.criticality,
                        submit_us: l.submit_us,
                        start_us: l.start_us.unwrap_or(l.submit_us),
                        end_us: self.now_us,
                    };
                    self.metrics.records.push(record.clone());
                    // Pop the stream head, making the next launch eligible.
                    let s = &mut self.streams[l.stream as usize];
                    let popped = s.queue.pop_front().unwrap();
                    debug_assert_eq!(popped.tag, l.tag);
                    s.head_active = false;
                    completions.push(Completion { tag: l.tag, record });
                }
            } else {
                i += 1;
            }
        }
        self.activate_stream_heads();
        self.try_dispatch();
        completions
    }

    /// Run until the engine is idle; returns all completions in order.
    pub fn run_to_idle(&mut self) -> Vec<Completion> {
        let mut all = Vec::new();
        while self.next_event_time().is_some() {
            all.extend(self.step());
        }
        all
    }

    /// Snapshot for scheduling policies.
    pub fn snapshot(&self) -> GpuSnapshot {
        let mut critical_blocks = 0;
        let mut critical_block_threads = 0;
        let mut normal_blocks = 0;
        for b in &self.resident {
            let l = &self.active[&b.tag];
            match l.criticality {
                Criticality::Critical => {
                    critical_blocks += 1;
                    critical_block_threads = critical_block_threads
                        .max(l.config.block_threads);
                }
                Criticality::Normal => normal_blocks += 1,
            }
        }
        let critical_pending = self
            .active
            .values()
            .filter(|l| l.criticality == Criticality::Critical)
            .map(|l| l.blocks_pending)
            .sum();
        GpuSnapshot {
            now_us: self.now_us,
            sm_threads_used: self.sms.iter().map(|s| s.threads_used).collect(),
            sm_blocks: self.sms.iter().map(|s| s.blocks_resident).collect(),
            critical_blocks,
            critical_block_threads,
            critical_pending,
            normal_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(name: &str, grid: u32, threads: u32, flops: f64, bytes: f64) -> LaunchConfig {
        LaunchConfig {
            name: name.into(),
            grid,
            block_threads: threads,
            smem_per_block: 0,
            regs_per_thread: 32,
            flops,
            bytes,
        }
    }

    #[test]
    fn single_kernel_solo_latency() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        // 30 blocks of 512 threads: one per SM, each saturating its SM.
        // flops 30 * 215000 -> 1us of compute + 5us launch overhead.
        e.submit(s, cfg("k", 30, 512, 30.0 * 215_000.0, 0.0),
                 Criticality::Normal);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 1);
        let lat = done[0].record.latency_us();
        assert!((lat - 6.0).abs() < 1e-6, "latency {lat}");
    }

    #[test]
    fn stream_fifo_is_sequential() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(0);
        e.submit(s, cfg("a", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        e.submit(s, cfg("b", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].record.name, "a");
        assert_eq!(done[1].record.name, "b");
        // b cannot start before a completes.
        assert!(done[1].record.start_us >= done[0].record.end_us - 1e-9);
    }

    #[test]
    fn two_streams_overlap() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s0 = e.add_stream(0);
        let s1 = e.add_stream(0);
        // Each kernel occupies half the SM's threads; both fit concurrently.
        e.submit(s0, cfg("a", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        e.submit(s1, cfg("b", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        let a = done.iter().find(|c| c.record.name == "a").unwrap();
        let b = done.iter().find(|c| c.record.name == "b").unwrap();
        // They overlap in time (start of one before end of the other).
        assert!(a.record.start_us < b.record.end_us);
        assert!(b.record.start_us < a.record.end_us);
    }

    #[test]
    fn contention_slows_corunners() {
        let spec = GpuSpec::rtx2060();
        // Solo run: 30 blocks, one per SM (512 threads leaves half free).
        let mut e1 = Engine::new(spec.clone());
        let s = e1.add_stream(0);
        e1.submit(s, cfg("k", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let solo = e1.run_to_idle()[0].record.latency_us();
        // Same kernel co-resident with a rival occupying the other half of
        // every SM: the foreign-interference term must slow it down.
        let mut e2 = Engine::new(spec);
        let s0 = e2.add_stream(0);
        let s1 = e2.add_stream(0);
        e2.submit(s0, cfg("rival", 30, 512, 30.0 * 4.0 * 215_000.0, 0.0),
                  Criticality::Normal);
        e2.submit(s1, cfg("k", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let done = e2.run_to_idle();
        let co = done.iter().find(|c| c.record.name == "k").unwrap()
            .record.latency_us();
        assert!(co > solo * 1.2, "co {co} vs solo {solo}");
    }

    #[test]
    fn priority_stream_dispatches_first() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let hi = e.add_stream(10);
        let lo = e.add_stream(0);
        // Both kernels want every thread slot; the hi-priority one must
        // get dispatched first even though submitted second.
        let big = 30 * 2; // 2 full waves of 1024-thread blocks
        e.submit(lo, cfg("lo", big, 1024, big as f64 * 215_000.0, 0.0),
                 Criticality::Normal);
        e.submit(hi, cfg("hi", big, 1024, big as f64 * 215_000.0, 0.0),
                 Criticality::Critical);
        let done = e.run_to_idle();
        let hi_rec = done.iter().find(|c| c.record.name == "hi").unwrap();
        let lo_rec = done.iter().find(|c| c.record.name == "lo").unwrap();
        // Equal submit-to-dispatch conditions; priority should let "hi"
        // finish no later than "lo".
        assert!(hi_rec.record.end_us <= lo_rec.record.end_us + 1e-9);
    }

    #[test]
    fn work_conservation() {
        // Total executed FLOPs = submitted FLOPs (no lost/duplicated work):
        // checked indirectly via makespan = work / peak on a saturating
        // workload with no memory pressure.
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        let waves = 4;
        let grid = spec.num_sms * waves;
        let flops = grid as f64 * 215_000.0; // 1us per block when saturated
        e.submit(s, cfg("k", grid, 1024, flops, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        let span = done[0].record.end_us - done[0].record.start_us;
        assert!((span - waves as f64).abs() < 1e-6, "span {span}");
    }

    #[test]
    fn occupancy_accrues() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 30, 1024, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        e.run_to_idle();
        let m = e.into_metrics();
        // Full SM occupancy while active.
        let occ = m.occupancy.achieved(&spec);
        assert!((occ - 1.0).abs() < 1e-9, "occ {occ}");
    }

    #[test]
    fn launch_overhead_delays_start() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 1, 32, 1000.0, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        assert!(done[0].record.start_us >= 5.0 - 1e-9);
    }

    #[test]
    fn extra_delay_adds_to_overhead() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(0);
        e.submit_delayed(s, cfg("k", 1, 32, 1000.0, 0.0),
                         Criticality::Normal, 100.0);
        let done = e.run_to_idle();
        assert!(done[0].record.start_us >= 105.0 - 1e-9);
    }

    #[test]
    fn snapshot_reports_residency() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(5);
        e.submit(s, cfg("crit", 10, 256, 1e7, 0.0), Criticality::Critical);
        // Advance past launch overhead so blocks dispatch.
        let t = e.next_event_time().unwrap();
        e.advance_to(t);
        e.step();
        let snap = e.snapshot();
        assert!(snap.critical_blocks > 0 || snap.critical_pending > 0);
        assert_eq!(snap.normal_blocks, 0);
    }

    #[test]
    #[should_panic(expected = "empty grid")]
    fn zero_grid_rejected() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        let s = e.add_stream(0);
        e.submit(s, cfg("bad", 0, 32, 1.0, 0.0), Criticality::Normal);
    }

    #[test]
    fn idle_engine_has_no_events() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        e.add_stream(0);
        assert!(e.next_event_time().is_none());
        assert!(e.idle());
        assert!(e.step().is_empty());
    }
}
