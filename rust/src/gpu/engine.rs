//! The discrete-event edge-GPU simulator.
//!
//! Executes [`LaunchConfig`]s submitted to priority streams under the
//! resource model of [`crate::gpu::sm`] and the contention/rate model of
//! [`crate::gpu::contention`]. Between events every resident block
//! progresses at a constant rate, so completions are exact — no time
//! quantization.
//!
//! The engine is *mechanism only*: it implements CUDA-like semantics
//! (FIFO within a stream, priority block dispatch across streams, greedy
//! fill of SMs) and knows nothing about criticality policies. Schedulers
//! (Sequential / Multi-stream / IB / Miriam, `crate::coordinator`) decide
//! what to submit and when.
//!
//! Steady-state cost per event is proportional to what *changed*, not to
//! total residency (EXPERIMENTS.md §Perf change #4):
//!
//! * per-SM contention aggregates live in [`SmState`] and are updated on
//!   block admit/release; the rate refresh only revisits SMs whose
//!   residency changed, with the global bandwidth term kept as a running
//!   sum over per-SM contributions;
//! * block placement pops the least-loaded SM from a lazily-invalidated
//!   binary heap keyed by `threads_used` instead of scanning every SM per
//!   block;
//! * kernel names are interned to `u32` ids at submit
//!   ([`crate::gpu::names::NameTable`]), so per-name occupancy attribution
//!   indexes flat `Vec` accumulators — no per-event `HashMap`;
//! * blocks and launches live in free-list slabs with per-SM resident
//!   lists; the hot loops (`refresh_rates`/`advance_to`/`step`) construct
//!   no `Vec`/`HashMap` in steady state.
//!
//! The seed's full-recompute algorithm is retained behind
//! [`Engine::with_reference_rates`] as a differential-testing oracle and
//! the "before" leg of `benches/engine_throughput.rs`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::gpu::contention::{
    bandwidth_scale, block_rates, foreign_penalty, intra_sm_scale,
    standalone_demand, BlockWork, ContentionParams,
};
use crate::gpu::kernel::{Criticality, LaunchConfig, LaunchShape};
use crate::gpu::metrics::{LaunchRecord, SimMetrics};
use crate::gpu::names::NameTable;
use crate::gpu::sm::{BlockDemand, SmMask, SmState};
use crate::gpu::spec::GpuSpec;
use crate::gpu::stream::{LaunchTag, QueuedLaunch, Stream, StreamId};
use crate::gpu::trace::{Trace, TraceEventKind, TraceRecorder};

/// Total-ordered f64 time key for the launch-overhead timer heap.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Tm(f64);
impl Tm {
    /// All timer keys are built here: a NaN key would order arbitrarily
    /// against everything and silently corrupt the `BinaryHeap` (ISSUE 3
    /// satellite — a bad arrival process must fail loudly, in debug, not
    /// wedge the event loop).
    fn new(t: f64) -> Self {
        debug_assert!(t.is_finite(), "non-finite simulated time {t}");
        Tm(t)
    }
}
impl Eq for Tm {}
impl PartialOrd for Tm {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Tm {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0
            .partial_cmp(&other.0)
            .unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// A launch whose blocks are being dispatched / executed (slab entry).
#[derive(Debug)]
struct ActiveLaunch {
    tag: LaunchTag,
    stream: StreamId,
    name_id: u32,
    criticality: Criticality,
    submit_us: f64,
    /// Time the launch became eligible to dispatch (post launch overhead).
    ready_us: f64,
    /// First-block dispatch time (None until a block lands).
    start_us: Option<f64>,
    /// Blocks not yet dispatched.
    blocks_pending: u32,
    /// Blocks dispatched and still executing.
    blocks_running: u32,
    // Launch statics, cached at activation so dispatch and completion
    // never touch the stream queue again.
    block_threads: u32,
    smem_per_block: u32,
    regs_per_thread: u32,
    flops_per_block: f64,
    bytes_per_block: f64,
}

impl ActiveLaunch {
    fn demand(&self) -> BlockDemand {
        BlockDemand {
            threads: self.block_threads,
            smem: self.smem_per_block,
            regs: self.regs_per_thread * self.block_threads,
        }
    }
    fn finished(&self) -> bool {
        self.blocks_pending == 0 && self.blocks_running == 0
    }
}

/// One resident (executing) thread block (slab entry).
///
/// Launch statics are cached here at dispatch time so the per-event rate
/// refresh never touches the launch slab — the event loop's hottest path
/// (EXPERIMENTS.md §Perf, changes #1/#4).
#[derive(Debug, Clone)]
struct BlockSlot {
    /// Slot occupancy flag (dead slots are on the free list).
    live: bool,
    tag: LaunchTag,
    /// Index into the launch slab.
    launch: u32,
    sm: u32,
    /// Position inside `sm_resident[sm]` (maintained across swap-removes).
    pos_in_sm: u32,
    name_id: u32,
    criticality: Criticality,
    threads: u32,
    warps: f64,
    /// Standalone compute demand (FLOP/us) — also the entitled rate, the
    /// denominator of the productive-occupancy weight (a warp stalled by
    /// contention does not count as active, matching the profiler
    /// semantics of the paper's achieved-occupancy metric, §8.1.4).
    demand: f64,
    flops_per_block: f64,
    bytes_per_block: f64,
    /// Couples to the global DRAM-bandwidth term.
    memory_bound: bool,
    /// Remaining work in FLOPs.
    remaining: f64,
    /// Compute rate (FLOP/us) from the per-SM terms; the effective
    /// progress rate is `cr * bw_scale` for memory-bound blocks. In
    /// reference mode `cr` holds the final rate and `bw_scale` stays 1.
    cr: f64,
}

/// Completion event the engine reports to the driver.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Tag of the launch that completed.
    pub tag: LaunchTag,
    /// The finished launch's timeline record.
    pub record: LaunchRecord,
}

/// Scalar residency counters, `Copy` and allocation-free — the
/// per-carving-decision read Miriam's pump does (paper §7's Eq. 2 only
/// needs these totals; the old per-decision [`GpuSnapshot`] built two
/// per-SM `Vec`s each time — ISSUE 3 zero-clone fast path). All counters
/// are maintained incrementally on dispatch/completion, so this is a
/// handful of loads; late binding of shard geometry stays intact because
/// reading it fresh per carve costs nothing.
#[derive(Debug, Clone, Copy)]
pub struct Residency {
    /// Current simulated time (us).
    pub now_us: f64,
    /// Resident critical blocks count (total) and their block size.
    pub critical_blocks: u32,
    /// Largest resident critical block size (threads; 0 when none).
    pub critical_block_threads: u32,
    /// Pending (undispatched) critical blocks across streams.
    pub critical_pending: u32,
    /// Resident normal blocks count.
    pub normal_blocks: u32,
}

/// Read-only snapshot of GPU residency used by scheduling policies
/// (Miriam's coordinator reads leftover resources from this; paper §7).
#[derive(Debug, Clone)]
pub struct GpuSnapshot {
    /// Current simulated time (us).
    pub now_us: f64,
    /// Per-SM thread slots in use.
    pub sm_threads_used: Vec<u32>,
    /// Per-SM resident block counts.
    pub sm_blocks: Vec<u32>,
    /// Resident critical blocks count (total) and their block size.
    pub critical_blocks: u32,
    /// Largest resident critical block size (threads; 0 when none).
    pub critical_block_threads: u32,
    /// Pending (undispatched) critical blocks across streams.
    pub critical_pending: u32,
    /// Resident normal blocks count.
    pub normal_blocks: u32,
}

/// The simulator.
pub struct Engine {
    /// Hardware parameters of the simulated GPU.
    pub spec: GpuSpec,
    /// Contention-model tunables.
    pub params: ContentionParams,
    now_us: f64,
    streams: Vec<Stream>,
    /// Stream indices in dispatch order (priority desc, id asc); rebuilt
    /// only when a stream is added.
    stream_order: Vec<u32>,
    /// Active launch slot per stream (parallel to `streams`).
    head_slot: Vec<Option<u32>>,
    /// Placement constraint per stream (parallel to `streams`). Streams
    /// start at [`SmMask::ALL`], the unconstrained sentinel dispatched
    /// through the heap path; only the isolation scheduler narrows it
    /// (via [`Engine::set_stream_mask`]), so mask-free runs are bitwise
    /// unchanged.
    stream_masks: Vec<SmMask>,
    sms: Vec<SmState>,
    /// Per-SM list of live block-slot ids.
    sm_resident: Vec<Vec<u32>>,
    /// Per-SM bandwidth demand at current compute rates (running sum
    /// contributions to `total_bw_demand`).
    sm_bw_demand: Vec<f64>,
    /// SMs whose residency changed since the last rate refresh.
    dirty_sms: Vec<u32>,
    sm_dirty: Vec<bool>,
    /// Least-loaded-SM index: min-heap of (threads_used, sm, version)
    /// with lazy invalidation; exactly one entry per SM is current.
    sm_heap: BinaryHeap<Reverse<(u32, u32, u64)>>,
    sm_ver: Vec<u64>,
    sm_heap_scratch: Vec<(u32, u32, u64)>,
    /// Launch slab + free list.
    launches: Vec<Option<ActiveLaunch>>,
    free_launches: Vec<u32>,
    live_launches: usize,
    /// Block slab + free list. The slab never exceeds peak residency,
    /// which the hardware budgets cap at `num_sms * max_blocks_per_sm`
    /// slots (480 on the RTX 2060 preset), so whole-slab sweeps in the
    /// event loop stay bounded by the GPU size, not the workload.
    blocks: Vec<BlockSlot>,
    free_blocks: Vec<u32>,
    live_blocks: usize,
    /// SMs with >= 1 resident block (occupancy integral term).
    busy_sms: u32,
    /// Global bandwidth running sum and its derived scale.
    total_bw_demand: f64,
    bw_scale: f64,
    /// Launch-overhead timers (ready_us, launch slot, tag), popped lazily.
    ready_timers: BinaryHeap<Reverse<(Tm, u32, LaunchTag)>>,
    /// Interned kernel names and flat per-name occupancy accumulators.
    names: NameTable,
    name_warp_time: Vec<f64>,
    name_active_time: Vec<f64>,
    name_seen_epoch: Vec<u64>,
    epoch: u64,
    /// Residency counters maintained incrementally for `snapshot`.
    critical_blocks: u32,
    normal_blocks: u32,
    /// (block_threads, count) of resident critical blocks.
    critical_thread_sizes: Vec<(u32, u32)>,
    critical_pending: u32,
    metrics: SimMetrics,
    next_tag: LaunchTag,
    rates_dirty: bool,
    /// Use the retained full-recompute rate model (differential oracle).
    reference_rates: bool,
    /// Optional event recorder ([`crate::gpu::trace`]). `None` (the
    /// default) costs one branch per hook — nothing is captured.
    trace: Option<TraceRecorder>,
    /// Memoized absolute time of the next internal event. Finish times are
    /// absolute, so advancing the clock does not invalidate the cache —
    /// only rate changes and new timers do (§Perf change #2).
    event_cache: Option<f64>,
}

impl Engine {
    /// An idle engine over `spec` with default contention parameters.
    pub fn new(spec: GpuSpec) -> Self {
        Self::with_params(spec, ContentionParams::default())
    }

    /// An idle engine with explicit contention parameters (calibration
    /// experiments; see EXPERIMENTS.md §Calib).
    pub fn with_params(spec: GpuSpec, params: ContentionParams) -> Self {
        let n = spec.num_sms as usize;
        let mut sm_heap = BinaryHeap::with_capacity(2 * n);
        for s in 0..n {
            sm_heap.push(Reverse((0u32, s as u32, 0u64)));
        }
        Engine {
            spec,
            params,
            now_us: 0.0,
            streams: Vec::new(),
            stream_order: Vec::new(),
            head_slot: Vec::new(),
            stream_masks: Vec::new(),
            sms: (0..n).map(|_| SmState::empty()).collect(),
            sm_resident: vec![Vec::new(); n],
            sm_bw_demand: vec![0.0; n],
            dirty_sms: Vec::with_capacity(n),
            sm_dirty: vec![false; n],
            sm_heap,
            sm_ver: vec![0; n],
            sm_heap_scratch: Vec::with_capacity(n),
            launches: Vec::new(),
            free_launches: Vec::new(),
            live_launches: 0,
            blocks: Vec::new(),
            free_blocks: Vec::new(),
            live_blocks: 0,
            busy_sms: 0,
            total_bw_demand: 0.0,
            bw_scale: 1.0,
            ready_timers: BinaryHeap::new(),
            names: NameTable::new(),
            name_warp_time: Vec::new(),
            name_active_time: Vec::new(),
            name_seen_epoch: Vec::new(),
            epoch: 0,
            critical_blocks: 0,
            normal_blocks: 0,
            critical_thread_sizes: Vec::new(),
            critical_pending: 0,
            metrics: SimMetrics::default(),
            next_tag: 1,
            rates_dirty: true,
            reference_rates: false,
            trace: None,
            event_cache: None,
        }
    }

    /// Switch to the retained full-recompute rate model (the seed's
    /// O(events × resident) algorithm). Used by differential property
    /// tests and as the "before" leg of the engine-throughput bench.
    pub fn with_reference_rates(mut self) -> Self {
        self.reference_rates = true;
        self
    }

    /// Enable the event-trace recorder: every submit, launch activation,
    /// block placement and launch completion is captured as a compact
    /// [`crate::gpu::trace::TraceEvent`]. Collect with [`Engine::take_trace`].
    pub fn with_trace(mut self) -> Self {
        self.trace = Some(TraceRecorder::new());
        self
    }

    /// Whether the trace recorder is active.
    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    /// Detach the recorded trace (if recording was enabled), resolving
    /// interned kernel names so the trace outlives the engine. Recording
    /// stops once taken.
    pub fn take_trace(&mut self) -> Option<Trace> {
        let names = &self.names;
        self.trace.take().map(|r| r.into_trace(names))
    }

    /// Number of streams created so far (ids are dense `0..num_streams`),
    /// so schedulers can size flat per-stream state.
    pub fn num_streams(&self) -> usize {
        self.streams.len()
    }

    /// Create a stream with the given dispatch priority (higher wins).
    pub fn add_stream(&mut self, priority: i32) -> StreamId {
        let id = self.streams.len() as StreamId;
        self.streams.push(Stream::new(id, priority));
        self.head_slot.push(None);
        self.stream_masks.push(SmMask::ALL);
        self.stream_order.push(id);
        let streams = &self.streams;
        self.stream_order
            .sort_by_key(|&i| (-streams[i as usize].priority, i));
        id
    }

    /// Constrain `stream`'s block placement to the SMs in `mask` (the
    /// hard-isolation partitioning of ISSUE 9). Takes effect immediately:
    /// already-activated launches with pending blocks re-attempt dispatch
    /// under the new mask at the current instant, so *widening* a mask
    /// (work-conserving spillover) places waiting blocks right away, and
    /// *narrowing* one stops new foreign placements at once — blocks
    /// already resident outside the new mask run to completion (lent SMs
    /// drain; there is no preemption, matching MPS semantics).
    ///
    /// An empty mask is legal but the stream must then hold no pending
    /// blocks — they could never place and the launch would never finish.
    /// Passing [`SmMask::ALL`] restores the unconstrained heap path.
    pub fn set_stream_mask(&mut self, stream: StreamId, mask: SmMask) {
        self.stream_masks[stream as usize] = mask;
        self.try_dispatch();
    }

    /// The placement constraint currently set for `stream`
    /// ([`SmMask::ALL`] unless [`Engine::set_stream_mask`] narrowed it).
    pub fn stream_mask(&self, stream: StreamId) -> SmMask {
        self.stream_masks[stream as usize]
    }

    /// Current simulated time (us).
    pub fn now_us(&self) -> f64 {
        self.now_us
    }

    /// The metrics accumulated so far (per-name occupancy is resolved
    /// only by [`Engine::into_metrics`]).
    pub fn metrics(&self) -> &SimMetrics {
        &self.metrics
    }

    /// The interned kernel-name table.
    pub fn names(&self) -> &NameTable {
        &self.names
    }

    /// Consume the engine, resolving interned per-name occupancy
    /// accumulators into the metrics maps (names are resolved once here,
    /// not per event).
    pub fn into_metrics(mut self) -> SimMetrics {
        self.metrics.sim_time_us = self.now_us;
        for (id, name) in self.names.iter() {
            let at = self.name_active_time[id as usize];
            if at > 0.0 {
                self.metrics
                    .occupancy
                    .per_name_warp_time
                    .insert(name.to_string(), self.name_warp_time[id as usize]);
                self.metrics
                    .occupancy
                    .per_name_active_time
                    .insert(name.to_string(), at);
            }
        }
        self.metrics
    }

    /// Submit a launch to a stream. Returns its tag.
    pub fn submit(&mut self, stream: StreamId, config: LaunchConfig,
                  criticality: Criticality) -> LaunchTag {
        self.submit_delayed(stream, config, criticality, 0.0)
    }

    /// Submit with an extra pre-dispatch delay (models scheduler-imposed
    /// synchronization cost, e.g. IB barriers).
    pub fn submit_delayed(&mut self, stream: StreamId, config: LaunchConfig,
                          criticality: Criticality, extra_delay_us: f64)
                          -> LaunchTag {
        let name_id = self.intern_name(&config.name);
        self.submit_interned(stream, name_id, config.shape(), criticality,
                             extra_delay_us)
    }

    /// Intern `name` into this engine's [`NameTable`], sizing the per-name
    /// accumulators. The returned id is valid for
    /// [`Engine::submit_interned`] on *this* engine only.
    pub fn intern_name(&mut self, name: &str) -> u32 {
        let id = self.names.intern(name);
        self.ensure_name_capacity(id);
        id
    }

    /// The zero-allocation submit path (ISSUE 3 fast path): geometry and
    /// work come as a `Copy` [`LaunchShape`] and the kernel name as a
    /// pre-interned id from [`Engine::intern_name`], so steady-state
    /// submitters (the Miriam coordinator's shard and critical paths)
    /// never build a name `String` per launch.
    pub fn submit_interned(&mut self, stream: StreamId, name_id: u32,
                           shape: LaunchShape, criticality: Criticality,
                           extra_delay_us: f64) -> LaunchTag {
        assert!((name_id as usize) < self.names.len(),
                "submit_interned: id {name_id} was never interned");
        assert!(shape.grid > 0, "launch {} has empty grid",
                self.names.resolve(name_id));
        assert!(shape.block_threads > 0
                    && shape.block_threads <= self.spec.max_threads_per_sm,
                "launch {} block size {} outside (0, {}]",
                self.names.resolve(name_id), shape.block_threads,
                self.spec.max_threads_per_sm);
        assert!(shape.flops > 0.0, "launch {} needs flops > 0",
                self.names.resolve(name_id));
        // A non-finite delay becomes a NaN ready time, and NaN heap keys
        // corrupt the timer ordering silently (see [`Tm::new`]).
        debug_assert!(extra_delay_us.is_finite(),
                      "launch {} has non-finite extra delay {extra_delay_us}",
                      self.names.resolve(name_id));
        self.ensure_name_capacity(name_id);
        let tag = self.next_tag;
        self.next_tag += 1;
        if let Some(tr) = self.trace.as_mut() {
            tr.record(TraceEventKind::Submit, self.now_us, tag, name_id,
                      stream);
        }
        self.streams[stream as usize].push(QueuedLaunch {
            tag,
            name_id,
            shape,
            criticality,
            extra_delay_us,
            submit_us: self.now_us,
        });
        self.activate_stream_heads();
        self.try_dispatch();
        tag
    }

    /// Remove still-queued (never activated) launches with the given
    /// tags from one stream, returning how many were removed (ISSUE 8
    /// recovery layer). Queued launches hold no SM residency and touch
    /// no dispatch counters until activation, so removal is pure queue
    /// surgery. Tags already activated (stream head or resident) are
    /// left untouched — there is no preemption; running work completes
    /// normally and its completion must be tolerated by the caller.
    pub fn cancel_queued(&mut self, stream: StreamId, tags: &[LaunchTag])
                         -> usize {
        let q = &mut self.streams[stream as usize].queue;
        let before = q.len();
        q.retain(|l| !tags.contains(&l.tag));
        before - q.len()
    }

    /// True when nothing is queued, dispatching, or executing.
    pub fn idle(&self) -> bool {
        self.live_launches == 0 && self.streams.iter().all(|s| s.is_empty())
    }

    /// Number of launches not yet completed.
    pub fn inflight(&self) -> usize {
        self.live_launches
            + self.streams.iter().map(|s| s.depth()).sum::<usize>()
    }

    fn ensure_name_capacity(&mut self, id: u32) {
        let need = id as usize + 1;
        if self.name_warp_time.len() < need {
            self.name_warp_time.resize(need, 0.0);
            self.name_active_time.resize(need, 0.0);
            self.name_seen_epoch.resize(need, 0);
        }
    }

    fn alloc_launch(&mut self, launch: ActiveLaunch) -> u32 {
        self.live_launches += 1;
        if let Some(slot) = self.free_launches.pop() {
            self.launches[slot as usize] = Some(launch);
            slot
        } else {
            self.launches.push(Some(launch));
            (self.launches.len() - 1) as u32
        }
    }

    fn alloc_block(&mut self, block: BlockSlot) -> u32 {
        self.live_blocks += 1;
        if let Some(slot) = self.free_blocks.pop() {
            self.blocks[slot as usize] = block;
            slot
        } else {
            self.blocks.push(block);
            (self.blocks.len() - 1) as u32
        }
    }

    fn mark_sm_dirty(&mut self, sm: usize) {
        if !self.sm_dirty[sm] {
            self.sm_dirty[sm] = true;
            self.dirty_sms.push(sm as u32);
        }
        self.rates_dirty = true;
        self.event_cache = None;
    }

    /// Re-key `sm` in the placement heap after its load changed. Stale
    /// entries are popped lazily by `pick_sm`; high-key stale entries can
    /// linger at the bottom, so once the heap outgrows a small multiple of
    /// the SM count it is rebuilt from the current entries — O(num_sms),
    /// amortized O(1) per bump.
    fn bump_sm_ver(&mut self, sm: usize) {
        self.sm_ver[sm] += 1;
        self.sm_heap
            .push(Reverse((self.sms[sm].threads_used, sm as u32,
                           self.sm_ver[sm])));
        if self.sm_heap.len() > 8 * self.sms.len() {
            self.sm_heap.clear();
            for (s, state) in self.sms.iter().enumerate() {
                self.sm_heap.push(Reverse((state.threads_used, s as u32,
                                           self.sm_ver[s])));
            }
        }
    }

    fn crit_threads_inc(&mut self, threads: u32) {
        match self
            .critical_thread_sizes
            .iter_mut()
            .find(|(t, _)| *t == threads)
        {
            Some((_, c)) => *c += 1,
            None => self.critical_thread_sizes.push((threads, 1)),
        }
    }

    fn crit_threads_dec(&mut self, threads: u32) {
        if let Some(pos) = self
            .critical_thread_sizes
            .iter()
            .position(|(t, _)| *t == threads)
        {
            self.critical_thread_sizes[pos].1 -= 1;
            if self.critical_thread_sizes[pos].1 == 0 {
                self.critical_thread_sizes.swap_remove(pos);
            }
        }
    }

    /// Promote stream heads whose turn has come into the launch slab. The
    /// queued launch is *moved* out of its stream (one ownership transfer,
    /// no clone).
    fn activate_stream_heads(&mut self) {
        for s in 0..self.streams.len() {
            if self.streams[s].head_active || self.streams[s].is_empty() {
                continue;
            }
            let q = self.streams[s].queue.pop_front().unwrap();
            let ready = self.now_us + self.spec.kernel_launch_us
                + q.extra_delay_us;
            self.streams[s].head_active = true;
            self.event_cache = None; // new launch-overhead timer
            if q.criticality == Criticality::Critical {
                self.critical_pending += q.shape.grid;
            }
            let launch = ActiveLaunch {
                tag: q.tag,
                stream: s as StreamId,
                name_id: q.name_id,
                criticality: q.criticality,
                submit_us: q.submit_us,
                ready_us: ready,
                start_us: None,
                blocks_pending: q.shape.grid,
                blocks_running: 0,
                block_threads: q.shape.block_threads,
                smem_per_block: q.shape.smem_per_block,
                regs_per_thread: q.shape.regs_per_thread,
                flops_per_block: q.shape.flops_per_block(),
                bytes_per_block: q.shape.bytes_per_block(),
            };
            let tag = launch.tag;
            let name_id = launch.name_id;
            let slot = self.alloc_launch(launch);
            self.head_slot[s] = Some(slot);
            self.ready_timers.push(Reverse((Tm::new(ready), slot, tag)));
            if let Some(tr) = self.trace.as_mut() {
                tr.record(TraceEventKind::Activate, self.now_us, tag, name_id,
                          s as u32);
            }
        }
    }

    /// Least-loaded (by threads) SM that fits `d`, via the placement heap.
    /// Pops stale entries lazily; current-but-unfit entries are set aside
    /// and reinserted, so the heap invariant (one current entry per SM)
    /// holds on return. Selection order matches a linear argmin scan:
    /// smallest `threads_used`, ties broken by smallest SM id.
    fn pick_sm(&mut self, d: &BlockDemand) -> Option<usize> {
        let mut found = None;
        while let Some(&Reverse(entry)) = self.sm_heap.peek() {
            let (_, sm, ver) = entry;
            let si = sm as usize;
            if self.sm_ver[si] != ver {
                self.sm_heap.pop(); // stale
                continue;
            }
            if self.sms[si].fits(d, &self.spec) {
                found = Some(si);
                break;
            }
            self.sm_heap.pop();
            self.sm_heap_scratch.push(entry);
        }
        for e in self.sm_heap_scratch.drain(..) {
            self.sm_heap.push(Reverse(e));
        }
        found
    }

    /// Least-loaded SM *within `mask`* that fits `d` — the
    /// mask-constrained placement path (ISSUE 9). A linear argmin over
    /// the masked SMs with exactly [`Engine::pick_sm`]'s selection order
    /// (smallest `threads_used`, ties broken by smallest SM id), so an
    /// explicit mask covering every SM reproduces the unmasked heap
    /// placement bitwise — pinned by `explicit_full_mask_matches_unmasked`
    /// and the isolation differential suite. Masked streams exist only
    /// under the isolation scheduler and edge devices have few SMs, so
    /// the O(num_sms) scan never touches the default hot path.
    fn pick_sm_masked(&self, d: &BlockDemand, mask: SmMask) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, sm) in self.sms.iter().enumerate() {
            if !mask.contains(i as u32) || !sm.fits(d, &self.spec) {
                continue;
            }
            match best {
                Some(b) if self.sms[b].threads_used <= sm.threads_used => {}
                _ => best = Some(i),
            }
        }
        best
    }

    /// Greedy block dispatcher: streams in priority order (FIFO within a
    /// stream — only the head launch dispatches); for each, place pending
    /// blocks on the least-loaded SM that fits. Lower-priority blocks may
    /// fill around a higher-priority launch that does not fit (hardware
    /// work-distributor behaviour per Gilman et al. [9]).
    fn try_dispatch(&mut self) {
        for oi in 0..self.stream_order.len() {
            let si = self.stream_order[oi] as usize;
            if !self.streams[si].head_active {
                continue;
            }
            let Some(slot) = self.head_slot[si] else { continue };
            let (ready, pending0, demand, tag, crit, name_id, threads, fpb,
                 bpb) = {
                let l = self.launches[slot as usize].as_ref().unwrap();
                (l.ready_us, l.blocks_pending, l.demand(), l.tag,
                 l.criticality, l.name_id, l.block_threads,
                 l.flops_per_block, l.bytes_per_block)
            };
            if ready > self.now_us || pending0 == 0 {
                continue; // still in launch overhead, or fully dispatched
            }
            let demand_flops =
                standalone_demand(&self.spec, &self.params, threads);
            let warps = threads.div_ceil(self.spec.warp_size) as f64;
            let memory_bound = bpb > 0.0 && fpb > 0.0;
            // Mask read per placement (not per launch lifetime): narrowing
            // a mask mid-launch stops further foreign placements at once.
            let mask = self.stream_masks[si];
            let mut pending = pending0;
            while pending > 0 {
                let picked = if mask.is_all() {
                    self.pick_sm(&demand)
                } else {
                    self.pick_sm_masked(&demand, mask)
                };
                let Some(sm_idx) = picked else { break };
                self.sms[sm_idx].admit(&demand, tag, demand_flops);
                if self.sms[sm_idx].blocks_resident == 1 {
                    self.busy_sms += 1;
                }
                self.bump_sm_ver(sm_idx);
                self.mark_sm_dirty(sm_idx);
                pending -= 1;
                {
                    let l = self.launches[slot as usize].as_mut().unwrap();
                    l.blocks_pending -= 1;
                    l.blocks_running += 1;
                    if l.start_us.is_none() {
                        l.start_us = Some(self.now_us);
                    }
                }
                match crit {
                    Criticality::Critical => {
                        self.critical_blocks += 1;
                        self.critical_pending -= 1;
                        self.crit_threads_inc(threads);
                    }
                    Criticality::Normal => self.normal_blocks += 1,
                }
                let pos = self.sm_resident[sm_idx].len() as u32;
                let bslot = self.alloc_block(BlockSlot {
                    live: true,
                    tag,
                    launch: slot,
                    sm: sm_idx as u32,
                    pos_in_sm: pos,
                    name_id,
                    criticality: crit,
                    threads,
                    warps,
                    demand: demand_flops,
                    flops_per_block: fpb,
                    bytes_per_block: bpb,
                    memory_bound,
                    remaining: fpb,
                    cr: 0.0,
                });
                self.sm_resident[sm_idx].push(bslot);
                if let Some(tr) = self.trace.as_mut() {
                    tr.record(TraceEventKind::BlockPlace, self.now_us, tag,
                              name_id, sm_idx as u32);
                }
            }
        }
    }

    /// Incremental rate refresh: only SMs whose residency changed are
    /// revisited; the bandwidth term updates as a running per-SM sum.
    fn refresh_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        if self.reference_rates {
            self.refresh_rates_reference();
            self.rates_dirty = false;
            return;
        }
        while let Some(s) = self.dirty_sms.pop() {
            let si = s as usize;
            self.sm_dirty[si] = false;
            let scale = intra_sm_scale(&self.spec, self.sms[si].compute_demand);
            let mut sm_bw = 0.0;
            for k in 0..self.sm_resident[si].len() {
                let bi = self.sm_resident[si][k] as usize;
                let penalty = foreign_penalty(
                    &self.spec,
                    &self.params,
                    self.sms[si].threads_used,
                    self.sms[si].own_threads(self.blocks[bi].tag),
                );
                let b = &mut self.blocks[bi];
                b.cr = b.demand * scale * penalty;
                if b.memory_bound {
                    sm_bw += b.cr * b.bytes_per_block / b.flops_per_block;
                }
            }
            self.total_bw_demand += sm_bw - self.sm_bw_demand[si];
            self.sm_bw_demand[si] = sm_bw;
        }
        if self.live_blocks == 0 {
            // Exact reset: the running sum cannot drift across idle gaps.
            self.total_bw_demand = 0.0;
        }
        self.bw_scale = bandwidth_scale(&self.spec, self.total_bw_demand);
        self.rates_dirty = false;
    }

    /// The seed's O(events × resident) algorithm: rebuild the full
    /// `BlockWork` set and recompute every rate through the reference
    /// model. Kept as the differential-testing oracle and the perf
    /// baseline; the allocations here are the point.
    fn refresh_rates_reference(&mut self) {
        let mut works = Vec::with_capacity(self.live_blocks);
        let mut slots = Vec::with_capacity(self.live_blocks);
        for (i, b) in self.blocks.iter().enumerate() {
            if b.live {
                works.push(BlockWork {
                    sm: b.sm,
                    threads: b.threads,
                    flops: b.flops_per_block,
                    bytes: b.bytes_per_block,
                    kernel: b.tag,
                });
                slots.push(i);
            }
        }
        let rates = block_rates(&self.spec, &self.params, &works);
        for (i, r) in slots.into_iter().zip(rates) {
            self.blocks[i].cr = r;
        }
        self.bw_scale = 1.0; // final rates already carry the bw term
        while let Some(s) = self.dirty_sms.pop() {
            self.sm_dirty[s as usize] = false;
        }
    }

    /// Time of the next internal event (block completion or launch-overhead
    /// expiry), if any.
    pub fn next_event_time(&mut self) -> Option<f64> {
        self.refresh_rates();
        if let Some(t) = self.event_cache {
            return if t.is_finite() { Some(t) } else { None };
        }
        let mut t = f64::INFINITY;
        let bw = self.bw_scale;
        for b in &self.blocks {
            if !b.live {
                continue;
            }
            let rate = if b.memory_bound { b.cr * bw } else { b.cr };
            if rate > 0.0 {
                t = t.min(self.now_us + b.remaining / rate);
            }
        }
        // A launch waiting out its overhead (with pending blocks) wakes
        // the engine at ready_us. Expired or dead timers pop lazily.
        while let Some(&Reverse((Tm(rt), slot, tag))) = self.ready_timers.peek()
        {
            let live = self
                .launches
                .get(slot as usize)
                .and_then(|l| l.as_ref())
                .is_some_and(|l| l.tag == tag && l.blocks_pending > 0);
            if live && rt > self.now_us {
                t = t.min(rt);
                break;
            }
            self.ready_timers.pop();
        }
        self.event_cache = Some(t);
        if t.is_finite() {
            Some(t)
        } else {
            None
        }
    }

    /// Advance simulated time to `t` (must be <= next_event_time), accruing
    /// occupancy integrals. No completions may occur inside the window.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now_us - 1e-9, "time went backwards");
        let dt = (t - self.now_us).max(0.0);
        if dt > 0.0 {
            self.refresh_rates();
            self.metrics.occupancy.active_sm_time += self.busy_sms as f64 * dt;
            // Per-name attribution, productivity-weighted: a warp making
            // `rate/entitled` of its solo progress counts as that fraction
            // of an active warp. Flat-Vec accumulators indexed by interned
            // name id; the epoch stamp dedups active-time per interval.
            self.epoch += 1;
            let epoch = self.epoch;
            let bw = self.bw_scale;
            let mut warp_time = 0.0;
            for b in &mut self.blocks {
                if !b.live {
                    continue;
                }
                let rate = if b.memory_bound { b.cr * bw } else { b.cr };
                let weight = if b.demand > 0.0 {
                    (rate / b.demand).min(1.0)
                } else {
                    1.0
                };
                let w = b.warps * weight;
                warp_time += w;
                let id = b.name_id as usize;
                self.name_warp_time[id] += w * dt;
                if self.name_seen_epoch[id] != epoch {
                    self.name_seen_epoch[id] = epoch;
                    self.name_active_time[id] += dt;
                }
                b.remaining -= rate * dt;
            }
            self.metrics.occupancy.warp_time += warp_time * dt;
        }
        self.now_us = t;
    }

    /// Retire one finished block; emits a [`Completion`] when it was the
    /// launch's last.
    fn complete_block(&mut self, bi: usize,
                      completions: &mut Vec<Completion>) {
        let (tag, lslot, sm, pos, crit, threads) = {
            let b = &mut self.blocks[bi];
            b.live = false;
            (b.tag, b.launch as usize, b.sm as usize, b.pos_in_sm as usize,
             b.criticality, b.threads)
        };
        self.free_blocks.push(bi as u32);
        self.live_blocks -= 1;
        let _ = self.sm_resident[sm].swap_remove(pos);
        if pos < self.sm_resident[sm].len() {
            let moved = self.sm_resident[sm][pos] as usize;
            self.blocks[moved].pos_in_sm = pos as u32;
        }
        let demand = self.launches[lslot].as_ref().unwrap().demand();
        let demand_flops = standalone_demand(&self.spec, &self.params, threads);
        self.sms[sm].release(&demand, tag, demand_flops);
        if self.sms[sm].blocks_resident == 0 {
            self.busy_sms -= 1;
        }
        self.bump_sm_ver(sm);
        self.mark_sm_dirty(sm);
        match crit {
            Criticality::Critical => {
                self.critical_blocks -= 1;
                self.crit_threads_dec(threads);
            }
            Criticality::Normal => self.normal_blocks -= 1,
        }
        let finished = {
            let l = self.launches[lslot].as_mut().unwrap();
            l.blocks_running -= 1;
            l.finished()
        };
        if finished {
            let l = self.launches[lslot].take().unwrap();
            self.free_launches.push(lslot as u32);
            self.live_launches -= 1;
            // Free the stream head, making the next launch eligible.
            self.head_slot[l.stream as usize] = None;
            self.streams[l.stream as usize].head_active = false;
            if let Some(tr) = self.trace.as_mut() {
                tr.record(TraceEventKind::Complete, self.now_us, l.tag,
                          l.name_id, l.stream);
            }
            let record = LaunchRecord {
                tag: l.tag,
                name: self.names.resolve(l.name_id).to_string(),
                stream: l.stream,
                criticality: l.criticality,
                submit_us: l.submit_us,
                start_us: l.start_us.unwrap_or(l.submit_us),
                end_us: self.now_us,
            };
            self.metrics.records.push(record.clone());
            completions.push(Completion { tag: l.tag, record });
        }
    }

    /// Process the next internal event. Returns completions that fired.
    /// `step()` advances to the event time itself; callers that want to
    /// avoid the per-event `Vec` use [`Engine::step_into`].
    pub fn step(&mut self) -> Vec<Completion> {
        let mut completions = Vec::new();
        self.step_into(&mut completions);
        completions
    }

    /// [`Engine::step`] into a caller-owned buffer (cleared first), so an
    /// event loop reuses one completions allocation across events — the
    /// driver's steady state allocates nothing per event beyond the one
    /// record `String` per *launch* completion (EXPERIMENTS.md §Perf).
    pub fn step_into(&mut self, completions: &mut Vec<Completion>) {
        completions.clear();
        let Some(t) = self.next_event_time() else {
            return;
        };
        self.advance_to(t);
        self.metrics.events += 1;
        // The event at `t` is being consumed (completion or timer expiry):
        // the cached next-event time is spent either way.
        self.event_cache = None;
        // Collect finished blocks. The threshold is *time*-relative: a block
        // whose remaining work amounts to less simulated time than f64 can
        // resolve at `now` must complete now, or `now + remaining/rate`
        // rounds back to `now` and the event loop livelocks (dt == 0, work
        // never decreases). `slack` is ~1000 ULPs of `now` plus a picosecond
        // floor — nanoseconds at most, far below kernel timescales.
        let slack = self.now_us.abs() * 1e-12 + 1e-6;
        let bw = self.bw_scale;
        for bi in 0..self.blocks.len() {
            let b = &self.blocks[bi];
            if !b.live {
                continue;
            }
            let rate = if b.memory_bound { b.cr * bw } else { b.cr };
            if b.remaining <= rate * slack {
                self.complete_block(bi, completions);
            }
        }
        self.activate_stream_heads();
        self.try_dispatch();
    }

    /// Run until the engine is idle; returns all completions in order.
    pub fn run_to_idle(&mut self) -> Vec<Completion> {
        let mut all = Vec::new();
        while self.next_event_time().is_some() {
            all.extend(self.step());
        }
        all
    }

    /// The scalar residency counters (no allocation; see [`Residency`]).
    pub fn residency(&self) -> Residency {
        Residency {
            now_us: self.now_us,
            critical_blocks: self.critical_blocks,
            critical_block_threads: self
                .critical_thread_sizes
                .iter()
                .map(|&(t, _)| t)
                .max()
                .unwrap_or(0),
            critical_pending: self.critical_pending,
            normal_blocks: self.normal_blocks,
        }
    }

    /// Snapshot for scheduling policies and tests. All counters are
    /// maintained incrementally on dispatch/completion, so this never
    /// walks the residency set — but it does allocate the per-SM vectors;
    /// policies that only need totals should use [`Engine::residency`].
    pub fn snapshot(&self) -> GpuSnapshot {
        let r = self.residency();
        GpuSnapshot {
            now_us: r.now_us,
            sm_threads_used: self.sms.iter().map(|s| s.threads_used).collect(),
            sm_blocks: self.sms.iter().map(|s| s.blocks_resident).collect(),
            critical_blocks: r.critical_blocks,
            critical_block_threads: r.critical_block_threads,
            critical_pending: r.critical_pending,
            normal_blocks: r.normal_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(name: &str, grid: u32, threads: u32, flops: f64, bytes: f64) -> LaunchConfig {
        LaunchConfig {
            name: name.into(),
            grid,
            block_threads: threads,
            smem_per_block: 0,
            regs_per_thread: 32,
            flops,
            bytes,
        }
    }

    #[test]
    fn single_kernel_solo_latency() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        // 30 blocks of 512 threads: one per SM, each saturating its SM.
        // flops 30 * 215000 -> 1us of compute + 5us launch overhead.
        e.submit(s, cfg("k", 30, 512, 30.0 * 215_000.0, 0.0),
                 Criticality::Normal);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 1);
        let lat = done[0].record.latency_us();
        assert!((lat - 6.0).abs() < 1e-6, "latency {lat}");
    }

    #[test]
    fn stream_fifo_is_sequential() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(0);
        e.submit(s, cfg("a", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        e.submit(s, cfg("b", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].record.name, "a");
        assert_eq!(done[1].record.name, "b");
        // b cannot start before a completes.
        assert!(done[1].record.start_us >= done[0].record.end_us - 1e-9);
    }

    #[test]
    fn two_streams_overlap() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s0 = e.add_stream(0);
        let s1 = e.add_stream(0);
        // Each kernel occupies half the SM's threads; both fit concurrently.
        e.submit(s0, cfg("a", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        e.submit(s1, cfg("b", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        let a = done.iter().find(|c| c.record.name == "a").unwrap();
        let b = done.iter().find(|c| c.record.name == "b").unwrap();
        // They overlap in time (start of one before end of the other).
        assert!(a.record.start_us < b.record.end_us);
        assert!(b.record.start_us < a.record.end_us);
    }

    #[test]
    fn contention_slows_corunners() {
        let spec = GpuSpec::rtx2060();
        // Solo run: 30 blocks, one per SM (512 threads leaves half free).
        let mut e1 = Engine::new(spec.clone());
        let s = e1.add_stream(0);
        e1.submit(s, cfg("k", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let solo = e1.run_to_idle()[0].record.latency_us();
        // Same kernel co-resident with a rival occupying the other half of
        // every SM: the foreign-interference term must slow it down.
        let mut e2 = Engine::new(spec);
        let s0 = e2.add_stream(0);
        let s1 = e2.add_stream(0);
        e2.submit(s0, cfg("rival", 30, 512, 30.0 * 4.0 * 215_000.0, 0.0),
                  Criticality::Normal);
        e2.submit(s1, cfg("k", 30, 512, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        let done = e2.run_to_idle();
        let co = done.iter().find(|c| c.record.name == "k").unwrap()
            .record.latency_us();
        assert!(co > solo * 1.2, "co {co} vs solo {solo}");
    }

    #[test]
    fn priority_stream_dispatches_first() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let hi = e.add_stream(10);
        let lo = e.add_stream(0);
        // Both kernels want every thread slot; the hi-priority one must
        // get dispatched first even though submitted second.
        let big = 30 * 2; // 2 full waves of 1024-thread blocks
        e.submit(lo, cfg("lo", big, 1024, big as f64 * 215_000.0, 0.0),
                 Criticality::Normal);
        e.submit(hi, cfg("hi", big, 1024, big as f64 * 215_000.0, 0.0),
                 Criticality::Critical);
        let done = e.run_to_idle();
        let hi_rec = done.iter().find(|c| c.record.name == "hi").unwrap();
        let lo_rec = done.iter().find(|c| c.record.name == "lo").unwrap();
        // Equal submit-to-dispatch conditions; priority should let "hi"
        // finish no later than "lo".
        assert!(hi_rec.record.end_us <= lo_rec.record.end_us + 1e-9);
    }

    #[test]
    fn work_conservation() {
        // Total executed FLOPs = submitted FLOPs (no lost/duplicated work):
        // checked indirectly via makespan = work / peak on a saturating
        // workload with no memory pressure.
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        let waves = 4;
        let grid = spec.num_sms * waves;
        let flops = grid as f64 * 215_000.0; // 1us per block when saturated
        e.submit(s, cfg("k", grid, 1024, flops, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        let span = done[0].record.end_us - done[0].record.start_us;
        assert!((span - waves as f64).abs() < 1e-6, "span {span}");
    }

    #[test]
    fn occupancy_accrues() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 30, 1024, 30.0 * 215_000.0, 0.0), Criticality::Normal);
        e.run_to_idle();
        let m = e.into_metrics();
        // Full SM occupancy while active.
        let occ = m.occupancy.achieved(&spec);
        assert!((occ - 1.0).abs() < 1e-9, "occ {occ}");
    }

    #[test]
    fn launch_overhead_delays_start() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 1, 32, 1000.0, 0.0), Criticality::Normal);
        let done = e.run_to_idle();
        assert!(done[0].record.start_us >= 5.0 - 1e-9);
    }

    #[test]
    fn extra_delay_adds_to_overhead() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(0);
        e.submit_delayed(s, cfg("k", 1, 32, 1000.0, 0.0),
                         Criticality::Normal, 100.0);
        let done = e.run_to_idle();
        assert!(done[0].record.start_us >= 105.0 - 1e-9);
    }

    #[test]
    fn snapshot_reports_residency() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec);
        let s = e.add_stream(5);
        e.submit(s, cfg("crit", 10, 256, 1e7, 0.0), Criticality::Critical);
        // Advance past launch overhead so blocks dispatch.
        let t = e.next_event_time().unwrap();
        e.advance_to(t);
        e.step();
        let snap = e.snapshot();
        assert!(snap.critical_blocks > 0 || snap.critical_pending > 0);
        assert_eq!(snap.normal_blocks, 0);
    }

    #[test]
    #[should_panic(expected = "empty grid")]
    fn zero_grid_rejected() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        let s = e.add_stream(0);
        e.submit(s, cfg("bad", 0, 32, 1.0, 0.0), Criticality::Normal);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite extra delay")]
    fn non_finite_delay_rejected_in_debug() {
        // A NaN delay would produce a NaN timer key and corrupt the
        // BinaryHeap ordering silently (ISSUE 3 satellite).
        let mut e = Engine::new(GpuSpec::rtx2060());
        let s = e.add_stream(0);
        e.submit_delayed(s, cfg("k", 1, 32, 1000.0, 0.0),
                         Criticality::Normal, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "never interned")]
    fn uninterned_id_rejected() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        let s = e.add_stream(0);
        let shape = cfg("k", 1, 32, 1000.0, 0.0).shape();
        e.submit_interned(s, 7, shape, Criticality::Normal, 0.0);
    }

    #[test]
    fn interned_submit_matches_string_submit() {
        // The id+shape path and the LaunchConfig path must be the same
        // launch: identical trajectory and resolved record names.
        let run = |interned: bool| {
            let mut e = Engine::new(GpuSpec::rtx2060());
            let s = e.add_stream(0);
            for i in 0..3 {
                let c = cfg("k", 4 + i, 256, 4e6, 1e4);
                if interned {
                    let id = e.intern_name("k");
                    e.submit_interned(s, id, c.shape(), Criticality::Normal,
                                      0.0);
                } else {
                    e.submit(s, c, Criticality::Normal);
                }
            }
            e.run_to_idle()
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tag, y.tag);
            assert_eq!(x.record.name, y.record.name);
            assert!((x.record.end_us - y.record.end_us).abs() < 1e-12);
        }
    }

    #[test]
    fn step_into_reuses_buffer_and_matches_step() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        let s = e.add_stream(0);
        for i in 0..4 {
            e.submit(s, cfg(&format!("k{i}"), 2, 256, 5e5, 0.0),
                     Criticality::Normal);
        }
        let mut buf = Vec::new();
        let mut seen = Vec::new();
        while e.next_event_time().is_some() {
            e.step_into(&mut buf);
            seen.extend(buf.iter().map(|c| c.tag));
        }
        assert_eq!(seen.len(), 4);
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 3, 4]);
        // Residency totals agree with the allocating snapshot.
        let r = e.residency();
        let snap = e.snapshot();
        assert_eq!(r.critical_blocks, snap.critical_blocks);
        assert_eq!(r.normal_blocks, snap.normal_blocks);
        assert_eq!(r.critical_pending, snap.critical_pending);
    }

    #[test]
    fn idle_engine_has_no_events() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        e.add_stream(0);
        assert!(e.next_event_time().is_none());
        assert!(e.idle());
        assert!(e.step().is_empty());
    }

    #[test]
    fn indexed_placement_spreads_like_least_loaded() {
        // 60 equal blocks on 30 SMs: the heap-driven placement must land
        // exactly 2 per SM, like the linear least-loaded scan it replaces.
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 60, 256, 60.0 * 215_000.0, 0.0),
                 Criticality::Normal);
        let t = e.next_event_time().unwrap();
        e.advance_to(t);
        e.step(); // overhead expiry -> dispatch
        let snap = e.snapshot();
        assert!(snap.sm_blocks.iter().all(|&b| b == 2),
                "uneven placement: {:?}", snap.sm_blocks);
        e.run_to_idle();
    }

    #[test]
    fn names_are_interned_once() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        let s = e.add_stream(0);
        for _ in 0..3 {
            e.submit(s, cfg("same", 1, 32, 1000.0, 0.0), Criticality::Normal);
        }
        e.submit(s, cfg("other", 1, 32, 1000.0, 0.0), Criticality::Normal);
        assert_eq!(e.names().len(), 2);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 4);
        assert_eq!(done[0].record.name, "same");
        assert_eq!(done[3].record.name, "other");
    }

    #[test]
    fn snapshot_counters_return_to_zero_at_idle() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        let hi = e.add_stream(10);
        let lo = e.add_stream(0);
        e.submit(hi, cfg("c", 40, 512, 4e6, 1e5), Criticality::Critical);
        e.submit(lo, cfg("n", 40, 256, 4e6, 0.0), Criticality::Normal);
        e.run_to_idle();
        let snap = e.snapshot();
        assert_eq!(snap.critical_blocks, 0);
        assert_eq!(snap.normal_blocks, 0);
        assert_eq!(snap.critical_pending, 0);
        assert_eq!(snap.critical_block_threads, 0);
        assert!(snap.sm_threads_used.iter().all(|&t| t == 0));
        assert!(e.idle());
    }

    #[test]
    fn trace_records_lifecycle_in_order() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone()).with_trace();
        assert!(e.trace_enabled());
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 2, 256, 2.0 * 215_000.0, 0.0),
                 Criticality::Normal);
        e.run_to_idle();
        let t = e.take_trace().expect("trace was enabled");
        assert!(e.take_trace().is_none(), "trace taken twice");
        use crate::gpu::trace::TraceEventKind as K;
        assert_eq!(t.count_of(K::Submit), 1);
        assert_eq!(t.count_of(K::Activate), 1);
        assert_eq!(t.count_of(K::BlockPlace), 2);
        assert_eq!(t.count_of(K::Complete), 1);
        // Lifecycle order: submit first, complete last, places after the
        // launch-overhead window.
        assert_eq!(t.events.first().unwrap().kind, K::Submit);
        assert_eq!(t.events.last().unwrap().kind, K::Complete);
        for ev in &t.events {
            assert_eq!(t.name_of(ev), "k");
            if ev.kind == K::BlockPlace {
                assert!(ev.loc < spec.num_sms);
                assert!(ev.t_us >= spec.kernel_launch_us - 1e-9);
            }
        }
        // Times are monotone along the event list.
        for w in t.events.windows(2) {
            assert!(w[1].t_us >= w[0].t_us - 1e-9);
        }
    }

    #[test]
    fn trace_is_absent_when_disabled() {
        let mut e = Engine::new(GpuSpec::rtx2060());
        assert!(!e.trace_enabled());
        let s = e.add_stream(0);
        e.submit(s, cfg("k", 1, 32, 1000.0, 0.0), Criticality::Normal);
        e.run_to_idle();
        assert!(e.take_trace().is_none());
    }

    #[test]
    fn reference_mode_matches_incremental_mode() {
        // The retained full-recompute oracle and the incremental aggregate
        // path must produce the same trajectory on a contended workload
        // (same completion order; latencies equal to ~1e-9 relative).
        let run = |reference: bool| {
            let mut e = Engine::new(GpuSpec::tx2());
            if reference {
                e = e.with_reference_rates();
            }
            let s0 = e.add_stream(5);
            let s1 = e.add_stream(0);
            for i in 0..6 {
                let stream = if i % 2 == 0 { s0 } else { s1 };
                let crit = if i % 2 == 0 {
                    Criticality::Critical
                } else {
                    Criticality::Normal
                };
                e.submit(stream,
                         cfg(&format!("k{i}"), 4 + i, 128 + 64 * i,
                             1e6 + i as f64 * 3e5, i as f64 * 2e4),
                         crit);
            }
            e.run_to_idle()
        };
        let inc = run(false);
        let refr = run(true);
        assert_eq!(inc.len(), refr.len());
        for (a, b) in inc.iter().zip(&refr) {
            assert_eq!(a.tag, b.tag, "completion order diverged");
            let denom = b.record.end_us.abs().max(1.0);
            assert!((a.record.end_us - b.record.end_us).abs() / denom <= 1e-9,
                    "tag {}: end {} vs {}", a.tag, a.record.end_us,
                    b.record.end_us);
        }
    }

    #[test]
    fn explicit_full_mask_matches_unmasked() {
        // The differential backbone of the masked path: a mask covering
        // every SM must reproduce the heap placement *bitwise*, since
        // pick_sm_masked is specified as the same argmin order.
        let spec = GpuSpec::rtx2060();
        let run = |mask: bool| {
            let mut e = Engine::new(spec.clone()).with_trace();
            let s0 = e.add_stream(10);
            let s1 = e.add_stream(0);
            if mask {
                let full = SmMask::range(0, spec.num_sms);
                assert!(!full.is_all(), "test needs the non-sentinel path");
                e.set_stream_mask(s0, full);
                e.set_stream_mask(s1, full);
            }
            for i in 0..5u32 {
                let stream = if i % 2 == 0 { s0 } else { s1 };
                let crit = if i % 2 == 0 {
                    Criticality::Critical
                } else {
                    Criticality::Normal
                };
                e.submit(stream,
                         cfg(&format!("k{i}"), 20 + 7 * i, 128 + 64 * i,
                             1e6 + i as f64 * 2e5, i as f64 * 1e4),
                         crit);
            }
            e.run_to_idle();
            e.take_trace().unwrap().to_canonical_json()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn masked_stream_places_only_inside_mask() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone()).with_trace();
        let s = e.add_stream(0);
        e.set_stream_mask(s, SmMask::range(0, 4));
        // 12 blocks onto a 4-SM partition: 3 resident per SM, none outside.
        e.submit(s, cfg("k", 12, 256, 12.0 * 215_000.0, 0.0),
                 Criticality::Normal);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 1);
        let t = e.take_trace().unwrap();
        use crate::gpu::trace::TraceEventKind as K;
        assert_eq!(t.count_of(K::BlockPlace), 12);
        for ev in &t.events {
            if ev.kind == K::BlockPlace {
                assert!(ev.loc < 4, "block placed on SM {} outside 0..4",
                        ev.loc);
            }
        }
    }

    #[test]
    fn widening_mask_dispatches_waiting_blocks() {
        let spec = GpuSpec::rtx2060();
        let mut e = Engine::new(spec.clone());
        let s = e.add_stream(0);
        // One SM holds at most 4 blocks of 256 threads; 8 blocks on a
        // 1-SM partition leave 4 waiting once the partition saturates.
        e.set_stream_mask(s, SmMask::range(0, 1));
        e.submit(s, cfg("k", 8, 256, 8.0 * 215_000.0, 0.0),
                 Criticality::Normal);
        // Step past launch overhead so blocks dispatch.
        while e.snapshot().normal_blocks == 0 {
            assert!(e.step().is_empty(), "completed before placing blocks");
        }
        let narrow = e.snapshot();
        assert_eq!(narrow.normal_blocks, 4, "partition should saturate");
        assert!(narrow.sm_threads_used[1..].iter().all(|&t| t == 0));
        // Spillover: widening the mask places the waiting blocks now.
        e.set_stream_mask(s, SmMask::range(0, spec.num_sms));
        let wide = e.snapshot();
        assert_eq!(wide.normal_blocks, 8, "widened mask should dispatch");
        assert!(wide.sm_threads_used[1] > 0);
        let done = e.run_to_idle();
        assert_eq!(done.len(), 1);
    }
}
