//! GPU streams: FIFO kernel queues with priorities.
//!
//! Kernels in the same stream execute strictly in order (paper §3); kernels
//! in different streams may overlap. Stream priority orders *block
//! dispatch* across streams (NVIDIA priority streams), which is the
//! mechanism the Multi-stream baseline (§8.1.3) and Miriam's critical
//! stream rely on.

use std::collections::VecDeque;

use crate::gpu::kernel::{Criticality, LaunchShape};

/// Dense stream identifier (`0..Engine::num_streams`).
pub type StreamId = u32;
/// Unique, monotonically increasing id the engine assigns per launch.
pub type LaunchTag = u64;

/// A launch queued on a stream, waiting for its turn. Carries only the
/// interned name id and the `Copy` geometry/work [`LaunchShape`] — no
/// `String`, so queueing a launch never allocates beyond the queue slot
/// itself (ISSUE 3 zero-clone fast path).
#[derive(Debug, Clone, Copy)]
pub struct QueuedLaunch {
    /// The launch's engine-assigned tag.
    pub tag: LaunchTag,
    /// Interned id of the launch name in the engine's
    /// [`crate::gpu::names::NameTable`], assigned at submit.
    pub name_id: u32,
    /// Launch geometry and work.
    pub shape: LaunchShape,
    /// Task class of the submitting request.
    pub criticality: Criticality,
    /// Extra delay (us) before the launch may start dispatching once it
    /// reaches the head of its stream — models sync/barrier costs the
    /// scheduler imposes (e.g. the IB baseline's inter-stream barriers) on
    /// top of the hardware launch overhead.
    pub extra_delay_us: f64,
    /// Simulation time at which the launch was submitted.
    pub submit_us: f64,
}

/// One GPU stream.
#[derive(Debug)]
pub struct Stream {
    /// This stream's id.
    pub id: StreamId,
    /// Larger value = higher dispatch priority.
    pub priority: i32,
    /// Launches waiting behind the active head.
    pub queue: VecDeque<QueuedLaunch>,
    /// Whether a launch from this stream is currently dispatching or
    /// executing (a stream runs at most one kernel at a time). The active
    /// launch is moved out of `queue` into the engine's launch slab at
    /// activation, so `queue` only holds waiting launches.
    pub head_active: bool,
}

impl Stream {
    /// An empty stream with the given dispatch priority.
    pub fn new(id: StreamId, priority: i32) -> Self {
        Stream { id, priority, queue: VecDeque::new(), head_active: false }
    }

    /// Enqueue a launch at the back (FIFO within the stream).
    pub fn push(&mut self, launch: QueuedLaunch) {
        self.queue.push_back(launch);
    }

    /// Whether no launches are waiting (the active head, if any, has
    /// already left the queue).
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Number of launches waiting (the active head, if any, has already
    /// been moved out of the queue).
    pub fn depth(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn launch(tag: u64) -> QueuedLaunch {
        QueuedLaunch {
            tag,
            name_id: tag as u32,
            shape: LaunchShape {
                grid: 1,
                block_threads: 32,
                smem_per_block: 0,
                regs_per_thread: 16,
                flops: 1.0,
                bytes: 0.0,
            },
            criticality: Criticality::Normal,
            extra_delay_us: 0.0,
            submit_us: 0.0,
        }
    }

    #[test]
    fn fifo_order() {
        let mut s = Stream::new(0, 0);
        s.push(launch(1));
        s.push(launch(2));
        assert_eq!(s.depth(), 2);
        assert_eq!(s.queue.pop_front().unwrap().tag, 1);
        assert_eq!(s.queue.pop_front().unwrap().tag, 2);
        assert!(s.is_empty());
    }
}
