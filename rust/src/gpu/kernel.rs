//! Kernel descriptors and launch configurations.
//!
//! A [`KernelDesc`] is the simulator's view of one GPU kernel: its launch
//! geometry (grid x block) plus aggregate work (FLOPs, DRAM bytes) and
//! per-block resource demands. Miriam never inspects kernel *code* at
//! runtime — only launch geometry and occupancy (paper §6) — so descriptors
//! expose exactly the interface the real system consumes.


/// Task criticality (paper §4: critical tasks have hard real-time
/// requirements; normal tasks run best-effort).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Criticality {
    /// Hard real-time task: latency protected, never shed.
    Critical,
    /// Best-effort task: padded into leftover resources, may be shed by
    /// the online admission controller.
    Normal,
}

/// Static description of a GPU kernel as authored/compiled (before any
/// elastic transformation).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDesc {
    /// Kernel name, e.g. "alexnet/conv2".
    pub name: String,
    /// Number of thread blocks in the grid.
    pub grid: u32,
    /// Threads per block.
    pub block_threads: u32,
    /// Shared memory per block, bytes.
    pub smem_per_block: u32,
    /// Registers per thread.
    pub regs_per_thread: u32,
    /// Total kernel FLOPs.
    pub flops: f64,
    /// Total DRAM traffic in bytes (reads + writes past the cache).
    pub bytes: f64,
}

impl KernelDesc {
    /// FLOPs carried by one thread block.
    pub fn flops_per_block(&self) -> f64 {
        self.flops / self.grid as f64
    }

    /// DRAM bytes carried by one thread block.
    pub fn bytes_per_block(&self) -> f64 {
        self.bytes / self.grid as f64
    }

    /// Arithmetic intensity (FLOP/byte) — decides whether the kernel is
    /// compute- or memory-bound on a given spec (the "contention channel"
    /// of DeepEye/Abacus the paper contrasts with).
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.bytes > 0.0 {
            self.flops / self.bytes
        } else {
            f64::INFINITY
        }
    }
}

/// A concrete launch: some (possibly elastic-transformed) geometry carrying
/// a slice of a kernel's work. For an untransformed kernel this is the
/// identity mapping of its [`KernelDesc`]; for an elastic shard, `grid` and
/// `block_threads` come from the coordinator and `flops`/`bytes` are the
/// covered fraction of the logical work (persistent-thread N:1 mapping,
/// paper §6.1).
#[derive(Debug, Clone, PartialEq)]
pub struct LaunchConfig {
    /// Name (inherits the kernel's, plus a shard suffix).
    pub name: String,
    /// Physical thread blocks to dispatch.
    pub grid: u32,
    /// Threads per physical block.
    pub block_threads: u32,
    /// Shared memory per block, bytes.
    pub smem_per_block: u32,
    /// Registers per thread.
    pub regs_per_thread: u32,
    /// FLOPs this launch performs.
    pub flops: f64,
    /// DRAM bytes this launch moves.
    pub bytes: f64,
}

impl LaunchConfig {
    /// The identity launch of an untransformed kernel.
    pub fn from_kernel(k: &KernelDesc) -> Self {
        LaunchConfig {
            name: k.name.clone(),
            grid: k.grid,
            block_threads: k.block_threads,
            smem_per_block: k.smem_per_block,
            regs_per_thread: k.regs_per_thread,
            flops: k.flops,
            bytes: k.bytes,
        }
    }

    /// The name-free part of this launch (see [`LaunchShape`]).
    pub fn shape(&self) -> LaunchShape {
        LaunchShape {
            grid: self.grid,
            block_threads: self.block_threads,
            smem_per_block: self.smem_per_block,
            regs_per_thread: self.regs_per_thread,
            flops: self.flops,
            bytes: self.bytes,
        }
    }

    /// FLOPs carried by one thread block of this launch.
    pub fn flops_per_block(&self) -> f64 {
        self.flops / self.grid as f64
    }

    /// DRAM bytes carried by one thread block of this launch.
    pub fn bytes_per_block(&self) -> f64 {
        self.bytes / self.grid as f64
    }
}

/// A launch without its name: geometry plus work, `Copy`. The engine's
/// interned submit path ([`crate::gpu::engine::Engine::submit_interned`])
/// takes a `LaunchShape` and a pre-interned name id instead of a
/// [`LaunchConfig`], so steady-state submitters (the Miriam coordinator's
/// shard and critical paths) never allocate a name `String` per launch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchShape {
    /// Physical thread blocks to dispatch.
    pub grid: u32,
    /// Threads per physical block.
    pub block_threads: u32,
    /// Shared memory per block, bytes.
    pub smem_per_block: u32,
    /// Registers per thread.
    pub regs_per_thread: u32,
    /// FLOPs this launch performs.
    pub flops: f64,
    /// DRAM bytes this launch moves.
    pub bytes: f64,
}

impl LaunchShape {
    /// The identity shape of an untransformed kernel.
    pub fn from_kernel(k: &KernelDesc) -> Self {
        LaunchShape {
            grid: k.grid,
            block_threads: k.block_threads,
            smem_per_block: k.smem_per_block,
            regs_per_thread: k.regs_per_thread,
            flops: k.flops,
            bytes: k.bytes,
        }
    }

    /// FLOPs carried by one thread block of this shape.
    pub fn flops_per_block(&self) -> f64 {
        self.flops / self.grid as f64
    }

    /// DRAM bytes carried by one thread block of this shape.
    pub fn bytes_per_block(&self) -> f64 {
        self.bytes / self.grid as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k() -> KernelDesc {
        KernelDesc {
            name: "t/conv".into(),
            grid: 64,
            block_threads: 256,
            smem_per_block: 8192,
            regs_per_thread: 32,
            flops: 6.4e6,
            bytes: 3.2e5,
        }
    }

    #[test]
    fn per_block_work_partitions_total() {
        let k = k();
        assert!((k.flops_per_block() * k.grid as f64 - k.flops).abs() < 1e-6);
        assert!((k.bytes_per_block() * k.grid as f64 - k.bytes).abs() < 1e-6);
    }

    #[test]
    fn intensity() {
        let k = k();
        assert!((k.arithmetic_intensity() - 20.0).abs() < 1e-9);
        let pure = KernelDesc { bytes: 0.0, ..k };
        assert!(pure.arithmetic_intensity().is_infinite());
    }

    #[test]
    fn identity_launch_preserves_work() {
        let k = k();
        let l = LaunchConfig::from_kernel(&k);
        assert_eq!(l.grid, k.grid);
        assert_eq!(l.block_threads, k.block_threads);
        assert_eq!(l.flops, k.flops);
        assert_eq!(l.bytes, k.bytes);
    }

    #[test]
    fn shape_matches_config_and_kernel() {
        let k = k();
        let l = LaunchConfig::from_kernel(&k);
        let s = l.shape();
        assert_eq!(s, LaunchShape::from_kernel(&k));
        assert_eq!(s.grid, k.grid);
        assert_eq!(s.smem_per_block, k.smem_per_block);
        assert!((s.flops_per_block() - l.flops_per_block()).abs() < 1e-12);
        assert!((s.bytes_per_block() - l.bytes_per_block()).abs() < 1e-12);
    }
}
