//! GPU hardware specifications for the edge-GPU simulator.
//!
//! The paper's testbeds (NVIDIA GeForce RTX 2060, Jetson AGX Xavier) plus
//! the Jetson TX2 from its background section (§3, Fig. 1). This
//! environment has no GPU, so these specs parameterize the discrete-event
//! simulator in [`crate::gpu::engine`] — see DESIGN.md "Hardware
//! substitution" for why this preserves the paper's contention behaviour.


/// Static architecture parameters of a simulated GPU (paper Table 1's
/// `SM`, `N_SM`, `L_threads` plus the rate parameters the execution model
/// needs).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Human-readable platform name (e.g. "rtx2060").
    pub name: String,
    /// Number of streaming multiprocessors (`N_SM`).
    pub num_sms: u32,
    /// Maximum resident threads per SM (`L_threads` in paper Table 1).
    pub max_threads_per_sm: u32,
    /// Maximum resident thread blocks per SM.
    pub max_blocks_per_sm: u32,
    /// Shared memory per SM in bytes.
    pub smem_per_sm: u32,
    /// Register file size per SM (32-bit registers).
    pub regs_per_sm: u32,
    /// Threads per warp.
    pub warp_size: u32,
    /// Peak FP32 throughput of one SM, in FLOP per microsecond.
    pub flops_per_sm_us: f64,
    /// Global (DRAM) memory bandwidth, bytes per microsecond, shared by all
    /// SMs — the inter-SM contention resource (§4).
    pub dram_bw_bytes_us: f64,
    /// Fixed kernel launch overhead in microseconds (the cost OScore, Eq. 5,
    /// charges per elastic shard).
    pub kernel_launch_us: f64,
}

impl GpuSpec {
    /// NVIDIA GeForce RTX 2060: 30 SMs x 64 cores = 1920 CUDA cores
    /// (paper §8.1.1), ~6.5 TFLOPS FP32, 336 GB/s GDDR6.
    pub fn rtx2060() -> Self {
        GpuSpec {
            name: "rtx2060".into(),
            num_sms: 30,
            max_threads_per_sm: 1024,
            max_blocks_per_sm: 16,
            smem_per_sm: 64 * 1024,
            regs_per_sm: 65_536,
            warp_size: 32,
            // 6.45 TFLOPS / 30 SMs = 215 GFLOP/s/SM = 215_000 FLOP/us.
            flops_per_sm_us: 215_000.0,
            // 336 GB/s = 336_000 bytes/us.
            dram_bw_bytes_us: 336_000.0,
            kernel_launch_us: 5.0,
        }
    }

    /// NVIDIA Jetson AGX Xavier (paper §8.1.1 describes its GPU as a
    /// 256-core edge part): 8 SMs, ~1.4 TFLOPS FP32, 137 GB/s LPDDR4x,
    /// thermally constrained (lower effective per-SM rate).
    pub fn xavier() -> Self {
        GpuSpec {
            name: "xavier".into(),
            num_sms: 8,
            max_threads_per_sm: 1024,
            max_blocks_per_sm: 16,
            smem_per_sm: 48 * 1024,
            regs_per_sm: 65_536,
            warp_size: 32,
            // 1.4 TFLOPS / 8 SMs, derated ~20% for edge thermals (§8.2
            // discusses the Xavier's TDP-limited clocks).
            flops_per_sm_us: 140_000.0,
            dram_bw_bytes_us: 137_000.0,
            kernel_launch_us: 8.0,
        }
    }

    /// NVIDIA Jetson TX2 (paper Fig. 1): 2 SMs x 128 cores, 0.665 TFLOPS,
    /// 59.7 GB/s. Used by tests as the smallest-contention platform.
    pub fn tx2() -> Self {
        GpuSpec {
            name: "tx2".into(),
            num_sms: 2,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 16,
            smem_per_sm: 64 * 1024,
            regs_per_sm: 65_536,
            warp_size: 32,
            flops_per_sm_us: 332_000.0,
            dram_bw_bytes_us: 59_700.0,
            kernel_launch_us: 10.0,
        }
    }

    /// Canonical preset names, in presentation order — the vocabulary of
    /// every platform-naming CLI flag (`--platform`, `--devices`).
    /// `by_name` resolves each of these (plus a couple of aliases) to the
    /// preset whose `name` field round-trips to the same string.
    pub const PRESET_NAMES: [&'static str; 3] = ["rtx2060", "xavier", "tx2"];

    /// Every preset, in [`GpuSpec::PRESET_NAMES`] order.
    pub fn presets() -> Vec<Self> {
        Self::PRESET_NAMES
            .iter()
            .map(|n| Self::by_name(n).expect("preset name resolves"))
            .collect()
    }

    /// Look up a named preset.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "rtx2060" | "2060" => Some(Self::rtx2060()),
            "xavier" => Some(Self::xavier()),
            "tx2" => Some(Self::tx2()),
            _ => None,
        }
    }

    /// Maximum resident warps per SM (denominator of achieved occupancy,
    /// §8.1.4).
    pub fn max_warps_per_sm(&self) -> u32 {
        self.max_threads_per_sm / self.warp_size
    }

    /// Total peak FP32 throughput in FLOP/us.
    pub fn total_flops_us(&self) -> f64 {
        self.flops_per_sm_us * self.num_sms as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        assert_eq!(GpuSpec::by_name("rtx2060").unwrap().num_sms, 30);
        assert_eq!(GpuSpec::by_name("2060").unwrap().num_sms, 30);
        assert_eq!(GpuSpec::by_name("xavier").unwrap().num_sms, 8);
        assert_eq!(GpuSpec::by_name("tx2").unwrap().num_sms, 2);
        assert!(GpuSpec::by_name("h100").is_none());
    }

    #[test]
    fn occupancy_denominator() {
        assert_eq!(GpuSpec::rtx2060().max_warps_per_sm(), 32);
        assert_eq!(GpuSpec::tx2().max_warps_per_sm(), 64);
    }

    #[test]
    fn every_preset_round_trips_by_name() {
        // ISSUE 5 satellite: `by_name` over PRESET_NAMES is a bijection
        // onto the presets, and each preset's `name` field round-trips —
        // fleet device labels (`d0-xavier`, ...) depend on this.
        assert_eq!(GpuSpec::PRESET_NAMES.len(), GpuSpec::presets().len());
        for name in GpuSpec::PRESET_NAMES {
            let spec = GpuSpec::by_name(name)
                .unwrap_or_else(|| panic!("preset {name} does not resolve"));
            assert_eq!(spec.name, name, "preset name does not round-trip");
            let again = GpuSpec::by_name(&spec.name).unwrap();
            assert_eq!(again, spec, "{name}: by_name not idempotent");
        }
        // The alias resolves to a canonical preset, never a new name.
        let alias = GpuSpec::by_name("2060").unwrap();
        assert!(GpuSpec::PRESET_NAMES.contains(&alias.name.as_str()));
    }

    #[test]
    fn preset_invariants_hold_for_every_preset() {
        for spec in GpuSpec::presets() {
            // Warp arithmetic: threads per SM divide into whole warps and
            // the occupancy denominator is consistent with it.
            assert_eq!(spec.max_threads_per_sm % spec.warp_size, 0,
                       "{}: ragged warp count", spec.name);
            assert_eq!(spec.max_warps_per_sm(),
                       spec.max_threads_per_sm / spec.warp_size,
                       "{}", spec.name);
            assert!(spec.max_warps_per_sm() >= 1, "{}", spec.name);
            // Peak FLOP arithmetic.
            let total = spec.total_flops_us();
            assert!((total - spec.flops_per_sm_us * spec.num_sms as f64)
                        .abs()
                        <= 1e-9 * total,
                    "{}", spec.name);
            // Everything the contention model divides by is positive.
            assert!(spec.num_sms >= 1, "{}", spec.name);
            assert!(spec.max_blocks_per_sm >= 1, "{}", spec.name);
            assert!(spec.flops_per_sm_us > 0.0, "{}", spec.name);
            assert!(spec.dram_bw_bytes_us > 0.0, "{}", spec.name);
            assert!(spec.kernel_launch_us > 0.0, "{}", spec.name);
            assert!(spec.smem_per_sm > 0 && spec.regs_per_sm > 0,
                    "{}", spec.name);
        }
    }

    #[test]
    fn edge_parts_are_smaller() {
        // The paper's premise: edge GPUs have far fewer on-board resources.
        let big = GpuSpec::rtx2060();
        let small = GpuSpec::xavier();
        assert!(small.num_sms < big.num_sms);
        assert!(small.dram_bw_bytes_us < big.dram_bw_bytes_us);
        assert!(small.total_flops_us() < big.total_flops_us());
    }
}
