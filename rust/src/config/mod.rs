//! Run configuration: which platform, workload, scheduler(s), duration —
//! shared by the CLI, the examples and the bench harnesses.

pub mod cli;


use crate::gpu::spec::GpuSpec;
use crate::workloads::mdtb::{self, WorkloadSpec};

/// A full simulation-run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// GPU preset name ("rtx2060", "xavier", "tx2").
    pub platform: String,
    /// Workload name ("A".."D" for MDTB, "lgsvl").
    pub workload: String,
    /// Scheduler names to run (subset of coordinator::SCHEDULERS).
    pub schedulers: Vec<String>,
    /// Simulated duration in seconds.
    pub duration_s: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            platform: "rtx2060".into(),
            workload: "A".into(),
            schedulers: crate::coordinator::SCHEDULERS
                .iter()
                .map(|s| s.to_string())
                .collect(),
            duration_s: 1.0,
        }
    }
}

impl RunConfig {
    /// The GPU preset this config names, if known.
    pub fn spec(&self) -> Option<GpuSpec> {
        GpuSpec::by_name(&self.platform)
    }

    /// The MDTB workload this config names, if any.
    pub fn workload_spec(&self) -> Option<WorkloadSpec> {
        mdtb::by_name(&self.workload, self.duration_s * 1e6)
    }

    /// Check platform, workload, scheduler names and duration.
    pub fn validate(&self) -> Result<(), String> {
        if self.spec().is_none() {
            return Err(format!("unknown platform {}", self.platform));
        }
        if self.workload_spec().is_none()
            && self.workload.to_ascii_lowercase() != "lgsvl"
        {
            return Err(format!("unknown workload {}", self.workload));
        }
        for s in &self.schedulers {
            // Everything scheduler_for resolves is accepted, including
            // miriam-ref and the parameterized isolation family.
            if !crate::coordinator::is_scheduler_name(s) {
                return Err(format!("unknown scheduler {s}"));
            }
        }
        if self.duration_s <= 0.0 {
            return Err("duration must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(RunConfig::default().validate().is_ok());
    }

    #[test]
    fn rejects_unknowns() {
        let mut c = RunConfig::default();
        c.platform = "h100".into();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.workload = "Z".into();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.schedulers = vec!["fifo".into()];
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.duration_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lgsvl_is_a_known_workload() {
        let mut c = RunConfig::default();
        c.workload = "lgsvl".into();
        assert!(c.validate().is_ok());
    }
}
