//! Minimal CLI argument parser (no external deps in this offline build):
//! `--key value` / `--key=value` flags plus positional arguments.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments, in order (the subcommand comes first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` flags (value-less flags map to
    /// `"true"`).
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // "--" terminator: everything after is positional.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.flags.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (argv[0] excluded).
    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag with a default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    /// String flag without a default: `None` when the flag is absent —
    /// for flags whose mere presence changes behaviour (`--trace-out`,
    /// `--record-golden`, `--seed` overrides).
    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Float flag with a default; errors on an unparsable value.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number {v}")),
        }
    }

    /// Unsigned integer flag with a default; errors on an unparsable value.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v}")),
        }
    }

    /// 64-bit unsigned flag with a default; errors on an unparsable value.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v}")),
        }
    }

    /// Whether the flag was given at all (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Comma-separated list flag: `--key a,b,c` (whitespace around items
    /// trimmed, empty items dropped). Falls back to parsing `default` the
    /// same way when the flag is absent.
    pub fn get_list(&self, key: &str, default: &str) -> Vec<String> {
        self.get(key, default)
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["simulate", "--platform", "xavier", "--duration=2.5",
                        "--verbose"]);
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.get("platform", "rtx2060"), "xavier");
        assert_eq!(a.get_f64("duration", 1.0).unwrap(), 2.5);
        assert!(a.has("verbose"));
        assert_eq!(a.get("missing", "d"), "d");
    }

    #[test]
    fn optional_flags_distinguish_absent_from_valueless() {
        let a = parse(&["--trace-out", "t.json", "--verbose"]);
        assert_eq!(a.get_opt("trace-out"), Some("t.json"));
        assert_eq!(a.get_opt("verbose"), Some("true"));
        assert_eq!(a.get_opt("missing"), None);
    }

    #[test]
    fn double_dash_terminates_flags() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["run", "--not-a-flag"]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--duration", "abc"]);
        assert!(a.get_f64("duration", 1.0).is_err());
        assert!(a.get_usize("duration", 1).is_err());
        assert!(a.get_u64("duration", 1).is_err());
    }

    #[test]
    fn u64_flags_parse_and_default() {
        let a = parse(&["--seed", "12345"]);
        assert_eq!(a.get_u64("seed", 7).unwrap(), 12345);
        assert_eq!(a.get_u64("missing", 7).unwrap(), 7);
    }

    #[test]
    fn list_flags_split_trim_and_default() {
        let a = parse(&["--schedulers", " miriam , ib ,,sequential"]);
        assert_eq!(a.get_list("schedulers", "x"),
                   vec!["miriam", "ib", "sequential"]);
        assert_eq!(a.get_list("missing", "a,b"), vec!["a", "b"]);
    }
}
